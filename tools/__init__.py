"""Repo tooling package (``python -m tools.analyze``, doc checker)."""
