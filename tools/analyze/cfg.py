"""Intraprocedural control-flow graphs for the verification rules.

The lint rules (R1–R4) answer *where* questions — is this call inside a
traced function, does this jit donate — and a flat ``shallow_walk`` is
enough.  The verification rules (R5–R8, ``tools/analyze/verify.py``)
answer *ordering* questions: does every page allocation reach a release
on every exit, including the exception exit an ``OutOfPages`` raise
takes; is a PRNG key consumed twice without an interleaving ``split``.
Those need a CFG.

``build_cfg(fn_node, may_raise)`` returns a :class:`CFG` of statement
blocks with three virtual endpoints: ``entry``, ``exit`` (return /
fall-off) and ``raise_exit`` (an exception escaping the function).  The
caller supplies ``may_raise(stmt) -> bool``; a statement it flags is
isolated in its own single-statement block with ``raises=True`` and an
``"exc"`` edge to the innermost enclosing handler (or ``raise_exit``).
Keeping raising statements isolated lets a dataflow pass distinguish
the state *before* the statement (what the exception path sees — an
``x = pool.alloc()`` that raises never bound ``x``) from the state
after it (what the fall-through path sees).

Modeling choices, deliberately simple and documented:

* **exception edges go to the handler chain, not past it** — we do not
  track exception *types*, so a ``try`` body's raising statements edge
  to every handler of that ``try``; only an explicit ``raise`` inside a
  handler propagates outward.  This under-approximates propagation of
  unmatched exception types and over-approximates which handler runs;
  both are benign for the lifecycle rules (handlers around alloc code
  in this repo catch ``OutOfPages`` / clean up unconditionally).
* **finally bodies are duplicated per continuation** (normal /
  exception / return), the classic lowering — each copy sees the state
  of the path that entered it.
* branch/loop conditions and ``for`` iterables are materialized into
  the graph as synthetic ``ast.Expr`` / ``ast.Assign`` statements so a
  dataflow pass sees every expression exactly once, uniformly.
* nested ``def`` / ``class`` / ``lambda`` bodies are opaque single
  statements (they have their own CFG), matching ``shallow_walk``.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["Block", "CFG", "build_cfg"]


@dataclasses.dataclass
class Block:
    bid: int
    stmts: List[ast.stmt] = dataclasses.field(default_factory=list)
    succs: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    # True iff this block holds exactly one statement that may raise;
    # its "exc" edge carries the state from *before* the statement.
    raises: bool = False

    def add_succ(self, bid: int, kind: str) -> None:
        if (bid, kind) not in self.succs:
            self.succs.append((bid, kind))


@dataclasses.dataclass
class CFG:
    blocks: Dict[int, Block]
    entry: int
    exit: int
    raise_exit: int

    def preds(self) -> Dict[int, List[Tuple[int, str]]]:
        out: Dict[int, List[Tuple[int, str]]] = {b: [] for b in self.blocks}
        for blk in self.blocks.values():
            for bid, kind in blk.succs:
                out[bid].append((blk.bid, kind))
        return out

    def rpo(self) -> List[int]:
        """Reverse post-order from entry (loops converge fast)."""
        seen, order = set(), []

        def visit(bid: int) -> None:
            stack = [(bid, iter(self.blocks[bid].succs))]
            seen.add(bid)
            while stack:
                cur, it = stack[-1]
                advanced = False
                for nxt, _kind in it:
                    if nxt not in seen:
                        seen.add(nxt)
                        stack.append((nxt, iter(self.blocks[nxt].succs)))
                        advanced = True
                        break
                if not advanced:
                    order.append(cur)
                    stack.pop()

        visit(self.entry)
        return list(reversed(order))


_OPAQUE = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


class _Builder:
    def __init__(self, may_raise: Callable[[ast.stmt], bool]):
        self.may_raise = may_raise
        self.blocks: Dict[int, Block] = {}
        self.entry = self._new()
        self.exit = self._new()
        self.raise_exit = self._new()
        self.cur: Optional[int] = self.entry
        # innermost-first stacks
        self.exc_targets: List[List[int]] = [[self.raise_exit]]
        self.loop_stack: List[Tuple[int, int]] = []   # (continue, break)
        # pending finally bodies (innermost last); Return/Break/Continue
        # and escaping exceptions must thread through copies of these
        self.finally_stack: List[List[ast.stmt]] = []

    # -- graph primitives ---------------------------------------------------

    def _new(self) -> int:
        bid = len(self.blocks)
        self.blocks[bid] = Block(bid=bid)
        return bid

    def _edge(self, frm: Optional[int], to: int, kind: str = "next") -> None:
        if frm is not None:
            self.blocks[frm].add_succ(to, kind)

    def _start(self) -> int:
        """Seal the current block and open a fresh one chained to it."""
        nxt = self._new()
        self._edge(self.cur, nxt)
        self.cur = nxt
        return nxt

    def _emit(self, stmt: ast.stmt) -> None:
        if self.cur is None:          # unreachable code after return/raise
            self.cur = self._new()
        if self.may_raise(stmt):
            blk = self._start()
            self.blocks[blk].stmts.append(stmt)
            self.blocks[blk].raises = True
            for tgt in self.exc_targets[-1]:
                self._edge(blk, tgt, "exc")
            self._start()
        else:
            self.blocks[self.cur].stmts.append(stmt)

    def _thread_finallies(self, upto: int) -> None:
        """Emit copies of the pending finally bodies (innermost first)
        down to stack depth ``upto`` — used by Return/Break/Continue."""
        for body in reversed(self.finally_stack[upto:]):
            for s in body:
                self._emit(s)

    # -- statement visitors --------------------------------------------------

    def build(self, fn: ast.FunctionDef) -> CFG:
        self.visit_body(fn.body)
        self._edge(self.cur, self.exit)
        return CFG(blocks=self.blocks, entry=self.entry, exit=self.exit,
                   raise_exit=self.raise_exit)

    def visit_body(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self.visit(stmt)

    def visit(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, _OPAQUE):
            self._emit(stmt)
        elif isinstance(stmt, ast.Return):
            self._emit(stmt)
            self._thread_finallies(0)
            self._edge(self.cur, self.exit)
            self.cur = None
        elif isinstance(stmt, ast.Raise):
            blk = self._start()
            self.blocks[blk].stmts.append(stmt)
            self.blocks[blk].raises = True
            for tgt in self.exc_targets[-1]:
                self._edge(blk, tgt, "exc")
            self.cur = None
        elif isinstance(stmt, (ast.Break, ast.Continue)):
            if self.loop_stack:
                cont, brk = self.loop_stack[-1]
                self._edge(self.cur, brk if isinstance(stmt, ast.Break)
                           else cont)
            self.cur = None
        elif isinstance(stmt, ast.If):
            self._visit_if(stmt)
        elif isinstance(stmt, (ast.While,)):
            self._visit_while(stmt)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._visit_for(stmt)
        elif isinstance(stmt, ast.Try):
            self._visit_try(stmt)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._emit(ast.copy_location(
                    ast.Expr(value=item.context_expr), stmt))
                if item.optional_vars is not None:
                    self._emit(ast.copy_location(
                        ast.Assign(targets=[item.optional_vars],
                                   value=item.context_expr), stmt))
            self.visit_body(stmt.body)
        else:
            self._emit(stmt)

    def _visit_if(self, stmt: ast.If) -> None:
        self._emit(ast.copy_location(ast.Expr(value=stmt.test), stmt))
        cond = self.cur
        join = self._new()
        # true arm
        self.cur = self._new()
        self._edge(cond, self.cur, "true")
        self.visit_body(stmt.body)
        self._edge(self.cur, join)
        # false arm
        if stmt.orelse:
            self.cur = self._new()
            self._edge(cond, self.cur, "false")
            self.visit_body(stmt.orelse)
            self._edge(self.cur, join)
        else:
            self._edge(cond, join, "false")
        self.cur = join

    def _visit_while(self, stmt: ast.While) -> None:
        head = self._start()
        self.blocks[head].stmts.append(
            ast.copy_location(ast.Expr(value=stmt.test), stmt))
        after = self._new()
        body = self._new()
        self._edge(head, body, "true")
        self._edge(head, after, "false")
        self.loop_stack.append((head, after))
        self.cur = body
        self.visit_body(stmt.body)
        self._edge(self.cur, head, "back")
        self.loop_stack.pop()
        if stmt.orelse:
            self.cur = after
            self.visit_body(stmt.orelse)
        else:
            self.cur = after

    def _visit_for(self, stmt) -> None:
        self._emit(ast.copy_location(ast.Expr(value=stmt.iter), stmt))
        head = self._start()
        # loop variable binding, once per iteration
        self.blocks[head].stmts.append(ast.copy_location(
            ast.Assign(targets=[stmt.target], value=stmt.iter), stmt))
        after = self._new()
        body = self._new()
        self._edge(head, body, "true")
        self._edge(head, after, "false")
        self.loop_stack.append((head, after))
        self.cur = body
        self.visit_body(stmt.body)
        self._edge(self.cur, head, "back")
        self.loop_stack.pop()
        if stmt.orelse:
            self.cur = after
            self.visit_body(stmt.orelse)
        else:
            self.cur = after

    def _visit_try(self, stmt: ast.Try) -> None:
        has_fin = bool(stmt.finalbody)
        after = self._new()

        handler_entries: List[int] = []
        for _h in stmt.handlers:
            handler_entries.append(self._new())

        # exceptions raised in the body go to the handlers (or, with no
        # handlers, through a finally copy to the outer target)
        if handler_entries:
            body_exc = handler_entries
        elif has_fin:
            body_exc = [self._build_finally_exc(stmt.finalbody)]
        else:
            body_exc = self.exc_targets[-1]

        self._start()
        self.exc_targets.append(body_exc)
        if has_fin:
            self.finally_stack.append(stmt.finalbody)
        self.visit_body(stmt.body)
        if stmt.orelse:
            self.visit_body(stmt.orelse)
        if has_fin:
            self.finally_stack.pop()
            self._thread_finallies_copy(stmt.finalbody)
        self.exc_targets.pop()
        self._edge(self.cur, after)

        # handlers: exceptions inside a handler (incl. bare `raise`)
        # escape past this try — through a finally copy if present
        for h, entry in zip(stmt.handlers, handler_entries):
            self.cur = entry
            if h.name and h.type is not None:
                self._emit(ast.copy_location(
                    ast.Assign(targets=[ast.Name(id=h.name, ctx=ast.Store())],
                               value=h.type), h))
            if has_fin:
                outer = [self._build_finally_exc(stmt.finalbody)]
                self.exc_targets.append(outer)
                self.finally_stack.append(stmt.finalbody)
            self.visit_body(h.body)
            if has_fin:
                self.finally_stack.pop()
                self.exc_targets.pop()
                self._thread_finallies_copy(stmt.finalbody)
            self._edge(self.cur, after)

        self.cur = after

    def _thread_finallies_copy(self, body: List[ast.stmt]) -> None:
        """Normal-completion copy of one finally body, inline."""
        if self.cur is None:
            return
        for s in body:
            self._emit(s)

    def _build_finally_exc(self, body: List[ast.stmt]) -> int:
        """Exception-path copy of a finally body: runs the cleanup, then
        continues to the enclosing exception target."""
        saved = self.cur
        self.cur = self._new()
        entry = self.cur
        for s in body:
            self._emit(s)
        for tgt in self.exc_targets[-1]:
            self._edge(self.cur, tgt, "exc")
        # the finally-on-exception path re-raises; it has no normal succ
        self.cur = saved
        return entry


def build_cfg(fn: ast.FunctionDef,
              may_raise: Optional[Callable[[ast.stmt], bool]] = None) -> CFG:
    """CFG of one function body.  ``may_raise`` marks statements that get
    their own block + an exception edge; default: explicit ``raise`` only
    (which is always modeled regardless)."""
    return _Builder(may_raise or (lambda s: False)).build(fn)
