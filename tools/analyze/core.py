"""AST core for ``repro-lint``: module index, cross-module call graph,
traced-region reachability and tracer-taint dataflow.

The analyzer is repo-specific by design: it resolves the idioms this
codebase actually uses (factory functions returning closures that get
jitted, per-bucket jit caches assigned through ``self._cache[key]``,
``from repro.kernels import ops as kops`` aliasing) instead of trying
to be a general-purpose type checker.  Everything is stdlib ``ast`` —
no imports of the analyzed code, no third-party deps.

Vocabulary:

* **jit root** — a function object handed to a tracing entry point
  (``jax.jit``, ``lax.scan``/``cond``/``while_loop``, ``pl.pallas_call``,
  ``jax.grad`` / ``value_and_grad``, ``vmap``, ``shard_map``, ...) either
  by name, decorator, or ``functools.partial(jax.jit, ...)``.
* **traced region** — the call-graph closure of the jit roots: any
  function reachable from a root (cross-module, via the import map and
  factory-return resolution) executes under tracing, so host-sync
  operations inside it are R1 findings.
* **taint** — "this value derives from a traced function's runtime
  arguments" (i.e. it is a tracer at trace time).  Static jit args,
  closure constants and shape/dtype attributes are untainted.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

FuncId = Tuple[str, str]        # (module dotted name, qualified func name)

# tracing entry points: canonical dotted name -> indices of positional
# args that are traced callables
TRACE_ENTRIES: Dict[str, Tuple[int, ...]] = {
    "jax.jit": (0,),
    "jax.pjit": (0,),
    "jax.experimental.pjit.pjit": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": (1,),     # list of branches, handled specially
    "jax.lax.map": (0,),
    "jax.lax.associative_scan": (0,),
    "jax.vmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.experimental.shard_map.shard_map": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
}

# modules whose functions are host-side BY CONTRACT even when invoked
# from hot-path code: the fault-injection hooks (inert no-ops unless a
# test installs an injector) and the transfer-guard helpers (annotation
# wrappers around explicit, intentional transfers).  The traced-closure
# BFS does not descend into them, so their host ops (np.isfinite,
# device_get inside annotated_transfer, ...) are not flagged as traced
# transfers — they already ARE the audited boundary.
TRACED_EXEMPT_MODULES: Set[str] = {
    "repro.core.faults",
    "repro.core.guard",
}

# import roots we canonicalize even without seeing their definition
_WELL_KNOWN = {
    "jnp": "jax.numpy",
    "np": "numpy",
    "onp": "numpy",
    "lax": "jax.lax",
    "pl": "jax.experimental.pallas",
    "pltpu": "jax.experimental.pallas.tpu",
}

_BUILTINS = set(dir(__builtins__)) if not isinstance(__builtins__, dict) \
    else set(__builtins__)


def _arg_names(node: ast.FunctionDef) -> List[str]:
    a = node.args
    names = [x.arg for x in a.posonlyargs] + [x.arg for x in a.args] + \
        [x.arg for x in a.kwonlyargs]
    return names


def _param_defaults(node: ast.FunctionDef) -> Dict[str, ast.AST]:
    """param name -> default expr (positional + kwonly)."""
    a = node.args
    out: Dict[str, ast.AST] = {}
    pos = a.posonlyargs + a.args
    for name, default in zip([p.arg for p in pos[len(pos)
                                                 - len(a.defaults):]],
                             a.defaults):
        out[name] = default
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            out[p.arg] = d
    return out


@dataclasses.dataclass
class JitSite:
    """One ``jax.jit(...)`` (or partial/decorator) creation site."""

    module: str
    lineno: int
    target: Optional[FuncId]            # the jitted function, if resolved
    in_function: Optional[str]          # qualname of the enclosing function
    in_loop: bool                       # lexically inside a for/while body
    static_argnums: Optional[Tuple[int, ...]] = None
    static_argnames: Optional[Tuple[str, ...]] = None
    donate_argnums: Optional[Tuple[int, ...]] = None
    donate_argnames: Optional[Tuple[str, ...]] = None
    call_node: Optional[ast.Call] = None
    entry: str = "jax.jit"              # which tracing entry created it


@dataclasses.dataclass
class FuncInfo:
    module: str
    qualname: str
    node: ast.FunctionDef
    parent: Optional[str]               # enclosing *function* qualname
    params: List[str] = dataclasses.field(default_factory=list)
    calls: Set[FuncId] = dataclasses.field(default_factory=set)
    returns_funcs: Set[FuncId] = dataclasses.field(default_factory=set)
    returns_jit: List[JitSite] = dataclasses.field(default_factory=list)
    is_root: bool = False
    traced: bool = False
    static_params: Set[str] = dataclasses.field(default_factory=set)
    jit_sites: List[JitSite] = dataclasses.field(default_factory=list)
    # params whose default is a Python literal (config flags like
    # ``causal=True`` — by convention passed as constants, not tracers)
    literal_defaults: Set[str] = dataclasses.field(default_factory=set)

    @property
    def fid(self) -> FuncId:
        return (self.module, self.qualname)


@dataclasses.dataclass
class ModuleInfo:
    name: str                           # dotted module name
    path: str                           # repo-relative path
    tree: ast.Module
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)
    # local name -> (module, attr) for from-imports of module members
    from_imports: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    functions: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)


def shallow_walk(nodes) -> Iterable[ast.AST]:
    """Like ``ast.walk`` over a statement list, but does NOT descend
    into nested function/class definitions — their bodies belong to
    their own :class:`FuncInfo` and double-recording them duplicates
    call edges and jit sites."""
    stack = list(nodes)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                continue
            stack.append(child)


def module_name_for(path: str) -> str:
    """repo-relative path -> dotted module name (src/ stripped)."""
    rel = path.replace(os.sep, "/")
    if rel.startswith("src/"):
        rel = rel[len("src/"):]
    rel = rel[:-3] if rel.endswith(".py") else rel
    if rel.endswith("/__init__"):
        rel = rel[: -len("/__init__")]
    return rel.replace("/", ".")


# ---------------------------------------------------------------------------
# per-module indexing
# ---------------------------------------------------------------------------

class _Indexer(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: List[str] = []          # qualname parts (class + func)
        self.func_stack: List[FuncInfo] = []
        self.loop_depth = 0

    # imports -----------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            local = alias.asname or alias.name.split(".")[0]
            target = alias.name if alias.asname else \
                alias.name.split(".")[0]
            self.mod.imports[local] = target

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None:
            return
        base = node.module
        if node.level:          # relative import: resolve against module
            parts = self.mod.name.split(".")
            parts = parts[: len(parts) - node.level]
            base = ".".join(parts + ([node.module] if node.module else []))
        for alias in node.names:
            local = alias.asname or alias.name
            self.mod.from_imports[local] = (base, alias.name)

    # defs --------------------------------------------------------------------
    def _qual(self, name: str) -> str:
        return ".".join(self.stack + [name])

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def _visit_func(self, node: ast.FunctionDef) -> None:
        qual = self._qual(node.name)
        parent = self.func_stack[-1].qualname if self.func_stack else None
        fi = FuncInfo(module=self.mod.name, qualname=qual, node=node,
                      parent=parent, params=_arg_names(node),
                      literal_defaults={
                          name for name, d in _param_defaults(node).items()
                          if isinstance(d, ast.Constant)})
        self.mod.functions[qual] = fi
        self.stack.append(node.name)
        self.func_stack.append(fi)
        self.generic_visit(node)
        self.func_stack.pop()
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_For(self, node: ast.For) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_While = visit_For
    visit_AsyncFor = visit_For


def index_module(name: str, path: str, source: str) -> ModuleInfo:
    tree = ast.parse(source, filename=path)
    mod = ModuleInfo(name=name, path=path, tree=tree)
    _Indexer(mod).visit(tree)
    return mod


# ---------------------------------------------------------------------------
# name / call resolution
# ---------------------------------------------------------------------------

class Index:
    """Whole-analysis view over the indexed modules."""

    def __init__(self, modules: Dict[str, ModuleInfo]):
        self.modules = modules
        self.jit_sites: List[JitSite] = []
        self._resolve_all()
        self._compute_returns_fixpoint()
        self._mark_traced()

    # -- helpers ---------------------------------------------------------------

    def func(self, fid: FuncId) -> Optional[FuncInfo]:
        mod = self.modules.get(fid[0])
        return mod.functions.get(fid[1]) if mod else None

    def all_functions(self) -> Iterable[FuncInfo]:
        for mod in self.modules.values():
            yield from mod.functions.values()

    def dotted_name(self, mod: ModuleInfo, node: ast.AST
                    ) -> Optional[str]:
        """Canonical dotted name of an expression like ``jax.lax.scan``,
        ``kops.paged_attention`` or ``partial`` — import aliases at the
        root are expanded (well-known jax/numpy aliases too)."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = node.id
        parts.append(root)
        parts.reverse()
        if root in mod.imports:
            parts[0] = mod.imports[root]
        elif root in mod.from_imports:
            base, attr = mod.from_imports[root]
            full = f"{base}.{attr}"
            parts[0] = full
        elif root in _WELL_KNOWN:
            parts[0] = _WELL_KNOWN[root]
        name = ".".join(parts)
        # normalize second-level well-knowns (from jax import lax, numpy..)
        for alias, full in _WELL_KNOWN.items():
            if name == alias or name.startswith(alias + "."):
                name = full + name[len(alias):]
        if name == "functools.partial":
            return name
        if name == "partial":
            return "functools.partial"
        if name in ("jit", "pjit"):
            return "jax.jit"
        return name

    def resolve_callable(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                         node: ast.AST, *, _env: Optional[Dict] = None
                         ) -> Set[FuncId]:
        """Best-effort: which function defs may ``node`` (a callable
        expression) denote?  Handles local defs (walking the enclosing
        function chain), module-level defs, imported names, ``self.X``
        methods, module-alias attributes, and local variables assigned
        from factory calls (via ``returns_funcs``)."""
        out: Set[FuncId] = set()
        if isinstance(node, ast.Name):
            name = node.id
            # nested defs / enclosing chain
            chain: List[Optional[FuncInfo]] = []
            cur = scope
            while cur is not None:
                chain.append(cur)
                cur = mod.functions.get(cur.parent) if cur.parent else None
            for fi in chain:
                cand = mod.functions.get(fi.qualname + "." + name)
                if cand:
                    return {cand.fid}
            if name in mod.functions:
                return {(mod.name, name)}
            if name in mod.from_imports:
                base, attr = mod.from_imports[name]
                target = self.modules.get(base)
                if target and attr in target.functions:
                    return {(base, attr)}
                # from a import b where a.b is a module
                sub = self.modules.get(f"{base}.{attr}")
                if sub:
                    return set()
            # local variable assigned from a factory call, resolved by
            # the scan in _resolve_all via per-function env
            if _env and name in _env:
                return set(_env[name])
            return out
        if isinstance(node, ast.Attribute):
            # self.method / cls.method
            if isinstance(node.value, ast.Name) and \
                    node.value.id in ("self", "cls") and scope is not None:
                cls_prefix = scope.qualname.rsplit(".", 1)[0] \
                    if "." in scope.qualname else None
                if cls_prefix:
                    cand = mod.functions.get(
                        f"{cls_prefix}.{node.attr}")
                    if cand:
                        return {cand.fid}
                return out
            dotted = self.dotted_name(mod, node)
            if dotted and "." in dotted:
                mod_part, attr = dotted.rsplit(".", 1)
                target = self.modules.get(mod_part)
                if target and attr in target.functions:
                    return {(mod_part, attr)}
        return out

    # -- pass: calls, jit sites, factory returns ------------------------------

    def _jit_kwargs(self, call: ast.Call) -> Dict[str, Optional[tuple]]:
        def lit_tuple(node):
            if isinstance(node, ast.Constant):
                return (node.value,)
            if isinstance(node, (ast.Tuple, ast.List)):
                vals = []
                for e in node.elts:
                    if not isinstance(e, ast.Constant):
                        return None
                    vals.append(e.value)
                return tuple(vals)
            return None

        out: Dict[str, Optional[tuple]] = {}
        for kw in call.keywords:
            if kw.arg in ("static_argnums", "static_argnames",
                          "donate_argnums", "donate_argnames"):
                out[kw.arg] = lit_tuple(kw.value)
        return out

    def _record_jit_site(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                         call: ast.Call, fn_node: Optional[ast.AST],
                         in_loop: bool, env: Dict,
                         entry: str = "jax.jit") -> JitSite:
        target: Optional[FuncId] = None
        if fn_node is not None:
            cands = self.resolve_callable(mod, scope, fn_node, _env=env)
            if len(cands) == 1:
                target = next(iter(cands))
        kw = self._jit_kwargs(call)
        site = JitSite(
            module=mod.name, lineno=call.lineno, target=target,
            in_function=scope.qualname if scope else None,
            in_loop=in_loop,
            static_argnums=kw.get("static_argnums"),
            static_argnames=kw.get("static_argnames"),
            donate_argnums=kw.get("donate_argnums"),
            donate_argnames=kw.get("donate_argnames"),
            call_node=call, entry=entry)
        self.jit_sites.append(site)
        if scope is not None:
            scope.jit_sites.append(site)
        if target is not None:
            fi = self.func(target)
            if fi is not None:
                fi.is_root = True
                if site.static_argnames:
                    fi.static_params |= set(site.static_argnames)
                if site.static_argnums:
                    for i in site.static_argnums:
                        if isinstance(i, int) and i < len(fi.params):
                            fi.static_params.add(fi.params[i])
        return site

    def _resolve_all(self) -> None:
        for mod in self.modules.values():
            for fi in mod.functions.values():
                self._resolve_function(mod, fi)
            # module-level trace entries (decorless top-level jit calls)
            self._scan_body(mod, None, mod.tree.body, {}, 0)

    def _resolve_function(self, mod: ModuleInfo, fi: FuncInfo) -> None:
        # decorators
        for dec in fi.node.decorator_list:
            dec_call = dec if isinstance(dec, ast.Call) else None
            name = self.dotted_name(
                mod, dec_call.func if dec_call else dec)
            if name == "functools.partial" and dec_call and dec_call.args:
                inner = self.dotted_name(mod, dec_call.args[0])
                if inner in TRACE_ENTRIES:
                    fi.is_root = True
                    kw = self._jit_kwargs(dec_call)
                    site = JitSite(
                        module=mod.name, lineno=dec.lineno, target=fi.fid,
                        in_function=fi.parent, in_loop=False,
                        static_argnums=kw.get("static_argnums"),
                        static_argnames=kw.get("static_argnames"),
                        donate_argnums=kw.get("donate_argnums"),
                        donate_argnames=kw.get("donate_argnames"),
                        call_node=dec_call, entry=inner)
                    self.jit_sites.append(site)
                    if site.static_argnames:
                        fi.static_params |= set(site.static_argnames)
                    if site.static_argnums:
                        for i in site.static_argnums:
                            if isinstance(i, int) and i < len(fi.params):
                                fi.static_params.add(fi.params[i])
            elif name in TRACE_ENTRIES:
                fi.is_root = True
                if dec_call is not None:
                    self._record_jit_site(mod, mod.functions.get(fi.parent)
                                          if fi.parent else None,
                                          dec_call, None, False, {})
        self._scan_body(mod, fi, fi.node.body, {}, 0)

    def _scan_body(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                   body: Sequence[ast.stmt], env: Dict, loop_depth: int
                   ) -> None:
        """Walk one function body (not descending into nested defs —
        they are scanned as their own FuncInfo) recording calls, jit
        sites and factory-return assignments."""
        for stmt in body:
            for node in shallow_walk([stmt]):
                if isinstance(node, ast.Call):
                    self._handle_call(mod, scope, node, env,
                                      in_loop=loop_depth > 0 or
                                      self._in_loop(stmt, node))
                elif scope is not None and isinstance(node, ast.Return) \
                        and node.value is not None:
                    self._handle_return(mod, scope, node.value, env)
                elif isinstance(node, ast.Assign) and scope is not None:
                    self._handle_assign(mod, scope, node, env)

    @staticmethod
    def _in_loop(stmt: ast.stmt, node: ast.AST) -> bool:
        """Is ``node`` inside a loop contained in ``stmt``?"""
        for outer in ast.walk(stmt):
            if isinstance(outer, (ast.For, ast.While, ast.AsyncFor)):
                for inner in ast.walk(outer):
                    if inner is node:
                        return True
        return False

    def _handle_call(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                     call: ast.Call, env: Dict, in_loop: bool) -> None:
        name = self.dotted_name(mod, call.func)
        if name == "functools.partial" and call.args:
            inner = self.dotted_name(mod, call.args[0])
            if inner in TRACE_ENTRIES and len(call.args) > 1:
                self._record_jit_site(mod, scope, call, call.args[1],
                                      in_loop, env, entry=inner)
                return
        if name in TRACE_ENTRIES:
            idxs = TRACE_ENTRIES[name]
            for i in idxs:
                if i < len(call.args):
                    arg = call.args[i]
                    if name == "jax.lax.switch" and isinstance(
                            arg, (ast.List, ast.Tuple)):
                        for el in arg.elts:
                            self._record_jit_site(mod, scope, call, el,
                                                  in_loop, env,
                                                  entry=name)
                    else:
                        self._record_jit_site(mod, scope, call, arg,
                                              in_loop, env, entry=name)
            return
        # plain call: call-graph edge
        if scope is not None:
            for fid in self.resolve_callable(mod, scope, call.func,
                                             _env=env):
                scope.calls.add(fid)

    def _handle_assign(self, mod: ModuleInfo, scope: FuncInfo,
                       stmt: ast.Assign, env: Dict) -> None:
        """``v = factory(...)`` binds v to the factory's returned funcs
        so later ``v(...)`` / ``jax.jit(v)`` resolve."""
        if not isinstance(stmt.value, ast.Call):
            return
        cands = self.resolve_callable(mod, scope, stmt.value.func,
                                      _env=env)
        rets: Set[FuncId] = set()
        for fid in cands:
            fi = self.func(fid)
            if fi is not None:
                rets |= fi.returns_funcs
        if not rets:
            return
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name):
                env[tgt.id] = rets

    def _handle_return(self, mod: ModuleInfo, scope: FuncInfo,
                       value: ast.AST, env: Dict) -> None:
        if isinstance(value, ast.IfExp):
            self._handle_return(mod, scope, value.body, env)
            self._handle_return(mod, scope, value.orelse, env)
            return
        if isinstance(value, ast.Call):
            # return other_factory(...) -> union of its returns (fixpoint)
            for fid in self.resolve_callable(mod, scope, value.func,
                                             _env=env):
                scope.returns_funcs.add(("__factory__",) + fid)  # marker
            return
        for fid in self.resolve_callable(mod, scope, value, _env=env):
            scope.returns_funcs.add(fid)

    def _compute_returns_fixpoint(self) -> None:
        # expand ("__factory__", mod, qual) markers until stable
        changed = True
        while changed:
            changed = False
            for fi in self.all_functions():
                new: Set[FuncId] = set()
                for entry in fi.returns_funcs:
                    if len(entry) == 3 and entry[0] == "__factory__":
                        inner = self.func((entry[1], entry[2]))
                        if inner is not None:
                            new |= {e for e in inner.returns_funcs
                                    if len(e) == 2}
                            new |= {e for e in inner.returns_funcs
                                    if len(e) == 3}
                    else:
                        new.add(entry)
                if new != fi.returns_funcs:
                    fi.returns_funcs = new
                    changed = True
        for fi in self.all_functions():
            fi.returns_funcs = {e for e in fi.returns_funcs
                                if len(e) == 2}
            # a cache-getter that creates exactly one jit site and does
            # not return a plain local def is assumed to return that jit
            if fi.jit_sites and not fi.returns_funcs:
                jits = [s for s in fi.jit_sites if s.target is not None]
                if len(jits) == 1:
                    fi.returns_jit = jits

    # -- traced closure --------------------------------------------------------

    def _mark_traced(self) -> None:
        # re-run call/factory resolution now that returns_funcs are
        # known (assignments scanned before fixpoint missed some)
        for mod in self.modules.values():
            for fi in mod.functions.values():
                env: Dict = {}
                for node in shallow_walk(fi.node.body):
                    if isinstance(node, ast.Assign):
                        self._handle_assign(mod, fi, node, env)
                    elif isinstance(node, ast.Call):
                        if self.dotted_name(mod, node.func) not in \
                                TRACE_ENTRIES:
                            for fid in self.resolve_callable(
                                    mod, fi, node.func, _env=env):
                                fi.calls.add(fid)
        work = [fi for fi in self.all_functions() if fi.is_root]
        seen: Set[FuncId] = set()
        while work:
            fi = work.pop()
            if fi.fid in seen:
                continue
            if fi.fid[0] in TRACED_EXEMPT_MODULES:
                continue  # host-by-contract helpers (see constant above)
            seen.add(fi.fid)
            fi.traced = True
            for callee in list(fi.calls):
                cfi = self.func(callee)
                if cfi is not None and cfi.fid not in seen:
                    work.append(cfi)
                # calling a factory from traced code means its returned
                # closures run traced too
                if cfi is not None:
                    for rid in cfi.returns_funcs:
                        rfi = self.func(rid)
                        if rfi is not None and rfi.fid not in seen:
                            work.append(rfi)


# ---------------------------------------------------------------------------
# file loading
# ---------------------------------------------------------------------------

def load_index(root: str, paths: Sequence[str]) -> Index:
    """Index every ``.py`` under the given repo-relative paths."""
    sources: Dict[str, str] = {}
    for p in paths:
        absp = os.path.join(root, p)
        if os.path.isfile(absp) and absp.endswith(".py"):
            sources[os.path.relpath(absp, root)] = open(
                absp, encoding="utf-8").read()
        elif os.path.isdir(absp):
            for dirpath, _dirnames, filenames in os.walk(absp):
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        fp = os.path.join(dirpath, fn)
                        sources[os.path.relpath(fp, root)] = open(
                            fp, encoding="utf-8").read()
    return index_sources(sources)


def index_sources(sources: Dict[str, str]) -> Index:
    """Index an in-memory {repo-relative-path: source} mapping (the
    fixture entry point — rules tests feed synthetic trees here)."""
    modules: Dict[str, ModuleInfo] = {}
    for path, src in sorted(sources.items()):
        name = module_name_for(path)
        modules[name] = index_module(name, path, src)
    return Index(modules)
