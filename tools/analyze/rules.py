"""repro-lint rules R1–R4.

Each rule emits :class:`Finding` records with a *stable key*
(``rule:module:function:detail`` — no line numbers) so the checked-in
baseline survives unrelated edits.  Rationale text lives in ``RULES``
and is printed by ``python -m tools.analyze --explain R<n>``; the long
form is ``docs/static_analysis.md``.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .core import FuncId, FuncInfo, Index, JitSite, ModuleInfo

# ---------------------------------------------------------------------------
# rule metadata (--explain)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RuleDoc:
    rule_id: str
    title: str
    rationale: str
    doc_anchor: str


RULES: Dict[str, RuleDoc] = {
    "R1": RuleDoc(
        "R1", "host-sync on the hot path",
        "A `.item()` / `float()` / `np.asarray()` / tracer-bool branch "
        "inside traced code forces a device sync per call (on real "
        "accelerators: a blocking d2h copy), and in host-side hot-path "
        "modules a raw per-array pull forfeits the batched "
        "annotated_transfer() door the runtime guard allowlists. "
        "TreePO's amortized-prefix efficiency claim dies by a thousand "
        "of these.",
        "docs/static_analysis.md#r1-host-sync"),
    "R2": RuleDoc(
        "R2", "donation hygiene",
        "An update-style jit (takes `params` + `opt_state`) that does "
        "not donate them doubles peak parameter memory and forfeits "
        "buffer aliasing; reading a donated buffer after the call "
        "returns garbage. Donation is the contract PR 2 built the "
        "bucketed update around.",
        "docs/static_analysis.md#r2-donation-hygiene"),
    "R3": RuleDoc(
        "R3", "recompile hazards",
        "A jit created inside a loop, an unhashable static argument, a "
        "mutable Python container captured by a jit closure, or a "
        "shape-dependent Python branch in traced code each silently "
        "multiply compilations — the one-compile-per-(N,L,S)-bucket "
        "invariant the compile counter asserts at runtime.",
        "docs/static_analysis.md#r3-recompile-hazards"),
    "R4": RuleDoc(
        "R4", "kernel-surface parity",
        "Every kernel must expose the same logical signature across the "
        "Pallas implementation, the `ref.py` reference, and the "
        "`ops.py` dispatch (Pallas-only tuning knobs excepted). A "
        "desynced `segment_ids` is exactly the packing bug class PR 5 "
        "fixed by hand; this rule makes it unrepresentable.",
        "docs/static_analysis.md#r4-kernel-surface-parity"),
}


@dataclasses.dataclass
class Finding:
    rule: str
    module: str          # dotted module name
    path: str            # repo-relative file path
    lineno: int
    func: str            # qualified function name ("<module>" if top level)
    detail: str          # stable slug (baseline key component)
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.module}:{self.func}:{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.lineno}: {self.rule} [{self.func}] "
                f"{self.message}")


# modules whose *untraced* host code is still a hot path: raw transfer
# calls there must route through repro.core.guard.annotated_transfer
HOT_PATH_MODULES: Set[str] = {
    "repro.core.engine",
    "repro.core.scheduler",
    "repro.rl.trainer",
    "repro.kv.cache",
    "repro.kv.radix",
}

# attributes of device values that are concrete at trace time
STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "itemsize", "nbytes",
                "sharding", "weak_type", "aval"}

# calls that launder taint away (concrete results even on tracers)
UNTAINT_CALLS = {"len", "isinstance", "type", "id", "repr", "str",
                 "hash", "range", "getattr", "hasattr"}

# methods whose receiver is array-like — used as *evidence* that a
# value is an array (vs. a Python config flag that happens to be a
# parameter of traced code); R1 traced-half findings require evidence
ARRAY_METHODS = {"astype", "reshape", "transpose", "sum", "mean", "max",
                 "min", "any", "all", "item", "tolist", "squeeze",
                 "ravel", "flatten", "take", "dot", "clip", "argmax",
                 "argmin", "cumsum", "round", "std", "var", "prod",
                 "block_until_ready"}

# d2h sync entry points: canonical dotted callable names
D2H_CALLS = {"numpy.asarray", "numpy.array", "numpy.asanyarray",
             "jax.device_get"}
H2D_CALLS = {"jax.numpy.asarray", "jax.numpy.array", "jax.device_put"}
SYNC_BUILTINS = {"float", "int", "bool", "complex"}
SYNC_METHODS = {"item", "tolist", "block_until_ready"}

# params that only the Pallas side of a kernel may have (tuning knobs)
_PALLAS_ONLY_PREFIXES = ("blk", "block", "grid", "num_warps",
                        "num_stages", "num_buffers", "debug")


def _is_pallas_only(param: str) -> bool:
    return param == "interpret" or param.startswith(_PALLAS_ONLY_PREFIXES)


def _expr_slug(node: ast.AST) -> str:
    """Short stable description of an expression for baseline keys."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return f"{_expr_slug(node.value)}.{node.attr}"
    if isinstance(node, ast.Call):
        return _expr_slug(node.func)
    if isinstance(node, ast.Subscript):
        return f"{_expr_slug(node.value)}[]"
    if isinstance(node, ast.Constant):
        return repr(node.value)
    return type(node).__name__.lower()


# ---------------------------------------------------------------------------
# taint / device-value dataflow (shared by R1 traced + R1 host halves)
# ---------------------------------------------------------------------------

class TaintScan:
    """Forward dataflow over one function body: which local names hold
    tracer (traced half) or device-array (host half) values.

    ``seed`` taints parameters; ``call_taints(call)`` lets the host half
    declare "calls resolving to a jitted function return device values".
    Two forward passes approximate loop back-edges.
    """

    def __init__(self, index: Index, mod: ModuleInfo, fi: FuncInfo,
                 seed: Set[str],
                 call_taints: Optional[Callable[[ast.Call], bool]] = None):
        self.index = index
        self.mod = mod
        self.fi = fi
        self.tainted: Set[str] = set(seed)
        self.call_taints = call_taints or (lambda call: False)
        # evidence: slugs whose array-ness the function itself attests
        # (receiver of a shape/dtype access or an array method call)
        self.arrayish: Set[str] = set()
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Attribute) and (
                    node.attr in STATIC_ATTRS or
                    node.attr in ARRAY_METHODS):
                self.arrayish.add(_expr_slug(node.value))
        for _ in range(2):
            for stmt in fi.node.body:
                self._scan_stmt(stmt)

    def has_array_evidence(self, node: ast.AST) -> bool:
        """Does the expression (or any sub-expression) refer to a value
        this function demonstrably treats as an array — or call into
        jax/jnp/lax (whose results are arrays by construction)?"""
        for n in ast.walk(node):
            if isinstance(n, (ast.Name, ast.Attribute)) and \
                    _expr_slug(n) in self.arrayish:
                return True
            if isinstance(n, ast.Call):
                name = self.index.dotted_name(self.mod, n.func)
                if name and (name.startswith("jax.") or name == "jax"):
                    return True
        return False

    # -- expression taint ------------------------------------------------------

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            return self._call_tainted(node)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in node.ops):
                return False        # identity / membership on pytrees
            return self.is_tainted(node.left) or any(
                self.is_tainted(c) for c in node.comparators)
        if isinstance(node, (ast.BinOp,)):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.NamedExpr):
            return self.is_tainted(node.value)
        return False

    def _call_tainted(self, call: ast.Call) -> bool:
        name = self.index.dotted_name(self.mod, call.func)
        if name in UNTAINT_CALLS:
            return False
        if name and name.split(".")[-1] == "annotated_transfer":
            # the sanctioned door: its results are host values (or an
            # intended, tallied device push) — taint stops here
            return False
        if name in SYNC_BUILTINS or name in D2H_CALLS:
            return False        # result is host-side (the call gets flagged)
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr in SYNC_METHODS:
            return False
        if self.call_taints(call):
            return True
        # method on a tainted object, or any tainted argument
        if isinstance(call.func, ast.Attribute) and \
                self.is_tainted(call.func.value):
            return True
        return any(self.is_tainted(a) for a in call.args) or any(
            self.is_tainted(k.value) for k in call.keywords)

    # -- statement propagation -------------------------------------------------

    def _bind(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind(el, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)
        # attribute/subscript stores: no tracked name

    def _bind_for(self, target: ast.AST, it: ast.AST) -> None:
        # enumerate(xs): index untainted, element follows xs
        if isinstance(it, ast.Call):
            name = self.index.dotted_name(self.mod, it.func)
            if name == "enumerate" and it.args and \
                    isinstance(target, (ast.Tuple, ast.List)) and \
                    len(target.elts) == 2:
                self._bind(target.elts[0], False)
                self._bind(target.elts[1], self.is_tainted(it.args[0]))
                return
            if isinstance(it.func, ast.Attribute) and \
                    it.func.attr == "items" and \
                    isinstance(target, (ast.Tuple, ast.List)) and \
                    len(target.elts) == 2:
                self._bind(target.elts[0], False)   # dict key
                self._bind(target.elts[1],
                           self.is_tainted(it.func.value))
                return
            if name == "zip":
                t = any(self.is_tainted(a) for a in it.args)
                self._bind(target, t)
                return
        self._bind(target, self.is_tainted(it))

    def _bind_arrayish(self, target: ast.AST) -> None:
        if isinstance(target, (ast.Name, ast.Attribute)):
            self.arrayish.add(_expr_slug(target))
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind_arrayish(el)
        elif isinstance(target, ast.Starred):
            self._bind_arrayish(target.value)

    def _scan_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            t = self.is_tainted(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, t)
            if self.has_array_evidence(stmt.value):
                for tgt in stmt.targets:
                    self._bind_arrayish(tgt)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind(stmt.target, self.is_tainted(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            if self.is_tainted(stmt.value):
                self._bind(stmt.target, True)
        elif isinstance(stmt, ast.For):
            self._bind_for(stmt.target, stmt.iter)
            for s in stmt.body + stmt.orelse:
                self._scan_stmt(s)
        elif isinstance(stmt, (ast.While, ast.If)):
            for s in stmt.body + stmt.orelse:
                self._scan_stmt(s)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                if item.optional_vars is not None:
                    self._bind(item.optional_vars,
                               self.is_tainted(item.context_expr))
            for s in stmt.body:
                self._scan_stmt(s)
        elif isinstance(stmt, ast.Try):
            for s in stmt.body + stmt.orelse + stmt.finalbody:
                self._scan_stmt(s)
            for h in stmt.handlers:
                for s in h.body:
                    self._scan_stmt(s)
        # comprehension targets
        for node in ast.walk(stmt):
            if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)):
                for gen in node.generators:
                    self._bind_for(gen.target, gen.iter)


# ---------------------------------------------------------------------------
# R1 — host-sync
# ---------------------------------------------------------------------------

def _jit_result_call(index: Index, mod: ModuleInfo, fi: FuncInfo,
                     call: ast.Call, jit_vars: Set[str]) -> bool:
    """Does this call return device values? — a direct call to a jit
    root / traced fn, a call through a var bound to a cached jit
    (``fn = self._get_update_fn(...)``), or a call to a factory whose
    returns are jitted."""
    if isinstance(call.func, ast.Name) and call.func.id in jit_vars:
        return True
    for fid in index.resolve_callable(mod, fi, call.func):
        cfi = index.func(fid)
        if cfi is None:
            continue
        if cfi.is_root or cfi.traced:
            return True
        if cfi.returns_jit:
            return True
        for rid in cfi.returns_funcs:
            rfi = index.func(rid)
            if rfi is not None and (rfi.is_root or rfi.traced):
                return True
        # a thin wrapper that itself calls a jit root returns device
        # values (e.g. ``batch_treepo_advantage`` over its jitted core)
        for cid in cfi.calls:
            ccfi = index.func(cid)
            if ccfi is not None and ccfi.is_root:
                return True
    return False


def _collect_jit_vars(index: Index, mod: ModuleInfo, fi: FuncInfo
                      ) -> Set[str]:
    """Local names bound to jitted callables (``fn = self._get_X(...)``
    or ``fn = jax.jit(...)``)."""
    out: Set[str] = set()
    for stmt in ast.walk(fi.node):
        if not isinstance(stmt, ast.Assign) or \
                not isinstance(stmt.value, ast.Call):
            continue
        call = stmt.value
        is_jit = False
        name = index.dotted_name(mod, call.func)
        if name == "jax.jit":
            is_jit = True
        else:
            for fid in index.resolve_callable(mod, fi, call.func):
                cfi = index.func(fid)
                if cfi is not None and (cfi.returns_jit or any(
                        index.func(r) is not None and
                        index.func(r).is_root
                        for r in cfi.returns_funcs)):
                    is_jit = True
        if is_jit:
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
    return out


def _r1_check_function(index: Index, mod: ModuleInfo, fi: FuncInfo,
                       findings: List[Finding], *, traced: bool) -> None:
    if traced:
        # params with literal defaults are config flags by convention
        # (``causal=True``): call sites pass constants, not tracers
        seed = (set(fi.params) - fi.static_params - fi.literal_defaults
                - {"self", "cls"})
        scan = TaintScan(index, mod, fi, seed)
        kind = "traced"
    else:
        jit_vars = _collect_jit_vars(index, mod, fi)
        scan = TaintScan(
            index, mod, fi, set(),
            call_taints=lambda c: _jit_result_call(index, mod, fi, c,
                                                   jit_vars))
        kind = "hot-host"

    def emit(node: ast.AST, detail: str, msg: str) -> None:
        findings.append(Finding(
            rule="R1", module=mod.name, path=mod.path,
            lineno=getattr(node, "lineno", fi.node.lineno),
            func=fi.qualname, detail=detail, message=msg))

    own_nested = {f.node for f in mod.functions.values()
                  if f.parent == fi.qualname}

    def hot(node: ast.AST) -> bool:
        """Is this tainted expression actually array-like?  The traced
        half demands that some *single* subexpression is both tainted
        and array-evidenced (a `.shape` access / array method on it, or
        a jnp call over tainted args) so Python config scalars passed
        as parameters don't fire; the host half's taint (jit-call
        results) is already precise."""
        if not scan.is_tainted(node):
            return False
        if not traced:
            return True
        for n in ast.walk(node):
            if isinstance(n, (ast.Name, ast.Attribute)) and \
                    _expr_slug(n) in scan.arrayish and \
                    scan.is_tainted(n):
                return True
            if isinstance(n, ast.Call) and scan.is_tainted(n):
                cname = index.dotted_name(mod, n.func)
                if cname and cname.startswith("jax."):
                    return True
                if isinstance(n.func, ast.Attribute) and \
                        n.func.attr in ARRAY_METHODS and \
                        scan.is_tainted(n.func.value):
                    return True
        return False

    for stmt in fi.node.body:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node in own_nested:
                continue        # nested defs analyzed as their own fns
            if isinstance(node, ast.Call):
                cname = index.dotted_name(mod, node.func)
                arg0 = node.args[0] if node.args else None
                if cname in SYNC_BUILTINS and arg0 is not None and \
                        hot(arg0):
                    emit(node, f"sync-builtin:{cname}:{_expr_slug(arg0)}",
                         f"`{cname}()` on a "
                         f"{'traced value' if traced else 'device value'}"
                         f" `{_expr_slug(arg0)}` forces a host sync")
                elif cname in D2H_CALLS and arg0 is not None and \
                        hot(arg0):
                    emit(node, f"d2h:{cname}:{_expr_slug(arg0)}",
                         f"`{cname}()` pulls `{_expr_slug(arg0)}` to "
                         "host — batch it through "
                         "guard.annotated_transfer()")
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in SYNC_METHODS and \
                        scan.is_tainted(node.func.value):
                    # no evidence gate: calling .item()/.tolist() IS
                    # array evidence in itself
                    emit(node,
                         f"sync-method:{node.func.attr}:"
                         f"{_expr_slug(node.func.value)}",
                         f"`.{node.func.attr}()` on "
                         f"`{_expr_slug(node.func.value)}` forces a "
                         "host sync")
                elif not traced and cname in H2D_CALLS and \
                        mod.name in HOT_PATH_MODULES:
                    emit(node,
                         f"h2d:{cname}:"
                         f"{_expr_slug(arg0) if arg0 is not None else '?'}",
                         f"raw `{cname}()` ships host data to device on "
                         "a hot path — route through "
                         "guard.annotated_transfer(to='device')")
            elif traced and isinstance(node, (ast.If, ast.While)) and \
                    hot(node.test):
                emit(node, f"tracer-bool:{_expr_slug(node.test)}",
                     "branching on a traced value "
                     f"`{_expr_slug(node.test)}` forces a concretization "
                     "sync (use lax.cond / jnp.where)")
            elif traced and isinstance(node, ast.Assert) and \
                    hot(node.test):
                emit(node, f"tracer-assert:{_expr_slug(node.test)}",
                     "assert on a traced value forces a host sync "
                     "(use checkify or a debug callback)")
    del kind


def rule_r1(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules.values():
        for fi in mod.functions.values():
            if fi.traced:
                _r1_check_function(index, mod, fi, findings, traced=True)
            elif mod.name in HOT_PATH_MODULES:
                _r1_check_function(index, mod, fi, findings, traced=False)
    return findings


# ---------------------------------------------------------------------------
# R2 — donation hygiene
# ---------------------------------------------------------------------------

DONATABLE_PARAMS = ("params", "opt_state")
LOGPROB_PARAM_PREFIXES = ("lp", "logprob", "logp")


def _donated_names(site: JitSite, target: FuncInfo) -> Set[str]:
    names: Set[str] = set(site.donate_argnames or ())
    for i in site.donate_argnums or ():
        if isinstance(i, int) and i < len(target.params):
            names.add(target.params[i])
    return names


def rule_r2(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    # (a) update-style jits must donate params/opt_state/logprob planes
    for site in index.jit_sites:
        if site.target is None:
            continue
        fi = index.func(site.target)
        if fi is None or "opt_state" not in fi.params:
            continue    # donation contract applies to update-style jits
        donated = _donated_names(site, fi)
        mod = index.modules[site.module]
        for p in fi.params:
            is_plane = p.startswith(LOGPROB_PARAM_PREFIXES)
            if (p in DONATABLE_PARAMS or is_plane) and p not in donated:
                findings.append(Finding(
                    rule="R2", module=site.module, path=mod.path,
                    lineno=site.lineno,
                    func=site.in_function or "<module>",
                    detail=f"no-donate:{fi.qualname}:{p}",
                    message=f"jit of `{fi.qualname}` does not donate "
                            f"`{p}` — doubles live buffers "
                            "(add donate_argnums)"))
    # (b) use-after-donate
    for mod in index.modules.values():
        for fi in mod.functions.values():
            findings.extend(_use_after_donate(index, mod, fi))
    return findings


def _use_after_donate(index: Index, mod: ModuleInfo, fi: FuncInfo
                      ) -> List[Finding]:
    out: List[Finding] = []
    jit_vars: Dict[str, JitSite] = {}
    # bind local names to jit sites (direct or via cache getters)
    for stmt in ast.walk(fi.node):
        if not isinstance(stmt, ast.Assign) or \
                not isinstance(stmt.value, ast.Call):
            continue
        call = stmt.value
        site: Optional[JitSite] = None
        if index.dotted_name(mod, call.func) == "jax.jit":
            for s in fi.jit_sites:
                if s.call_node is call:
                    site = s
        else:
            for fid in index.resolve_callable(mod, fi, call.func):
                cfi = index.func(fid)
                if cfi is not None and cfi.returns_jit:
                    site = cfi.returns_jit[0]
        if site is not None and (site.donate_argnums or
                                 site.donate_argnames):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    jit_vars[tgt.id] = site
    if not jit_vars:
        return out
    # find calls through those names; mark donated positional args dead.
    # Only named buffers (Name / dotted attribute) can be used later —
    # temporaries built inline in the call can't be re-read.
    dead: List[Tuple[str, int, int]] = []   # (slug, call start, call end)
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Name) and \
                node.func.id in jit_vars:
            site = jit_vars[node.func.id]
            target = index.func(site.target) if site.target else None
            donated_idx = set(site.donate_argnums or ())
            donated_names = set(site.donate_argnames or ())
            end = getattr(node, "end_lineno", node.lineno) or node.lineno
            for i, a in enumerate(node.args):
                if not isinstance(a, (ast.Name, ast.Attribute)):
                    continue
                pname = target.params[i] if target and \
                    i < len(target.params) else None
                if i in donated_idx or (pname in donated_names):
                    dead.append((_expr_slug(a), node.lineno, end))
    for slug, call_line, call_end in dead:
        # a rebind anywhere from the donating statement on revives the
        # name (the idiomatic `self.params, ... = fn(self.params, ...)`
        # rebinds on the very statement that donates)
        def _flat_targets(n: ast.AST):
            tgts = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in tgts:
                if isinstance(t, (ast.Tuple, ast.List)):
                    yield from t.elts
                else:
                    yield t

        rebinds = [n.lineno for n in ast.walk(fi.node)
                   if isinstance(n, (ast.Assign, ast.AugAssign))
                   and any(_expr_slug(t) == slug
                           for t in _flat_targets(n))]
        for node in ast.walk(fi.node):
            if isinstance(node, (ast.Name, ast.Attribute)) and \
                    isinstance(getattr(node, "ctx", None), ast.Load) and \
                    _expr_slug(node) == slug and node.lineno > call_end:
                if any(call_line <= rb <= node.lineno for rb in rebinds):
                    continue    # re-bound between donation and use
                out.append(Finding(
                    rule="R2", module=mod.name, path=mod.path,
                    lineno=node.lineno, func=fi.qualname,
                    detail=f"use-after-donate:{slug}",
                    message=f"`{slug}` is read after being donated at "
                            f"line {call_line} — donated buffers are "
                            "invalidated"))
                break
    return out


# ---------------------------------------------------------------------------
# R3 — recompile hazards
# ---------------------------------------------------------------------------

def rule_r3(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    # (a) jit created inside a loop: fresh wrapper per iteration = no
    # cache.  Only cache-bearing wrappers count — jax.checkpoint /
    # vmap / grad inside a traced loop body are ordinary combinators.
    for site in index.jit_sites:
        if site.in_loop and site.entry in ("jax.jit", "jax.pjit"):
            mod = index.modules[site.module]
            tgt = site.target[1] if site.target else "<lambda>"
            findings.append(Finding(
                rule="R3", module=site.module, path=mod.path,
                lineno=site.lineno, func=site.in_function or "<module>",
                detail=f"jit-in-loop:{tgt}",
                message=f"jax.jit(`{tgt}`) created inside a loop — each "
                        "iteration makes a fresh wrapper with an empty "
                        "trace cache (hoist it or memoize per bucket)"))
    # (b) unhashable values passed for static args
    findings.extend(_r3_unhashable_statics(index))
    # (c) mutable containers / loop-rebound values captured by jit closures
    findings.extend(_r3_closure_capture(index))
    # (d) shape-dependent Python branches in traced code
    findings.extend(_r3_shape_branches(index))
    return findings


def _r3_unhashable_statics(index: Index) -> List[Finding]:
    out: List[Finding] = []
    for mod in index.modules.values():
        for fi in mod.functions.values():
            jit_vars: Dict[str, FuncInfo] = {}
            for stmt in ast.walk(fi.node):
                if isinstance(stmt, ast.Assign) and \
                        isinstance(stmt.value, ast.Call):
                    for fid in index.resolve_callable(
                            mod, fi, stmt.value.func):
                        cfi = index.func(fid)
                        if cfi is None:
                            continue
                        tfi = None
                        if cfi.returns_jit and cfi.returns_jit[0].target:
                            tfi = index.func(cfi.returns_jit[0].target)
                        elif cfi.is_root:
                            tfi = cfi
                        if tfi is not None and tfi.static_params:
                            for tgt in stmt.targets:
                                if isinstance(tgt, ast.Name):
                                    jit_vars[tgt.id] = tfi
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                tfi = None
                if isinstance(node.func, ast.Name) and \
                        node.func.id in jit_vars:
                    tfi = jit_vars[node.func.id]
                else:
                    for fid in index.resolve_callable(mod, fi, node.func):
                        cfi = index.func(fid)
                        if cfi is not None and cfi.is_root and \
                                cfi.static_params:
                            tfi = cfi
                if tfi is None:
                    continue
                for kw in node.keywords:
                    if kw.arg in tfi.static_params and isinstance(
                            kw.value, (ast.List, ast.Dict, ast.Set)):
                        out.append(Finding(
                            rule="R3", module=mod.name, path=mod.path,
                            lineno=node.lineno, func=fi.qualname,
                            detail=f"unhashable-static:{tfi.qualname}:"
                                   f"{kw.arg}",
                            message=f"static arg `{kw.arg}` of "
                                    f"`{tfi.qualname}` gets an unhashable "
                                    f"{type(kw.value).__name__.lower()} "
                                    "literal — jit statics must be "
                                    "hashable (use a tuple)"))
                for i, a in enumerate(node.args):
                    tp = tfi.params[i] if i < len(tfi.params) else None
                    if tp in tfi.static_params and isinstance(
                            a, (ast.List, ast.Dict, ast.Set)):
                        out.append(Finding(
                            rule="R3", module=mod.name, path=mod.path,
                            lineno=node.lineno, func=fi.qualname,
                            detail=f"unhashable-static:{tfi.qualname}:"
                                   f"{tp}",
                            message=f"static arg `{tp}` of "
                                    f"`{tfi.qualname}` gets an unhashable "
                                    f"{type(a).__name__.lower()} literal"))
    return out


def _r3_closure_capture(index: Index) -> List[Finding]:
    out: List[Finding] = []
    for mod in index.modules.values():
        for fi in mod.functions.values():
            if not fi.is_root or fi.parent is None:
                continue
            parent = mod.functions.get(fi.parent)
            if parent is None:
                continue
            bound = set(fi.params) | {"self", "cls"}
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.Assign,)):
                    for t in node.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                bound.add(n.id)
                elif isinstance(node, (ast.For,)):
                    for n in ast.walk(node.target):
                        if isinstance(n, ast.Name):
                            bound.add(n.id)
                elif isinstance(node, ast.comprehension):
                    for n in ast.walk(node.target):
                        if isinstance(n, ast.Name):
                            bound.add(n.id)
            free = {n.id for n in ast.walk(fi.node)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)
                    and n.id not in bound
                    and n.id not in mod.imports
                    and n.id not in mod.from_imports
                    and n.id not in mod.functions}
            # parent bindings of those free names
            for name in sorted(free):
                mutable_bind = None
                loop_rebind = None
                for stmt in ast.walk(parent.node):
                    if isinstance(stmt, ast.Assign):
                        for t in stmt.targets:
                            if isinstance(t, ast.Name) and t.id == name:
                                if isinstance(stmt.value,
                                              (ast.List, ast.Dict,
                                               ast.Set)):
                                    mutable_bind = stmt
                    if isinstance(stmt, (ast.For, ast.While)):
                        for inner in ast.walk(stmt):
                            if isinstance(inner, ast.Assign) and any(
                                    isinstance(t, ast.Name) and
                                    t.id == name
                                    for t in inner.targets):
                                loop_rebind = inner
                            if isinstance(inner, ast.For) and any(
                                    isinstance(n, ast.Name) and
                                    n.id == name
                                    for n in ast.walk(inner.target)):
                                loop_rebind = inner
                if mutable_bind is not None:
                    out.append(Finding(
                        rule="R3", module=mod.name, path=mod.path,
                        lineno=fi.node.lineno, func=fi.qualname,
                        detail=f"closure-mutable:{name}",
                        message=f"jitted closure captures mutable "
                                f"container `{name}` — mutations after "
                                "trace are silently ignored (pass it as "
                                "an argument or freeze it)"))
                if loop_rebind is not None:
                    out.append(Finding(
                        rule="R3", module=mod.name, path=mod.path,
                        lineno=fi.node.lineno, func=fi.qualname,
                        detail=f"closure-loop-rebind:{name}",
                        message=f"jitted closure captures `{name}` which "
                                "the enclosing function rebinds in a "
                                "loop — the jit traces the first value "
                                "only (pass it as an argument)"))
    return out


def _r3_shape_branches(index: Index) -> List[Finding]:
    out: List[Finding] = []
    shape_attrs = {"shape", "ndim", "size"}
    for mod in index.modules.values():
        for fi in mod.functions.values():
            if not fi.traced:
                continue
            own_nested = {f.node for f in mod.functions.values()
                          if f.parent == fi.qualname}
            for stmt in fi.node.body:
                for node in ast.walk(stmt):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) and \
                            node in own_nested:
                        continue
                    if not isinstance(node, ast.If):
                        continue
                    hits = [n for n in ast.walk(node.test)
                            if isinstance(n, ast.Attribute)
                            and n.attr in shape_attrs]
                    if hits:
                        slug = _expr_slug(hits[0])
                        out.append(Finding(
                            rule="R3", module=mod.name, path=mod.path,
                            lineno=node.lineno, func=fi.qualname,
                            detail=f"shape-branch:{slug}",
                            message=f"Python branch on `{slug}` in "
                                    "traced code specializes the trace "
                                    "per shape — intentional dispatch "
                                    "belongs in the baseline, anything "
                                    "else in bucketing"))
    return out


# ---------------------------------------------------------------------------
# R4 — kernel-surface parity
# ---------------------------------------------------------------------------

def _kernel_pairs(index: Index, ops_mod: ModuleInfo
                  ) -> List[Tuple[FuncInfo, Optional[FuncInfo],
                                  Optional[FuncInfo]]]:
    """(ops dispatch fn, pallas kernel, ref kernel) triples, pairing
    derived from the dispatch body itself (so the
    ``flash_attention_pallas`` / ``attention_ref`` naming split is
    handled by construction)."""
    triples = []
    for fi in ops_mod.functions.values():
        if "." in fi.qualname or fi.qualname.startswith("_"):
            continue
        pallas: Optional[FuncInfo] = None
        ref: Optional[FuncInfo] = None
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            for fid in index.resolve_callable(ops_mod, fi, node.func):
                cfi = index.func(fid)
                if cfi is None:
                    continue
                if cfi.qualname.endswith("_pallas"):
                    pallas = cfi
                elif cfi.qualname.endswith("_ref"):
                    ref = cfi
        if pallas is not None or ref is not None:
            triples.append((fi, pallas, ref))
    return triples


def rule_r4(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    ops_mods = [m for m in index.modules.values()
                if m.name.endswith("kernels.ops")]
    for ops_mod in ops_mods:
        for disp, pallas, ref in _kernel_pairs(index, ops_mod):
            if pallas is None or ref is None:
                continue    # ref-only op (e.g. decode_attention): fine
            p_set = {p for p in pallas.params if not _is_pallas_only(p)}
            r_set = {p for p in ref.params if not _is_pallas_only(p)}
            for missing in sorted(r_set - p_set):
                findings.append(Finding(
                    rule="R4", module=ops_mod.name, path=ops_mod.path,
                    lineno=disp.node.lineno, func=disp.qualname,
                    detail=f"pallas-missing:{pallas.qualname}:{missing}",
                    message=f"`{ref.qualname}` accepts `{missing}` but "
                            f"`{pallas.qualname}` does not — kernel "
                            "surfaces drifted (the PR-5 bug class)"))
            for extra in sorted(p_set - r_set):
                findings.append(Finding(
                    rule="R4", module=ops_mod.name, path=ops_mod.path,
                    lineno=disp.node.lineno, func=disp.qualname,
                    detail=f"ref-missing:{ref.qualname}:{extra}",
                    message=f"`{pallas.qualname}` accepts `{extra}` but "
                            f"`{ref.qualname}` does not — reference "
                            "must cover the full kernel surface"))
            # the dispatch itself must plumb segment_ids when kernels do
            if "segment_ids" in (p_set & r_set) and \
                    "segment_ids" not in disp.params:
                findings.append(Finding(
                    rule="R4", module=ops_mod.name, path=ops_mod.path,
                    lineno=disp.node.lineno, func=disp.qualname,
                    detail=f"dispatch-missing:{disp.qualname}:segment_ids",
                    message=f"both kernels take `segment_ids` but the "
                            f"`{disp.qualname}` dispatch does not expose "
                            "it — packed sequences silently lose "
                            "segment resets"))
    return findings


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

ALL_RULES: Sequence[Callable[[Index], List[Finding]]] = (
    rule_r1, rule_r2, rule_r3, rule_r4)


def run_rules(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for rule in ALL_RULES:
        findings.extend(rule(index))
    findings.sort(key=lambda f: (f.path, f.lineno, f.rule, f.detail))
    return findings


# ---------------------------------------------------------------------------
# verification rules (R5-R8) compose onto the lint rules; see verify.py
# ---------------------------------------------------------------------------

from .verify import VERIFY_DOCS, VERIFY_RULES  # noqa: E402

RULES.update(VERIFY_DOCS)
ALL_RULES = tuple(ALL_RULES) + tuple(VERIFY_RULES)
