"""repro-lint: the repo-specific hot-path static analyzer.

Usage::

    python -m tools.analyze src/repro          # CI entry (baseline-gated)
    python -m tools.analyze --explain R1       # rule rationale + doc anchor
    python -m tools.analyze --write-baseline   # regenerate the ledger

Rules (see ``docs/static_analysis.md``): R1 host-sync, R2 donation
hygiene, R3 recompile hazards, R4 kernel-surface parity.  The runtime
half of the enforcement layer is ``repro.core.guard``.
"""
from .baseline import (DEFAULT_BASELINE, apply_baseline, load_baseline,
                       write_baseline)
from .core import Index, index_sources, load_index
from .rules import RULES, Finding, run_rules

__all__ = [
    "Index", "index_sources", "load_index",
    "Finding", "RULES", "run_rules",
    "DEFAULT_BASELINE", "load_baseline", "write_baseline",
    "apply_baseline",
    "analyze_paths", "analyze_sources",
]


def analyze_sources(sources):
    """Run all rules over {repo-relative-path: source} (fixture entry)."""
    return run_rules(index_sources(sources))


def analyze_paths(root, paths):
    """Run all rules over files/dirs under ``root``."""
    return run_rules(load_index(root, list(paths)))
