"""CLI for repro-lint: ``python -m tools.analyze [paths...]``.

Exit status: 0 = clean (every finding baselined, no stale entries),
1 = new findings and/or stale baseline entries, 2 = usage error.
"""
from __future__ import annotations

import argparse
import difflib
import os
import subprocess
import sys
from typing import List, Set

from . import (DEFAULT_BASELINE, analyze_paths, apply_baseline,
               load_baseline, write_baseline)
from .rules import RULES


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _explain(rule_id: str) -> int:
    doc = RULES.get(rule_id.upper())
    if doc is None:
        print(f"unknown rule {rule_id!r}; known: "
              f"{', '.join(sorted(RULES))}", file=sys.stderr)
        return 2
    print(f"{doc.rule_id} — {doc.title}\n")
    print(doc.rationale)
    print(f"\nSee: {doc.doc_anchor}")
    return 0


def _changed_files(root: str) -> Set[str]:
    """Repo-relative paths touched vs HEAD, plus untracked files."""
    changed: Set[str] = set()
    for cmd in (["git", "diff", "--name-only", "HEAD"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            out = subprocess.run(cmd, cwd=root, capture_output=True,
                                 text=True, check=True).stdout
        except (OSError, subprocess.CalledProcessError) as e:
            print(f"--changed-only: {' '.join(cmd)} failed ({e}); "
                  "analyzing everything", file=sys.stderr)
            return set()
        changed.update(line.strip() for line in out.splitlines()
                       if line.strip())
    return changed


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="repro-lint: hot-path static analyzer + verifier "
                    "(R1 host-sync, R2 donation, R3 recompile, R4 kernel "
                    "parity, R5 KV lifecycle, R6 path FSM, R7 RNG "
                    "discipline, R8 sharding specs)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to analyze (default: src/repro)")
    ap.add_argument("--root", default=None,
                    help="tree root the paths are relative to "
                         "(default: this repo's root)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON (default: tools/analyze/"
                         "baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignore the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--explain", metavar="RULE_ID",
                    help="print a rule's rationale and doc anchor")
    ap.add_argument("--list-rules", action="store_true",
                    help="list rule ids and titles")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="finding output format; 'github' emits workflow "
                         "::error annotations")
    ap.add_argument("--changed-only", action="store_true",
                    help="report findings only in files changed vs HEAD "
                         "(plus untracked); stale-baseline detection is "
                         "skipped — unchanged files aren't analyzed, so "
                         "their entries can't be confirmed live")
    args = ap.parse_args(argv)

    if args.explain:
        return _explain(args.explain)
    if args.list_rules:
        for doc in RULES.values():
            print(f"{doc.rule_id}  {doc.title}")
        return 0

    root = os.path.abspath(args.root) if args.root else _repo_root()
    paths = args.paths or ["src/repro"]
    findings = analyze_paths(root, paths)

    if args.write_baseline:
        prev = load_baseline(args.baseline)
        write_baseline(args.baseline, findings, prev)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new, stale = apply_baseline(findings, baseline)
    live_keys = sorted({f.key for f in findings})

    if args.changed_only:
        changed = _changed_files(root)
        if changed:
            new = [f for f in new if f.path in changed]
        # a full-tree index was still built (cross-module rules need
        # it); only the *reporting* narrows to the diff
        stale = []

    for f in new:
        if args.format == "github":
            title = RULES[f.rule].title
            print(f"::error file={f.path},line={f.lineno},"
                  f"title={f.rule} {title}::{f.message}")
        else:
            print(f.render())
    if new and args.format == "text":
        rules_hit = sorted({f.rule for f in new})
        print(f"\n{len(new)} new finding(s) "
              f"[{', '.join(rules_hit)}] — run "
              f"`python -m tools.analyze --explain <rule>` for rationale,"
              " fix or (justified) add to the baseline with"
              " --write-baseline")
    if stale:
        print(f"\n{len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (violation fixed but"
              " still listed — regenerate with --write-baseline):")
        for k in stale:
            print(f"  {k}")
            near = difflib.get_close_matches(k, live_keys, n=1, cutoff=0.6)
            if near:
                print(f"    nearest live finding: {near[0]}")
        if args.format == "github":
            for k in stale:
                print(f"::error title=repro-lint stale baseline::{k} has "
                      "no matching finding — regenerate with "
                      "--write-baseline")
    if not new and not stale:
        print(f"repro-lint: clean ({len(findings)} baselined finding(s),"
              f" {len(RULES)} rules)")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
