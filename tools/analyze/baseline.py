"""Baseline file handling for repro-lint.

The baseline is the *accepted-findings ledger*: findings whose keys
appear in it are known and intentional (e.g. the legacy un-donated
update jit kept as a parity oracle, shape-dispatch branches in
``kernels/ops.py`` that bucketing makes deliberate).  Two failure
modes are symmetric and both fatal:

* a finding NOT in the baseline → new violation, fix it or (with a
  written justification) ``--write-baseline``;
* a baseline entry with NO matching finding → stale entry, the
  violation was fixed but the ledger lies — regenerate it.

Keys carry no line numbers, so unrelated edits don't churn the file.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence, Tuple

from .rules import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "baseline.json")


def load_baseline(path: str) -> Dict[str, str]:
    """key -> justification. Missing file = empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path}: unsupported version {data.get('version')!r}"
            f" (expected {BASELINE_VERSION})")
    entries = data.get("entries")
    if not isinstance(entries, dict) or not all(
            isinstance(k, str) and isinstance(v, str)
            for k, v in entries.items()):
        raise ValueError(f"baseline {path}: 'entries' must map "
                         "finding keys to justification strings")
    return dict(entries)


def write_baseline(path: str, findings: Sequence[Finding],
                   previous: Dict[str, str]) -> None:
    """Regenerate the baseline from current findings, keeping the
    justification text of entries that survive."""
    entries = {
        f.key: previous.get(f.key, f.message)
        for f in findings
    }
    data = {"version": BASELINE_VERSION,
            "entries": dict(sorted(entries.items()))}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=False)
        f.write("\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Dict[str, str]
                   ) -> Tuple[List[Finding], List[str]]:
    """Split findings into (new, stale-baseline-keys)."""
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    stale = sorted(k for k in baseline if k not in keys)
    return new, stale
