"""repro-verify: lifecycle & state-machine verification rules (R5–R8).

Where the lint rules (R1–R4, ``rules.py``) flag *placement* mistakes —
a host sync inside a traced region, a jit without donation — these
rules verify *orderings* over the intraprocedural CFG (``cfg.py``):

* **R5 kv-lifecycle** — every ``PagePool`` / ``SlotAllocator`` /
  ``fork_table`` acquisition must reach a release or an ownership
  transfer (publication) on every exit, *including the exception exit*
  an ``OutOfPages`` raise or a fault-injection kill point takes;
  double-release and mutate-after-release are flagged; COW
  subscript-stores must be paired with a release of the displaced page.
* **R6 path-fsm** — every path-lifecycle mutation site (release /
  preempt / restore / branch / finish / status flips) must appear in
  the declared transition table ``FSM_TRANSITIONS``; illegal orderings
  (double ``release_path``, branching a preempted path, decoding a
  released one) are flagged from the CFG.
* **R7 rng-discipline** — a JAX PRNG key consumed twice without an
  interleaving ``split`` breaks fault-replay determinism; so does
  splitting and dropping the result, and host-RNG seeding outside the
  trainer's checkpoint-captured state.
* **R8 sharding-specs** — ``PartitionSpec`` axis names must be axes of
  a declared mesh, and ``donate_argnums`` must index into the
  ``in_shardings`` tuple they ride with.

The dataflow is a *may*-analysis over per-name state **sets** (merge =
union), so a name can simultaneously be "held on the else path" and
"released on the then path"; leak checks require ``H`` present and no
publication, which keeps the classic optimistic/pessimistic merge
trade-off honest.  Publication (``P``) means ownership left the
function: the value was returned, stored into a container/field, or
passed to another function — interprocedural lifetime is the runtime
twin's job (``repro.core.lifecycle``).

Deliberate scope limits (documented, stable):

* Only plain local names (and, for R6, ``name.attr`` slugs) are
  tracked; ``self.x`` fields and subscripted cells are publication
  sinks, not tracked resources.
* R5 "use-after-release" means a *consuming* use — re-growing,
  re-allocating into, or mutating a released resource.  Plain reads of
  a released page id stay legal: the COW idiom releases the source's
  refcount and then reads its id for the batched device copy.
* R7 tracks canonical ``jax.random.*`` producers/consumers only; keys
  threaded through local helpers are the runtime twin's problem.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, \
    Set, Tuple

from .cfg import CFG, build_cfg
from .core import FuncInfo, Index, ModuleInfo
from .rules import Finding, RuleDoc, _expr_slug

__all__ = ["VERIFY_DOCS", "VERIFY_RULES", "FSM_TRANSITIONS"]

VERIFY_DOCS: Dict[str, RuleDoc] = {
    "R5": RuleDoc(
        rule_id="R5",
        title="KV page/slot lifecycle",
        rationale=(
            "Tree rollouts share KV pages copy-on-write; a page acquired "
            "on a path that raises (OutOfPages, fault kill points) and "
            "never released leaks pool capacity until the engine dies — "
            "exactly under the KV pressure that triggers those raises. "
            "Every acquisition must reach a release or an ownership "
            "transfer on all CFG exits, including the exception exit; "
            "double-release and mutate-after-release corrupt refcounts "
            "or the slot free list silently."),
        doc_anchor="docs/static_analysis.md#r5-kv-lifecycle",
    ),
    "R6": RuleDoc(
        rule_id="R6",
        title="path-FSM conformance",
        rationale=(
            "The path lifecycle (active → branched/released/preempted/"
            "restored/finished/FAILED) is a state machine spread over "
            "five modules; an undeclared mutation site — restoring a "
            "released leaf, double release_path, branching a preempted "
            "path — corrupts rollouts in ways only visible as wrong "
            "advantages much later.  Every mutation site must be in the "
            "declared transition table FSM_TRANSITIONS, and illegal "
            "orderings within a function fail the build."),
        doc_anchor="docs/static_analysis.md#r6-path-fsm",
    ),
    "R7": RuleDoc(
        rule_id="R7",
        title="PRNG-key discipline",
        rationale=(
            "Fault determinism and crash-safe resume replay the exact "
            "RNG stream; a JAX key consumed twice without split silently "
            "correlates draws, a split whose result is dropped desyncs "
            "the stream across resume, and host-RNG seeded outside the "
            "trainer's checkpoint-captured generators diverges on "
            "restore.  All three are statically visible."),
        doc_anchor="docs/static_analysis.md#r7-rng-discipline",
    ),
    "R8": RuleDoc(
        rule_id="R8",
        title="sharding-spec consistency",
        rationale=(
            "PartitionSpec axis names are stringly-typed: an axis that "
            "is not in the declared mesh only fails at dispatch time on "
            "a real multi-device mesh, which CI never has.  Axis names "
            "and donate_argnums/in_shardings arity are checkable "
            "statically against the jax.make_mesh declarations."),
        doc_anchor="docs/static_analysis.md#r8-sharding-specs",
    ),
}


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

_OPAQUE = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _nodes(stmt: ast.stmt) -> Iterable[ast.AST]:
    """All AST nodes of one statement; opaque nested defs yield nothing
    (they are analyzed as their own functions)."""
    if isinstance(stmt, _OPAQUE):
        return
    yield from ast.walk(stmt)


def _calls(stmt: ast.stmt) -> Iterable[ast.Call]:
    for n in _nodes(stmt):
        if isinstance(n, ast.Call):
            yield n


def _tail(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _arg_names(call: ast.Call) -> Iterable[str]:
    """Plain-Name arguments, walking into list/tuple literals."""
    todo = list(call.args) + [kw.value for kw in call.keywords]
    while todo:
        a = todo.pop()
        if isinstance(a, ast.Name):
            yield a.id
        elif isinstance(a, (ast.List, ast.Tuple)):
            todo.extend(a.elts)
        elif isinstance(a, ast.Starred):
            todo.append(a.value)


def _fn_stmts(fn: FuncInfo) -> Iterable[ast.stmt]:
    """Shallow statement walk of a function body (no nested defs)."""
    todo = list(fn.node.body)
    while todo:
        s = todo.pop()
        if isinstance(s, _OPAQUE):
            continue
        yield s
        for fld in ("body", "orelse", "finalbody"):
            todo.extend(getattr(s, fld, []) or [])
        for h in getattr(s, "handlers", []) or []:
            todo.extend(h.body)


def _join(old: Optional[Dict[str, FrozenSet[str]]],
          new: Dict[str, FrozenSet[str]]) -> Dict[str, FrozenSet[str]]:
    """May-merge: per-name union of state sets."""
    if old is None:
        return dict(new)
    out = dict(old)
    for k, v in new.items():
        cur = out.get(k)
        out[k] = v if cur is None else (cur | v)
    return out


def _dataflow(cfg: CFG, step, entry_state=None
              ) -> Dict[int, Optional[Dict[str, FrozenSet[str]]]]:
    """Fixpoint over the CFG.  ``step(block, in_state) -> (out, exc)``;
    the ``exc`` state feeds "exc" edges of raising blocks (it carries
    the state *before* the isolated raising statement)."""
    in_map: Dict[int, Optional[Dict[str, FrozenSet[str]]]] = {
        bid: None for bid in cfg.blocks}
    in_map[cfg.entry] = dict(entry_state or {})
    order = cfg.rpo()
    for _ in range(64):
        changed = False
        for bid in order:
            st = in_map[bid]
            if st is None:
                continue
            out, exc = step(cfg.blocks[bid], dict(st))
            for succ, kind in cfg.blocks[bid].succs:
                nxt = exc if (kind == "exc" and cfg.blocks[bid].raises) \
                    else out
                merged = _join(in_map[succ], nxt)
                if merged != in_map[succ]:
                    in_map[succ] = merged
                    changed = True
        if not changed:
            break
    return in_map


# ---------------------------------------------------------------------------
# R5: KV page / slot lifecycle
# ---------------------------------------------------------------------------

# low-level acquisition tails: pool/slot allocators and the refcounting
# table fork.  Engine-level entry points (fork_paths, restore_path, ...)
# are the *verified* surface, not re-modeled at their call sites — the
# sampler-level lifecycle is R6's domain.
ALLOC_TAILS = {"alloc", "_alloc_page", "_alloc_slot", "fork_table"}
# calls that acquire pages *into* their first argument and may raise
# mid-way (the partial growth is visible on the exception path too)
GROW_TAILS = {"_ensure_capacity", "_cow_pages", "_replay_prefix",
              "_fork_from_prefix_arm"}
RELEASE_TAILS = {"release", "release_table", "release_path",
                 "release_qslot", "release_partial", "preempt_path"}

_R5_ALL_TAILS = ALLOC_TAILS | GROW_TAILS | RELEASE_TAILS

_H, _R, _P = "H", "R", "P"          # held / released / published


def _has_alloc_call(expr: ast.AST) -> bool:
    for n in ast.walk(expr):
        if isinstance(n, ast.Call) and _tail(n) in ALLOC_TAILS:
            return True
    return False


class _R5Pre:
    """Flow-insensitive prepass: which names are containers of acquired
    resources, which are published-at-birth via append, where each name
    was first acquired (for leak linenos)."""

    def __init__(self, fn: FuncInfo):
        self.ever_alloc: Set[str] = set()
        self.appended: Set[str] = set()      # names pushed into containers
        self.containers: Set[str] = set()
        self.local_ctor: Set[str] = set()    # bound from a constructor call
        self.alloc_lineno: Dict[str, int] = {}
        appends: List[Tuple[str, str]] = []  # (container, member)
        sub_stored: Set[str] = set()         # published into a cell
        for stmt in _fn_stmts(fn):
            tgt = None
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt, val = stmt.targets[0], stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                tgt, val = stmt.target, stmt.value
            else:
                tgt, val = None, None
            if isinstance(tgt, ast.Name) and val is not None:
                if _has_alloc_call(val):
                    self.ever_alloc.add(tgt.id)
                    self.alloc_lineno.setdefault(tgt.id, stmt.lineno)
                if isinstance(val, ast.Call):
                    self.local_ctor.add(tgt.id)
            if isinstance(tgt, ast.Subscript) and isinstance(val, ast.Name):
                sub_stored.add(val.id)
            for call in _calls(stmt):
                t = _tail(call)
                if t in GROW_TAILS and call.args \
                        and isinstance(call.args[0], ast.Name):
                    self.ever_alloc.add(call.args[0].id)
                    self.alloc_lineno.setdefault(call.args[0].id,
                                                 stmt.lineno)
                if t in ("append", "extend") \
                        and isinstance(call.func, ast.Attribute) \
                        and isinstance(call.func.value, ast.Name) \
                        and call.args \
                        and isinstance(call.args[0], ast.Name):
                    appends.append((call.func.value.id, call.args[0].id))
        for cont, member in appends:
            # a member that is also stored into some other container's
            # cell (the COW copy-pair manifests) is owned there, not by
            # the list it is *recorded* in
            if member in self.ever_alloc and member not in sub_stored:
                self.containers.add(cont)
                self.appended.add(member)
                self.alloc_lineno.setdefault(
                    cont, self.alloc_lineno.get(member, fn.node.lineno))


def _r5_function(fn: FuncInfo, mod: ModuleInfo,
                 findings: List[Finding]) -> None:
    pre = _R5Pre(fn)
    tracked_alloc = pre.ever_alloc - pre.appended

    def may_raise(stmt: ast.stmt) -> bool:
        return any(_tail(c) in ALLOC_TAILS or _tail(c) in GROW_TAILS
                   for c in _calls(stmt))

    cfg = build_cfg(fn.node, may_raise)
    seen: Set[str] = set()

    def report(detail: str, lineno: int, message: str) -> None:
        if detail in seen:
            return
        seen.add(detail)
        findings.append(Finding(
            rule="R5", module=mod.name, path=mod.path, lineno=lineno,
            func=fn.qualname, detail=detail, message=message))

    def release_one(name: str, st, lineno: int, reporting: bool) -> None:
        cur = st.get(name, frozenset())
        if reporting and _R in cur:
            report(f"double-release:{name}", lineno,
                   f"`{name}` may already be released here — a second "
                   "release corrupts the refcount / free list")
        st[name] = frozenset({_R})

    def publish(st, name: str) -> None:
        cur = st.get(name)
        if cur and _H in cur:
            st[name] = (cur - {_H}) | {_P}

    def consuming_use(name: str, st, lineno: int, reporting: bool,
                      what: str) -> None:
        if reporting and _R in st.get(name, frozenset()):
            report(f"use-after-release:{name}", lineno,
                   f"`{name}` may be released here but is {what} — "
                   "released resources must not be mutated or re-grown")

    def apply_stmt(stmt: ast.stmt, st, reporting: bool) -> None:
        # call effects, in source order
        for call in _calls(stmt):
            t = _tail(call)
            if t in RELEASE_TAILS:
                for a in list(call.args) + [k.value for k in call.keywords]:
                    todo = [a]
                    while todo:
                        x = todo.pop()
                        if isinstance(x, ast.Name):
                            release_one(x.id, st, stmt.lineno, reporting)
                        elif isinstance(x, (ast.List, ast.Tuple)):
                            todo.extend(x.elts)
                        elif isinstance(x, ast.Attribute) \
                                and isinstance(x.value, ast.Name) \
                                and _H in st.get(x.value.id, frozenset()):
                            release_one(x.value.id, st, stmt.lineno,
                                        reporting)
            elif t in GROW_TAILS:
                if call.args and isinstance(call.args[0], ast.Name):
                    n = call.args[0].id
                    consuming_use(n, st, stmt.lineno, reporting,
                                  f"grown by `{t}`")
                    # growing only transfers ownership to *locally
                    # constructed* objects; growing a caller-owned path
                    # (decode over `paths`) stays the caller's lifetime
                    if n not in pre.appended and n not in st \
                            and n in pre.local_ctor:
                        st[n] = frozenset({_H})
            elif t in ALLOC_TAILS:
                pass                     # handled at the binding
            else:
                for n in _arg_names(call):
                    publish(st, n)
                for a in call.args:
                    if isinstance(a, ast.Attribute) \
                            and isinstance(a.value, ast.Name):
                        publish(st, a.value.id)
        # bindings
        tgt = val = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt, val = stmt.targets[0], stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            tgt, val = stmt.target, stmt.value
        elif isinstance(stmt, ast.Assign):
            for t_ in stmt.targets:
                for n in ast.walk(t_):
                    if isinstance(n, ast.Name):
                        st.pop(n.id, None)
        if tgt is not None:
            if isinstance(tgt, ast.Name):
                n = tgt.id
                if _has_alloc_call(val) and n not in pre.appended:
                    st[n] = frozenset({_H})
                elif n in pre.containers:
                    st[n] = frozenset({_H})
                else:
                    # NB: a Name-to-Name copy (incl. the synthetic
                    # for-loop binding) deliberately does NOT transfer
                    # ownership — iterating a held container must not
                    # double-count its members
                    st.pop(n, None)
            elif isinstance(tgt, ast.Attribute):
                if isinstance(tgt.value, ast.Name):
                    consuming_use(tgt.value.id, st, stmt.lineno, reporting,
                                  "mutated (attribute store)")
                # storing an acquisition into obj.attr publishes it into
                # the object (self fields / path.slot); the object's own
                # lifetime covers it
            elif isinstance(tgt, ast.Subscript):
                if isinstance(val, ast.Name):
                    publish(st, val.id)   # stored into a container cell
                if isinstance(tgt.value, ast.Name):
                    consuming_use(tgt.value.id, st, stmt.lineno, reporting,
                                  "mutated (subscript store)")
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name):
                        st.pop(n.id, None)
        # returning publishes
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            for n in ast.walk(stmt.value):
                if isinstance(n, ast.Name):
                    publish(st, n.id)

    def step(block, st, reporting=False):
        exc = dict(st)
        if block.raises and block.stmts:
            # partial growth is visible to the exception path
            for call in _calls(block.stmts[0]):
                if _tail(call) in GROW_TAILS and call.args \
                        and isinstance(call.args[0], ast.Name):
                    n = call.args[0].id
                    if n not in pre.appended and n not in exc \
                            and n in pre.local_ctor:
                        exc[n] = frozenset({_H})
        for s in block.stmts:
            apply_stmt(s, st, reporting)
        return st, exc

    in_map = _dataflow(cfg, step)

    # reporting sweep: re-run each reachable block once with checks on,
    # and check leaks on edges into the exits
    for bid, st in in_map.items():
        if st is None:
            continue
        blk = cfg.blocks[bid]
        out, exc = step(blk, dict(st), reporting=True)
        for succ, kind in blk.succs:
            is_exc = kind == "exc" and blk.raises
            state = exc if is_exc else out
            if succ == cfg.exit or succ == cfg.raise_exit:
                suffix = "-on-raise" if succ == cfg.raise_exit else ""
                for name, s in sorted(state.items()):
                    if _H in s and _P not in s:
                        lineno = (blk.stmts[0].lineno if is_exc and
                                  blk.stmts else
                                  pre.alloc_lineno.get(name,
                                                       fn.node.lineno))
                        where = ("the exception path" if suffix
                                 else "a normal exit")
                        report(f"leak{suffix}:{name}", lineno,
                               f"`{name}` holds pages/slots that never "
                               f"reach a release on {where} — KV pool "
                               "capacity leaks exactly under the "
                               "OutOfPages pressure that raises here")

    # COW conservation: a subscript store of an acquisition into a table
    # must be paired with a release of the page it displaces
    alloc_stores: List[Tuple[str, int]] = []
    sub_loads: Dict[str, Set[str]] = {}
    released_names: Set[str] = set()
    for stmt in _fn_stmts(fn):
        tgt = val = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt, val = stmt.targets[0], stmt.value
        if tgt is None:
            continue
        if isinstance(tgt, ast.Subscript) and (
                (isinstance(val, ast.Name) and val.id in pre.ever_alloc)
                or _has_alloc_call(val)):
            alloc_stores.append((_expr_slug(tgt.value), stmt.lineno))
        if isinstance(tgt, ast.Name) and isinstance(val, ast.Subscript):
            sub_loads.setdefault(_expr_slug(val.value),
                                 set()).add(tgt.id)
    for stmt in _fn_stmts(fn):
        for call in _calls(stmt):
            if _tail(call) in RELEASE_TAILS:
                released_names.update(_arg_names(call))
    for slug, lineno in alloc_stores:
        if not (sub_loads.get(slug, set()) & released_names):
            report(f"cow-no-release:{slug}", lineno,
                   f"a fresh page is stored into `{slug}[...]` but no "
                   "page loaded from it is ever released — the displaced "
                   "COW source keeps its refcount forever")


def rule_r5(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for fn in index.all_functions():
        mod = index.modules[fn.module]
        if any(_tail(c) in _R5_ALL_TAILS
               for s in _fn_stmts(fn) for c in _calls(s)):
            _r5_function(fn, mod, findings)
    return findings


# ---------------------------------------------------------------------------
# R6: path-FSM conformance
# ---------------------------------------------------------------------------

# call tails that are FSM transitions, and the op they perform
FSM_CALL_OPS: Dict[str, str] = {
    "release_path": "release",
    "release_partial": "release",
    "preempt_path": "preempt",
    "restore_path": "restore",
    "fork_paths": "branch",
    "fork_from_prefix": "branch-prefix",
    "_finish_path": "finish",
    "add_finished": "record-finished",
}

# calls that *use* a path as a live decoding context
FSM_USE_TAILS = {"fork_paths", "fork_path", "fork_from_prefix",
                 "decode_segments", "sample_pending_batch"}
FSM_BRANCH_TAILS = {"fork_paths", "fork_path", "fork_from_prefix"}

# The declared path-lifecycle transition table: op -> sites allowed to
# perform it, as (module, function qualname).  Every mutation site the
# analyzer finds must appear here; adding a new transition to the
# engine/sampler means extending this table in the same PR, which is
# the point — the diff review *is* the FSM review.
FSM_TRANSITIONS: Dict[str, Set[Tuple[str, str]]] = {
    "release": {
        ("repro.core.engine", "TreeEngine.preempt_path"),
        ("repro.core.engine", "TreeEngine.release_partial"),
        # error-path cleanup: constructors release their partial batch
        # before re-raising OutOfPages / fault kills (R5)
        ("repro.core.engine", "TreeEngine.prefill_queries"),
        ("repro.core.engine", "TreeEngine.fork_paths"),
        ("repro.core.engine", "TreeEngine.restore_path"),
        ("repro.core.engine", "TreeEngine.fork_from_prefix"),
        ("repro.core.sampler", "_finish_path"),
        ("repro.core.sampler", "_release_leaf_kv"),
        ("repro.core.sampler", "sample_trees"),
        # serving frontend: admission-time error cleanup and request
        # completion (repro.core.scheduler)
        ("repro.core.scheduler", "Scheduler._build_path"),
        ("repro.core.scheduler", "Scheduler._finish_request"),
    },
    "preempt": {
        ("repro.core.sampler", "_admit_for_decode"),
        # serving frontend: newest-victim retraction under page pressure
        ("repro.core.scheduler", "Scheduler._preempt_victim"),
    },
    "preempt-enqueue": {
        ("repro.core.sampler", "_admit_for_decode"),
    },
    "restore": {
        ("repro.core.sampler", "_regenerate_tree"),
    },
    "branch": {
        ("repro.core.engine", "TreeEngine.fork_path"),
        ("repro.core.sampler", "_branch_tree"),
        ("repro.core.sampler", "sample_trees"),
    },
    "branch-prefix": {
        ("repro.core.sampler", "_fallback_tree"),
    },
    "finish": {
        ("repro.core.sampler", "_admit_for_decode"),
        ("repro.core.sampler", "_process_segment"),
        ("repro.core.sampler", "_branch_tree"),
        ("repro.core.sampler", "_quarantine_nonfinite"),
        ("repro.core.sampler", "sample_trees"),
    },
    "record-finished": {
        ("repro.core.sampler", "_finish_path"),
    },
    "status-set:dynamic": {
        ("repro.core.sampler", "_finish_path"),
    },
    "released-set": {
        ("repro.core.engine", "TreeEngine.release_path"),
    },
}


def _stmt_fsm_ops(stmt: ast.stmt) -> Iterable[Tuple[str, ast.AST]]:
    for call in _calls(stmt):
        t = _tail(call)
        if t in FSM_CALL_OPS:
            yield FSM_CALL_OPS[t], call
        if t == "append" and isinstance(call.func, ast.Attribute) \
                and isinstance(call.func.value, ast.Attribute) \
                and call.func.value.attr == "preempted":
            yield "preempt-enqueue", call
    tgt = None
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
        tgt = stmt.targets[0]
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        tgt = stmt.target
    if isinstance(tgt, ast.Attribute):
        v = getattr(stmt, "value", None)
        if v is None:
            return
        if tgt.attr == "status":
            if isinstance(v, ast.Attribute) and \
                    isinstance(v.value, ast.Name) and v.value.id == "Status":
                yield f"status-set:{v.attr}", stmt
            else:
                yield "status-set:dynamic", stmt
        elif tgt.attr == "released":
            yield "released-set", stmt


def _clear_slug(st: Dict[str, FrozenSet[str]], slug: str) -> None:
    st.pop(slug, None)
    for k in [k for k in st if k.startswith(slug + ".")]:
        st.pop(k, None)


def _r6_function(fn: FuncInfo, mod: ModuleInfo,
                 findings: List[Finding]) -> None:
    seen: Set[str] = set()

    def report(detail: str, lineno: int, message: str) -> None:
        if detail in seen:
            return
        seen.add(detail)
        findings.append(Finding(
            rule="R6", module=mod.name, path=mod.path, lineno=lineno,
            func=fn.qualname, detail=detail, message=message))

    # 1) every transition site must be declared
    for stmt in _fn_stmts(fn):
        for op, node in _stmt_fsm_ops(stmt):
            if (mod.name, fn.qualname) not in FSM_TRANSITIONS.get(op, ()):
                report(f"undeclared:{op}", node.lineno,
                       f"path-FSM transition `{op}` at "
                       f"`{fn.qualname}` is not in the declared "
                       "lifecycle table — add it to FSM_TRANSITIONS "
                       "(tools/analyze/verify.py) with review, or fix "
                       "the call site")

    # 2) illegal orderings within the function
    def arg_slugs(call: ast.Call) -> Iterable[str]:
        for a in list(call.args) + [k.value for k in call.keywords]:
            for n in ast.walk(a):
                if isinstance(n, (ast.Name, ast.Attribute)):
                    slug = _expr_slug(n)
                    if slug:
                        yield slug

    def apply_stmt(stmt: ast.stmt, st, reporting: bool) -> None:
        for call in _calls(stmt):
            t = _tail(call)
            if t in FSM_USE_TAILS:
                for slug in arg_slugs(call):
                    s = st.get(slug, frozenset())
                    if not reporting:
                        continue
                    if "released" in s:
                        report(f"use-after-release-path:{slug}",
                               stmt.lineno,
                               f"`{slug}` may be released here but is "
                               f"handed to `{t}` — released paths hold "
                               "no pages to decode or fork from")
                    elif "preempted" in s and t in FSM_BRANCH_TAILS:
                        report(f"branch-after-preempt:{slug}",
                               stmt.lineno,
                               f"`{slug}` may be preempted here but is "
                               f"branched via `{t}` — preempted paths "
                               "must be restored before branching")
            if t == "release_path":
                for a in call.args:
                    if isinstance(a, (ast.Name, ast.Attribute)):
                        slug = _expr_slug(a)
                        if reporting and \
                                "released" in st.get(slug, frozenset()):
                            report(f"double-release-path:{slug}",
                                   stmt.lineno,
                                   f"`{slug}` may already be released "
                                   "when release_path is called again")
                        st[slug] = frozenset({"released"})
            elif t == "preempt_path":
                for a in call.args:
                    if isinstance(a, (ast.Name, ast.Attribute)):
                        st[_expr_slug(a)] = frozenset({"preempted"})
        # rebinding a slug (path.ep = restore_path(...), loop vars)
        # clears its state and its fields'
        tgt = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
        elif isinstance(stmt, ast.AnnAssign):
            tgt = stmt.target
        if isinstance(tgt, (ast.Name, ast.Attribute)):
            _clear_slug(st, _expr_slug(tgt))
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for n in ast.walk(tgt):
                if isinstance(n, ast.Name):
                    _clear_slug(st, n.id)

    def step(block, st, reporting=False):
        exc = dict(st)
        for s in block.stmts:
            apply_stmt(s, st, reporting)
        return st, exc

    cfg = build_cfg(fn.node)
    in_map = _dataflow(cfg, step)
    for bid, st in in_map.items():
        if st is not None:
            step(cfg.blocks[bid], dict(st), reporting=True)


def rule_r6(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for fn in index.all_functions():
        mod = index.modules[fn.module]
        if any(True for s in _fn_stmts(fn) for _ in _stmt_fsm_ops(s)):
            _r6_function(fn, mod, findings)
    return findings


# ---------------------------------------------------------------------------
# R7: PRNG-key discipline
# ---------------------------------------------------------------------------

_KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in"}
_KEY_CONSUMERS = {"categorical", "normal", "uniform", "bernoulli",
                  "gumbel", "randint", "truncated_normal", "permutation",
                  "choice", "exponential", "gamma", "beta", "dirichlet",
                  "poisson", "laplace", "split", "shuffle"}
_KEY_PARAM_NAMES = {"key", "rng_key", "prng_key"}

# host-RNG constructors/seeders that break resume parity when they live
# outside checkpoint-captured state
_HOST_RNG = {"random.Random", "random.seed", "numpy.random.default_rng",
             "numpy.random.seed", "numpy.random.RandomState"}
# modules whose host RNGs *are* the checkpoint-captured state (trainer
# state_dict) or the deterministic fault-injection plan
R7_HOST_RNG_OK = {"repro.rl.trainer", "repro.core.faults"}


def _jax_random_fn(index: Index, mod: ModuleInfo,
                   call: ast.Call) -> Optional[str]:
    name = index.dotted_name(mod, call.func)
    if name and name.startswith("jax.random."):
        return name.rsplit(".", 1)[1]
    return None


def _key_arg(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "key":
            return kw.value
    return call.args[0] if call.args else None


def _r7_function(fn: FuncInfo, mod: ModuleInfo, index: Index,
                 findings: List[Finding]) -> None:
    seen: Set[str] = set()

    def report(detail: str, lineno: int, message: str) -> None:
        if detail in seen:
            return
        seen.add(detail)
        findings.append(Finding(
            rule="R7", module=mod.name, path=mod.path, lineno=lineno,
            func=fn.qualname, detail=detail, message=message))

    def apply_stmt(stmt: ast.stmt, st, reporting: bool) -> None:
        for call in _calls(stmt):
            jfn = _jax_random_fn(index, mod, call)
            if jfn in _KEY_CONSUMERS:
                a = _key_arg(call)
                if isinstance(a, ast.Name):
                    if reporting and "consumed" in st.get(a.id,
                                                         frozenset()):
                        report(f"key-reuse:{a.id}", stmt.lineno,
                               f"PRNG key `{a.id}` may already be "
                               f"consumed when `jax.random.{jfn}` "
                               "draws from it again — reused keys "
                               "correlate draws and break fault-replay "
                               "determinism; split first")
                    st[a.id] = frozenset({"consumed"})
        tgt = val = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt, val = stmt.targets[0], stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            tgt, val = stmt.target, stmt.value
        if tgt is None:
            return
        produced = isinstance(val, ast.Call) and \
            _jax_random_fn(index, mod, val) in _KEY_PRODUCERS
        names = [tgt] if isinstance(tgt, ast.Name) else (
            [e for e in tgt.elts if isinstance(e, ast.Name)]
            if isinstance(tgt, (ast.Tuple, ast.List)) else [])
        for n in names:
            if produced:
                st[n.id] = frozenset({"fresh"})
            else:
                st.pop(n.id, None)

    def step(block, st, reporting=False):
        exc = dict(st)
        for s in block.stmts:
            apply_stmt(s, st, reporting)
        return st, exc

    cfg = build_cfg(fn.node)
    entry_state = {p: frozenset({"fresh"}) for p in fn.params
                   if p in _KEY_PARAM_NAMES}
    in_map = _dataflow(cfg, step, entry_state)
    for bid, st in in_map.items():
        if st is not None:
            step(cfg.blocks[bid], dict(st), reporting=True)

    # split-and-drop: a split result that is never read desyncs the
    # stream relative to a resumed run that *does* read it
    split_targets: Dict[str, int] = {}
    loads: Dict[str, int] = {}
    for stmt in _fn_stmts(fn):
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            if _jax_random_fn(index, mod, stmt.value) == "split":
                a = _key_arg(stmt.value)
                report(f"split-drop:{_expr_slug(a) if a is not None else '?'}",
                       stmt.lineno,
                       "the result of `jax.random.split` is discarded — "
                       "the stream advances but nothing consumes the new "
                       "keys (resume will not replay this)")
        tgt = val = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt, val = stmt.targets[0], stmt.value
        if tgt is not None and isinstance(val, ast.Call) and \
                _jax_random_fn(index, mod, val) == "split":
            elts = [tgt] if isinstance(tgt, ast.Name) else (
                list(tgt.elts) if isinstance(tgt, (ast.Tuple, ast.List))
                else [])
            for e in elts:
                if isinstance(e, ast.Name) and not e.id.startswith("_"):
                    split_targets.setdefault(e.id, stmt.lineno)
        for n in _nodes(stmt):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                loads[n.id] = loads.get(n.id, 0) + 1
    for name, lineno in sorted(split_targets.items()):
        if loads.get(name, 0) == 0:
            report(f"split-drop:{name}", lineno,
                   f"`{name}` is split off a PRNG key but never used — "
                   "dead splits hide a missing consumer or a stream "
                   "desync (prefix with `_` if intentional)")


def rule_r7(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules.values():
        # host-RNG seeding: module-wide, function or module level
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = index.dotted_name(mod, node.func)
                if name in _HOST_RNG and mod.name not in R7_HOST_RNG_OK:
                    func = "<module>"
                    for fn in mod.functions.values():
                        if fn.node.lineno <= node.lineno <= max(
                                (n.lineno for n in ast.walk(fn.node)
                                 if hasattr(n, "lineno")),
                                default=fn.node.lineno):
                            func = fn.qualname
                    findings.append(Finding(
                        rule="R7", module=mod.name, path=mod.path,
                        lineno=node.lineno, func=func,
                        detail=f"host-rng:{name}",
                        message=f"`{name}` seeds host RNG state outside "
                                "the trainer's checkpoint-captured "
                                "generators — draws from it diverge "
                                "across crash-safe resume"))
    for fn in index.all_functions():
        mod = index.modules[fn.module]
        uses_jax_random = any(
            _jax_random_fn(index, mod, c) is not None
            for s in _fn_stmts(fn) for c in _calls(s))
        if uses_jax_random or (set(fn.params) & _KEY_PARAM_NAMES):
            _r7_function(fn, mod, index, findings)
    return findings


# ---------------------------------------------------------------------------
# R8: sharding-spec consistency
# ---------------------------------------------------------------------------

_MESH_CTORS = {"jax.make_mesh", "jax.sharding.Mesh",
               "jax.experimental.mesh_utils.Mesh"}


def _str_tuple(node: ast.AST) -> Optional[List[str]]:
    if isinstance(node, (ast.Tuple, ast.List)) and node.elts and all(
            isinstance(e, ast.Constant) and isinstance(e.value, str)
            for e in node.elts):
        return [e.value for e in node.elts]
    return None


def _axis_names_from(node: ast.AST,
                     local_assigns: Dict[str, ast.AST]) -> List[str]:
    """Axis names out of a make_mesh axis argument: a literal tuple, an
    IfExp over literal tuples, or a Name assigned one of those."""
    out: List[str] = []
    direct = _str_tuple(node)
    if direct:
        return direct
    if isinstance(node, ast.IfExp):
        return _axis_names_from(node.body, local_assigns) + \
            _axis_names_from(node.orelse, local_assigns)
    if isinstance(node, ast.Name) and node.id in local_assigns:
        return _axis_names_from(local_assigns[node.id], local_assigns)
    return out


def _collect_declared_axes(index: Index) -> Set[str]:
    axes: Set[str] = set()
    for mod in index.modules.values():
        for fn in mod.functions.values():
            local_assigns: Dict[str, ast.AST] = {}
            for stmt in _fn_stmts(fn):
                if isinstance(stmt, ast.Assign) and \
                        len(stmt.targets) == 1 and \
                        isinstance(stmt.targets[0], ast.Name):
                    local_assigns[stmt.targets[0].id] = stmt.value
            for stmt in _fn_stmts(fn):
                for call in _calls(stmt):
                    name = index.dotted_name(mod, call.func)
                    if (name in _MESH_CTORS or _tail(call) == "make_mesh") \
                            and len(call.args) >= 2:
                        axes.update(_axis_names_from(call.args[1],
                                                     local_assigns))
                    for kw in call.keywords:
                        if kw.arg == "axis_names" and (
                                name in _MESH_CTORS
                                or _tail(call) == "make_mesh"):
                            axes.update(_axis_names_from(kw.value,
                                                         local_assigns))
    return axes


def _pspec_aliases(mod: ModuleInfo) -> Set[str]:
    out = set()
    for alias, (src, attr) in mod.from_imports.items():
        if attr == "PartitionSpec" and src.startswith("jax"):
            out.add(alias)
    return out


def _r8_module(mod: ModuleInfo, index: Index, axes: Set[str],
               findings: List[Finding]) -> None:
    aliases = _pspec_aliases(mod)
    seen: Set[Tuple[str, str]] = set()

    def report(func: str, detail: str, lineno: int, message: str) -> None:
        if (func, detail) in seen:
            return
        seen.add((func, detail))
        findings.append(Finding(
            rule="R8", module=mod.name, path=mod.path, lineno=lineno,
            func=func, detail=detail, message=message))

    def check_axis(value: str, func: str, lineno: int, where: str) -> None:
        if value not in axes:
            report(func, f"bad-axis:{value}", lineno,
                   f"axis `{value}` in {where} is not an axis of any "
                   f"declared mesh ({', '.join(sorted(axes))}) — this "
                   "only fails at dispatch time on a real multi-device "
                   "mesh")

    def is_pspec_call(call: ast.Call) -> bool:
        if isinstance(call.func, ast.Name) and call.func.id in aliases:
            return True
        name = index.dotted_name(mod, call.func)
        return bool(name) and name.endswith(".PartitionSpec")

    for fn in mod.functions.values():
        local_tuples: Dict[str, ast.AST] = {}
        for stmt in _fn_stmts(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                local_tuples[stmt.targets[0].id] = stmt.value
        for stmt in _fn_stmts(fn):
            # PartitionSpec axis arguments
            for call in _calls(stmt):
                if is_pspec_call(call):
                    for a in call.args:
                        if isinstance(a, ast.Constant) and \
                                isinstance(a.value, str):
                            check_axis(a.value, fn.qualname, call.lineno,
                                       "a PartitionSpec")
                        elif isinstance(a, (ast.Tuple, ast.List)):
                            for e in a.elts:
                                if isinstance(e, ast.Constant) and \
                                        isinstance(e.value, str):
                                    check_axis(e.value, fn.qualname,
                                               call.lineno,
                                               "a PartitionSpec")
                # donate_argnums must index into in_shardings
                kwargs = {k.arg: k.value for k in call.keywords}
                if "donate_argnums" in kwargs and "in_shardings" in kwargs:
                    shard = kwargs["in_shardings"]
                    if isinstance(shard, ast.Name):
                        shard = local_tuples.get(shard.id, shard)
                    if isinstance(shard, (ast.Tuple, ast.List)):
                        n = len(shard.elts)
                        donate = kwargs["donate_argnums"]
                        if isinstance(donate, ast.IfExp):
                            arms = (donate.body, donate.orelse)
                        else:
                            arms = (donate,)
                        for arm in arms:
                            if isinstance(arm, (ast.Tuple, ast.List)):
                                for e in arm.elts:
                                    if isinstance(e, ast.Constant) and \
                                            isinstance(e.value, int) and \
                                            e.value >= n:
                                        report(
                                            fn.qualname,
                                            f"donate-out-of-range:"
                                            f"{e.value}",
                                            call.lineno,
                                            f"donate_argnums={e.value} "
                                            f"but in_shardings has only "
                                            f"{n} entries — donation "
                                            "silently targets the wrong "
                                            "buffer")
            # spec-element assignments: spec[0] = "data"
            tgt = val = None
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt, val = stmt.targets[0], stmt.value
            if tgt is not None and isinstance(val, ast.Constant) and \
                    isinstance(val.value, str) and \
                    "spec" in _expr_slug(tgt).lower():
                check_axis(val.value, fn.qualname, stmt.lineno,
                           f"`{_expr_slug(tgt)}`")
            # mesh.shape["data"]
            for n in _nodes(stmt):
                if isinstance(n, ast.Subscript) and \
                        isinstance(n.value, ast.Attribute) and \
                        n.value.attr == "shape" and \
                        "mesh" in _expr_slug(n.value.value).lower() and \
                        isinstance(n.slice, ast.Constant) and \
                        isinstance(n.slice.value, str):
                    check_axis(n.slice.value, fn.qualname, n.lineno,
                               f"`{_expr_slug(n.value)}[...]`")


def rule_r8(index: Index) -> List[Finding]:
    axes = _collect_declared_axes(index)
    if not axes:
        return []        # no mesh declared anywhere: nothing to check
    findings: List[Finding] = []
    for mod in index.modules.values():
        declares = any(
            _tail(c) == "make_mesh" or
            (index.dotted_name(mod, c.func) or "") in _MESH_CTORS
            for fn in mod.functions.values()
            for s in _fn_stmts(fn) for c in _calls(s))
        if _pspec_aliases(mod) or declares:
            _r8_module(mod, index, axes, findings)
    return findings


VERIFY_RULES: Sequence = (rule_r5, rule_r6, rule_r7, rule_r8)
