#!/usr/bin/env python3
"""Docs link/reference checker.

Validates that README.md and docs/*.md only reference things that
exist:

* markdown links ``[text](path)`` — the relative path must resolve from
  the file that contains it (http(s)/mailto/anchors are skipped);
* backtick path references like ``src/repro/rl/packing.py`` or
  ``benchmarks/run.py`` — must exist relative to the repo root
  (``repro/...`` is resolved under ``src/``);
* backtick dotted module references like ``repro.rl.update`` or
  ``repro.core.tree.QueryTree.add_finished`` — the longest module
  prefix must map to a real module file under ``src/``, with at most
  two trailing attribute components.

Run standalone (exits non-zero and lists dangling references):

    python tools/check_docs.py

or via pytest: ``tests/test_docs.py`` runs :func:`collect_errors` at
collection time as part of the tier-1 suite.
"""
from __future__ import annotations

import os
import re
import sys
from typing import List

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# artifacts a doc may legitimately describe before they are generated
GENERATED_OK = {
    "results/dryrun.jsonl",
}

# path-like backtick references we validate, by first component
_PATH_ROOTS = ("src", "benchmarks", "tests", "examples", "tools", "docs",
               "results", "repro")

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_RE = re.compile(r"`([^`\n]+)`")
_MODULE_RE = re.compile(r"^repro(\.\w+)+$")
_PATH_RE = re.compile(r"^[\w./-]+$")


def _doc_files(root: str) -> List[str]:
    files = [os.path.join(root, "README.md")]
    docs_dir = os.path.join(root, "docs")
    if os.path.isdir(docs_dir):
        for name in sorted(os.listdir(docs_dir)):
            if name.endswith(".md"):
                files.append(os.path.join(docs_dir, name))
    return [f for f in files if os.path.isfile(f)]


def _check_link(target: str, base_dir: str, root: str) -> bool:
    if target.startswith(("http://", "https://", "mailto:", "#")):
        return True
    target = target.split("#", 1)[0]
    if not target:
        return True
    return os.path.exists(os.path.normpath(os.path.join(base_dir, target)))


def _check_module_ref(ref: str, root: str) -> bool:
    """``repro.a.b[.Attr[.attr]]``: longest prefix must be a module under
    src/, and at most two components may remain as attributes."""
    parts = ref.split(".")
    for k in range(len(parts), 1, -1):
        base = os.path.join(root, "src", *parts[:k])
        if os.path.isfile(base + ".py") or \
                os.path.isfile(os.path.join(base, "__init__.py")):
            return len(parts) - k <= 2
    return False


def _check_path_ref(ref: str, root: str) -> bool:
    rel = ref.rstrip("/")
    if rel in GENERATED_OK:
        return True
    if rel.startswith("repro/"):
        rel = "src/" + rel
    return os.path.exists(os.path.join(root, rel))


def collect_errors(root: str = REPO_ROOT) -> List[str]:
    errors: List[str] = []
    for path in _doc_files(root):
        rel_file = os.path.relpath(path, root)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if not _check_link(target, os.path.dirname(path), root):
                errors.append(f"{rel_file}: dangling link ({target})")
        for m in _CODE_RE.finditer(text):
            ref = m.group(0).strip("`").strip()
            if _MODULE_RE.match(ref):
                if not _check_module_ref(ref, root):
                    errors.append(
                        f"{rel_file}: dangling module reference `{ref}`")
            elif "/" in ref and _PATH_RE.match(ref) and \
                    ref.split("/", 1)[0] in _PATH_ROOTS:
                if not _check_path_ref(ref, root):
                    errors.append(
                        f"{rel_file}: dangling path reference `{ref}`")
    return errors


def main() -> int:
    errors = collect_errors()
    if errors:
        print("check_docs: FAILED")
        for e in errors:
            print("  " + e)
        return 1
    print(f"check_docs: OK ({len(_doc_files(REPO_ROOT))} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
