#!/usr/bin/env bash
# Tier-1 verify wrapper — the one command every PR must keep green:
#
#     tools/run_tier1.sh                 # full tier-1 (== ROADMAP.md gate)
#     REPRO_TIER1_SHORT=1 tools/run_tier1.sh   # short mode: skip the
#         Pallas-interpreter kernel sweep and the subprocess dry-run
#         (the slowest, most isolated suites) for a fast inner loop
#     tools/run_tier1.sh -m pallas_interpret   # just the kernel bodies
#
# Marker map (see pytest.ini):
#   pallas_interpret — executes real Pallas kernel bodies via the CPU
#       interpreter (mamba/wkv6 segment-reset parity lives here)
#   hypothesis-gated — tests/test_property.py importorskips hypothesis;
#       absent the optional dep the property suite self-skips
#   fault — the deterministic fault-injection suite (tests/test_faults.py:
#       KV-pressure degradation, NaN quarantine, crash-safe resume). Runs
#       in BOTH full and short mode; -m fault selects just it
#   serve — the continuous-batching serving suite (tests/test_scheduler.py
#       scheduler simulation + parity, tests/test_radix.py radix-cache
#       properties). Runs in BOTH full and short mode; -m serve selects it
#   kernels — the per-kernel correctness suite (tests/test_kernels.py:
#       Pallas-vs-oracle parity incl. the pipelined fused-pool paged
#       kernels, buffer-depth bitwise stability, the zero-length padding
#       row regression). Same files as pallas_interpret today, but the
#       marker is the stable name: -m kernels selects the kernel suite
# Extra args are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# repro-lint + repro-verify first: the static half of the residency and
# lifecycle gates (R1-R8, baseline-checked; see docs/static_analysis.md).
# Fails fast on any new finding or stale baseline entry before the test
# suite spends minutes.  Knobs:
#   REPRO_LINT_CHANGED_ONLY=1  — report findings only in the git diff
#       (stale-baseline check off); fast inner loop on a big tree
#   GITHUB_ACTIONS=true        — emit ::error workflow annotations
LINT_ARGS=()
if [[ "${REPRO_LINT_CHANGED_ONLY:-0}" == "1" ]]; then
  LINT_ARGS+=(--changed-only)
fi
if [[ "${GITHUB_ACTIONS:-false}" == "true" ]]; then
  LINT_ARGS+=(--format github)
fi
python -m tools.analyze "${LINT_ARGS[@]}" src/repro
ARGS=(-x -q)
if [[ "${REPRO_TIER1_SHORT:-0}" == "1" ]]; then
  ARGS+=(-m "not pallas_interpret" --ignore tests/test_dryrun_integration.py)
fi
exec python -m pytest "${ARGS[@]}" "$@"
