"""Architecture registry: ``get_config("<arch-id>")`` resolves ``--arch`` ids."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    EncoderConfig,
    FrontendConfig,
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    TrainConfig,
    TreeConfig,
    smoke_variant,
)

# arch-id -> module name
_REGISTRY: Dict[str, str] = {
    "internlm2-20b": "internlm2_20b",
    "gemma3-12b": "gemma3_12b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "yi-6b": "yi_6b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-tiny": "whisper_tiny",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2.5-7b": "qwen2_5_7b",
}

ASSIGNED_ARCHS: List[str] = [k for k in _REGISTRY if k != "qwen2.5-7b"]
ALL_ARCHS: List[str] = list(_REGISTRY)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    key = arch.strip()
    if key.endswith("-smoke"):
        key, smoke = key[: -len("-smoke")], True
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[key]}")
    cfg: ModelConfig = mod.CONFIG
    return smoke_variant(cfg) if smoke else cfg


# the four assigned input shapes: name -> (seq_len, global_batch, mode)
INPUT_SHAPES: Dict[str, tuple] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}
