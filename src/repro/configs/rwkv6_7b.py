"""rwkv6-7b "Finch" — attention-free RNN with data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,        # d_model / rwkv.head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention_kind="none",
    rope_theta=0.0,
    max_position_embeddings=1_048_576,  # state-space: unbounded in principle
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, token_shift_lora=32),
    source="[arXiv:2404.05892]",
    supports_long_context=True,  # constant-size state
)
