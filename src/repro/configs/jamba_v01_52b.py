"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].

Jamba block: 8 layers, attention at in-block index 4, Mamba elsewhere; MoE FFN
every other layer (offset 1).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

_BLOCK = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attention_kind="gqa",
    rope_theta=0.0,  # jamba attention layers use no positional encoding
    max_position_embeddings=262_144,
    layer_pattern=_BLOCK,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=14336, every=2, offset=1),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    source="[arXiv:2403.19887]",
    supports_long_context=True,  # hybrid: Mamba state + linear-decode attn
)
