"""qwen2.5-7b — the paper's own training model [arXiv Qwen2.5 TR]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attention_kind="gqa",
    rope_theta=1_000_000.0,
    max_position_embeddings=131_072,
    source="[arXiv:2412.15115 Qwen2.5]",
)
