"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    attention_kind="gqa",
    qk_norm=True,  # olmoe uses qk-norm
    rope_theta=10_000.0,
    max_position_embeddings=4096,
    moe=MoEConfig(num_experts=64, top_k=8, expert_d_ff=1024),
    source="[arXiv:2409.02060]",
)
