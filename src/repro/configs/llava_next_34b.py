"""llava-next-34b — VLM decoder with anyres tiling; vision tower stubbed
[hf:llava-hf/llava-v1.6-mistral-7b-hf family].

The SigLIP/CLIP tower + projector are the permitted stub: the decoder
consumes precomputed patch embeddings.  anyres tiling at the default
(2x2 tiles + base) x 576 patches = 2880 prefix tokens.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attention_kind="gqa",
    rope_theta=5_000_000.0,
    max_position_embeddings=32_768,
    frontend=FrontendConfig(kind="vision", num_prefix_tokens=2880, embed_dim=7168),
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf]",
)
