"""deepseek-v3-671b — MLA + MoE (1 shared + 256 routed, top-8), MTP
[arXiv:2412.19437].

d_ff=2048 is the routed-expert intermediate size; the first 3 layers are
dense with d_ff=18432.  MLA: q_lora 1536, kv_lora 512, nope 128 + rope 64,
v_head 128.  MTP (multi-token prediction) is exposed as an auxiliary head in
the model (one extra depth), used only at train time.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: effectively MHA over decompressed heads
    head_dim=128,
    d_ff=18432,        # dense layers (first 3)
    vocab_size=129280,
    attention_kind="mla",
    rope_theta=10_000.0,
    max_position_embeddings=163_840,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        expert_d_ff=2048,
        num_shared_experts=1,
        shared_d_ff=2048,
        every=1,
        offset=3,  # first three layers dense
        router_aux_free_bias=True,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    source="[arXiv:2412.19437]",
)
