"""gemma3-12b — dense GQA with 5:1 local:global sliding-window, 128k context
[hf:google/gemma-3-1b-pt family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    attention_kind="gqa",
    qk_norm=True,               # gemma3 uses QK-norm
    rope_theta=1_000_000.0,
    max_position_embeddings=131_072,
    sliding_window=1024,
    global_every=6,             # 5 local : 1 global
    tie_embeddings=True,
    act="gelu",
    source="[hf:google/gemma-3-1b-pt]",
    supports_long_context=True,  # sliding-window variant: long_500k allowed
)
