"""whisper-tiny — enc-dec audio transformer, conv frontend stubbed
[arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is the permitted stub: the
encoder consumes precomputed (batch, 1500, 384) frame embeddings from
``input_specs``; encoder self-attn + decoder self/cross-attn are real.
"""
from repro.configs.base import EncoderConfig, FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    attention_kind="gqa",
    rope_theta=0.0,  # whisper uses learned positions; we use sinusoidal-fixed
    # model card caps generation at 448 positions; raised so the assigned
    # decode_32k input shape lowers as a pure shape exercise (DESIGN.md S5)
    max_position_embeddings=40_960,
    encoder=EncoderConfig(num_layers=4, d_model=384, num_heads=6, d_ff=1536,
                          max_positions=1500),
    frontend=FrontendConfig(kind="audio", num_prefix_tokens=1500, embed_dim=384),
    act="gelu",
    mlp_kind="plain",
    source="[arXiv:2212.04356]",
)
