"""Config system: frozen dataclasses describing every supported architecture.

Every assigned architecture gets one module in ``repro/configs/`` exporting a
``CONFIG`` constant built from these dataclasses.  ``repro.configs.get_config``
resolves ``--arch <id>`` strings, and ``smoke_variant`` derives the reduced
(2-layer, d_model<=512, <=4-expert) configuration used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for a single MoE FFN layer."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    # Which layers are MoE: every `every`-th layer starting at `offset`
    # (dense FFN elsewhere).  deepseek-v3 keeps the first 3 layers dense.
    every: int = 1
    offset: int = 0
    router_aux_free_bias: bool = False  # deepseek-v3 aux-loss-free balancing
    aux_loss_coef: float = 0.01
    router_jitter: float = 0.0
    # expert-parallel capacity factor (shard_map path): 0 = exact (every
    # shard runs all T*k rows; no drops), > 0 = GShard-style per-expert
    # capacity cf*T*k/E with overflow dropping — 8-16x less expert compute
    ep_capacity_factor: float = 0.0

    def is_moe_layer(self, layer_idx: int) -> bool:
        if layer_idx < self.offset:
            return False
        return (layer_idx - self.offset) % self.every == 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (deepseek-v2/v3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block settings (jamba)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, -(-d_model // 16))


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 "Finch" time-mix settings."""

    head_dim: int = 64
    decay_lora: int = 64   # rank of the data-dependent decay LoRA
    token_shift_lora: int = 32


@dataclass(frozen=True)
class EncoderConfig:
    """Audio/-style encoder for enc-dec models (whisper)."""

    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    max_positions: int = 1500  # whisper: 30s of audio -> 1500 frames


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend: provides precomputed embeddings.

    The carve-out permitted by the spec: mel+conv (audio) and ViT+projector
    (vision) are not implemented; ``input_specs`` hands the decoder a
    ``(batch, num_prefix_tokens, embed_dim)`` embedding tensor instead.
    """

    kind: str  # "audio" | "vision"
    num_prefix_tokens: int
    embed_dim: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention variant ---
    attention_kind: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    max_position_embeddings: int = 131_072
    # sliding window: window size for local layers; `global_every` = every
    # n-th layer is global full attention (gemma3: 5 local : 1 global -> 6).
    sliding_window: int = 0  # 0 = no sliding window anywhere
    global_every: int = 0    # 0 = all layers local if sliding_window>0
    # --- layer pattern for hybrids ---
    # e.g. jamba: ("mamba",)*4 + ("attn",) + ("mamba",)*3 repeated; empty =
    # every layer is `attn` (or `rwkv` for ssm archs).
    layer_pattern: Tuple[str, ...] = ()
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    mlp_kind: str = "gated"  # gated (swiglu) | plain (whisper)
    source: str = ""  # citation bracket from the assignment
    # decode-shape applicability notes
    supports_long_context: bool = False  # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim > 0 else self.d_model // self.num_heads

    def layer_kind(self, layer_idx: int) -> str:
        if self.layer_pattern:
            return self.layer_pattern[layer_idx % len(self.layer_pattern)]
        if self.attention_kind == "none":
            return "rwkv"
        return "attn"

    def is_global_attn_layer(self, layer_idx: int) -> bool:
        """For sliding-window models: is this layer full/global attention?"""
        if self.sliding_window <= 0:
            return True
        if self.global_every <= 0:
            return False
        return (layer_idx + 1) % self.global_every == 0

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                if self.attention_kind == "mla":
                    m = self.mla
                    total += d * m.q_lora_rank + m.q_lora_rank * n_q * m.qk_head_dim
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                    total += n_q * m.v_head_dim * d
                else:
                    total += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            elif kind == "mamba":
                mc = self.mamba
                d_in = mc.expand * d
                dtr = mc.resolved_dt_rank(d)
                total += d * 2 * d_in            # in_proj
                total += d_in * mc.d_conv        # conv
                total += d_in * (dtr + 2 * mc.d_state)  # x_proj
                total += dtr * d_in + d_in       # dt_proj
                total += d_in * mc.d_state + d_in  # A_log, D
                total += d_in * d                # out_proj
            elif kind == "rwkv":
                rc = self.rwkv
                total += 4 * d * d + d * d       # r,k,v,o,g  (time-mix)
                total += d * rc.decay_lora * 2   # decay lora
                total += 2 * d * self.d_ff       # channel mix (k,v)  + recv
            # FFN
            if kind != "rwkv":  # rwkv channel-mix counted above
                if self.moe is not None and self.moe.is_moe_layer(i):
                    m = self.moe
                    total += d * m.num_experts  # router
                    total += m.num_experts * 3 * d * m.expert_d_ff
                    if m.num_shared_experts:
                        total += m.num_shared_experts * 3 * d * m.shared_d_ff
                else:
                    mult = 3 if self.mlp_kind == "gated" else 2
                    total += mult * d * self.d_ff
        if self.encoder is not None:
            e = self.encoder
            per = 4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff
            total += e.num_layers * per
            total += 2 * self.d_model * d  # cross-attn kv proj (approx)
        return total

    def num_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        total = self.num_params()
        n_moe_layers = sum(
            1 for i in range(self.num_layers) if m.is_moe_layer(i)
        )
        inactive = n_moe_layers * (m.num_experts - m.top_k) * 3 * self.d_model * m.expert_d_ff
        return total - inactive


@dataclass(frozen=True)
class TreeConfig:
    """TreePO sampling hyper-parameters (paper §2.2, §3.1)."""

    max_depth: int = 14           # d
    segment_len: int = 512        # l  (d*l = response budget)
    max_width: int = 16           # w  (trajectory group size)
    branch_factor: int = 2        # N  (budget N^depth, binary default)
    init_divergence_low: int = 2  # "More Init Divergence": random 2..8 forks at root
    init_divergence_high: int = 2 #   (low==high -> "Fixed Init Divergence")
    budget_transfer: bool = True  # reassign dead paths' budget to live ones
    fallback: bool = True         # DFS fallback when w_q < w and no active paths
    fallback_align: int = 0       # 0 -> segment-aligned (page-aligned) fallback
    # heuristic branching: "uniform" | "low_prob" | "high_prob" | "scheduled_low_prob"
    branch_heuristic: str = "uniform"
    heuristic_temp: float = 2.0
    heuristic_temp_end: float = 2.0  # for scheduled variant
    # early stop
    repetition_ngram: int = 16
    repetition_count: int = 4
    temperature: float = 1.0
    top_p: float = 1.0
    # KV-pressure graceful degradation (docs/robustness.md): above the
    # soft watermark the branching budget's extra fan-out shrinks
    # linearly, hitting zero (continuations only) at the hard watermark;
    # engine-side preemption absorbs anything beyond that.  False
    # restores pressure-blind budgets (preemption stays on — it is a
    # correctness guard, not a heuristic).
    pressure_aware: bool = True
    kv_watermark_soft: float = 0.80
    kv_watermark_hard: float = 0.95

    @property
    def max_response_len(self) -> int:
        return self.max_depth * self.segment_len


@dataclass(frozen=True)
class TrainConfig:
    """GRPO/DAPO/TreePO optimization settings (paper Eq. 1, §3.1)."""

    learning_rate: float = 1e-6
    warmup_steps: int = 10
    batch_size: int = 512
    group_size: int = 16            # G == tree width w
    clip_eps_low: float = 0.2       # DAPO clip-higher: eps_low < eps_high
    clip_eps_high: float = 0.28
    advantage_kind: str = "treepo"  # grpo | treepo | treepo_size_weighted |
                                    # treepo_subgroup_reject | treepo_no_root
    global_norm: bool = True        # REINFORCE++ global variance normalization
    dynamic_sampling: bool = True   # DAPO rejection of all-0/all-1 groups
    oversample_factor: int = 3      # queries sent = 3x batch (paper)
    max_resample_rounds: int = 2
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    max_grad_norm: float = 1.0
    ppo_epochs: int = 1
    # numeric quarantine (docs/robustness.md): jitted all-finite check on
    # loss + grads inside the scanned update; a poisoned (N, L) bucket
    # keeps params/opt-state bitwise-unchanged for that epoch and reports
    # `skipped_nonfinite` instead of silently corrupting the run.
    nonfinite_guard: bool = True
    # sequence packing: bin multiple short trajectories into each (N, L)
    # row of the update batch (repro.rl.packing) — attention is segment-
    # masked, RoPE positions reset per segment and SSM/RWKV recurrent
    # state is zeroed at segment starts inside the scan kernels, so the
    # update matches the unpacked one while spending far fewer FLOPs on
    # pad tokens.  Exact for every arch, hybrids included
    # (repro.rl.packing.packing_supported).
    pack_sequences: bool = False
    # partial credit for a well-formatted but wrong boxed answer.  The paper
    # uses binary rewards on a pretrained base model; at toy scale the
    # shaping keeps reward std > 0 early (0.0 = paper-faithful binary).
    reward_shaping: float = 0.0


# ---------------------------------------------------------------------------
# smoke-variant derivation
# ---------------------------------------------------------------------------

def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts.

    Preserves every structural feature (GQA ratio, MLA, MoE, hybrid pattern,
    sliding window, enc-dec, frontend) at toy scale so a CPU forward/train
    step exercises the same code paths as the full config.
    """
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.num_heads, 4)
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    n_kv = max(1, n_heads // min(ratio, n_heads))
    head_dim = min(cfg.resolved_head_dim, 64)
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        max_position_embeddings=4096,
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=min(cfg.moe.expert_d_ff, 128),
            shared_d_ff=min(cfg.moe.shared_d_ff, 128),
            offset=min(cfg.moe.offset, 1),
        )
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.rwkv is not None:
        updates["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_dim=min(cfg.rwkv.head_dim, 32), decay_lora=16,
            token_shift_lora=8,
        )
    if cfg.encoder is not None:
        updates["encoder"] = EncoderConfig(
            num_layers=2, d_model=d_model, num_heads=n_heads,
            d_ff=min(cfg.encoder.d_ff, 512), max_positions=64,
        )
    if cfg.frontend is not None:
        updates["frontend"] = dataclasses.replace(
            cfg.frontend, num_prefix_tokens=16, embed_dim=d_model
        )
    if cfg.sliding_window > 0:
        updates["sliding_window"] = min(cfg.sliding_window, 64)
    if cfg.layer_pattern:
        # keep a 2-layer slice containing both kinds when hybrid
        kinds = list(dict.fromkeys(cfg.layer_pattern))
        if len(kinds) >= 2:
            updates["layer_pattern"] = (kinds[0], kinds[1])
        else:
            updates["layer_pattern"] = (kinds[0], kinds[0])
    return dataclasses.replace(cfg, **updates)
