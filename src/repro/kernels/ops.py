"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp reference
depending on backend/flags.  On this CPU container the jnp path (or the
Pallas interpreter in tests) executes; on TPU the pallas_call path compiles.

Set ``REPRO_FORCE_REF=1`` to force reference implementations everywhere.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp


def _interpret() -> bool:
    """REPRO_PALLAS_INTERPRET=1 routes ops through the Pallas interpreter on
    CPU — used by tests to exercise the real kernel bodies end-to-end."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_REF", "0") == "1":
        return False
    return jax.default_backend() == "tpu" or _interpret()


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    if _use_pallas():
        from repro.kernels.rmsnorm import rmsnorm_pallas

        return rmsnorm_pallas(x, scale, eps=eps, interpret=_interpret())
    from repro.kernels.ref import rmsnorm_ref

    return rmsnorm_ref(x, scale, eps=eps)


# ---------------------------------------------------------------------------
# flash attention (prefill / train)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, q_offset: int = 0,
                    segment_ids=None, bias=None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D).

    ``window``: sliding-window size (0 = full). ``q_offset``: absolute
    position of q[0] relative to k[0] (for chunked prefill).
    ``segment_ids``: optional (B, Skv) int32 per-token segment labels
    over the key axis for sequence-packed rows — attention is restricted
    to same-segment pairs.  With Sq < Skv (chunked prefill) the q chunk's
    labels are the slice at ``q_offset``; kv labels equal to
    ``SHARED_SEGMENT_ID`` (a per-row modality prefix) are visible to all.
    ``bias``: optional additive attention bias broadcastable to
    (B, Hq, Sq, Skv), added to the masked logits (ALiBi, relative
    position, soft prompt masks); supported by both backends.
    """
    # the Pallas kernel tiles one head dim for q/k/v; MLA prefill attends
    # with qk_head_dim != v_head_dim, which only the reference supports.
    if _use_pallas() and q.shape[-1] == v.shape[-1]:
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, q_offset=q_offset,
                                      segment_ids=segment_ids, bias=bias,
                                      interpret=_interpret())
    from repro.kernels.ref import attention_ref

    return attention_ref(q, k, v, causal=causal, window=window, scale=scale,
                         q_offset=q_offset, segment_ids=segment_ids,
                         bias=bias)


# ---------------------------------------------------------------------------
# dense-cache decode attention
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     scale: float | None = None):
    """Single-token decode. q: (B, Hq, D); caches: (B, S, Hkv, D);
    lengths: (B,) valid cache lengths (the new token is at lengths-1)."""
    from repro.kernels.ref import decode_attention_ref

    return decode_attention_ref(q, k_cache, v_cache, lengths, window=window,
                                scale=scale)


# ---------------------------------------------------------------------------
# paged (tree) decode attention
# ---------------------------------------------------------------------------

def paged_attention(q, k_pool, v_pool, block_tables, lengths, *,
                    page_size: int, scale: float | None = None,
                    window: int = 0):
    """Tree-decode attention over a shared paged KV pool.

    q: (B, Hq, D); pools: (num_pages, page, Hkv, D);
    block_tables: (B, max_pages) int32 page ids (-1 pad);
    lengths: (B,) total valid tokens per path.
    ``window`` > 0: sliding-window layers attend the last `window` keys.
    """
    if _use_pallas():
        from repro.kernels.paged_attention import paged_attention_pallas

        return paged_attention_pallas(q, k_pool, v_pool, block_tables,
                                      lengths, page_size=page_size,
                                      scale=scale, window=window,
                                      interpret=_interpret())
    from repro.kernels.ref import paged_attention_ref

    return paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               page_size=page_size, scale=scale,
                               window=window)


def fused_paged_attention(q, kv_pool, block_tables, lengths, *,
                          page_size: int, scale: float | None = None,
                          window: int = 0, num_buffers: int = 2):
    """Pipelined tree-decode over a fused head-interleaved KV pool.

    q: (B, Hq, D); kv_pool: (num_pages, page, 2*Hkv, D) with heads
    ``[K0,V0,K1,V1,...]`` (``repro.kv.layout.interleave_kv``);
    block_tables: (B, max_pages) int32 page ids (-1 pad); lengths: (B,).
    ``num_buffers``: DMA ring depth (Pallas-only scheduling knob — the
    kernel overlaps the copy of page i+1 with the scoring of page i;
    outputs are bitwise identical across depths).
    """
    if _use_pallas():
        from repro.kernels.paged_attention import fused_paged_attention_pallas

        return fused_paged_attention_pallas(q, kv_pool, block_tables,
                                            lengths, page_size=page_size,
                                            scale=scale, window=window,
                                            num_buffers=num_buffers,
                                            interpret=_interpret())
    from repro.kernels.ref import fused_paged_attention_ref

    return fused_paged_attention_ref(q, kv_pool, block_tables, lengths,
                                     page_size=page_size, scale=scale,
                                     window=window)


# ---------------------------------------------------------------------------
# MLA (absorbed-latent) paged decode attention
# ---------------------------------------------------------------------------

def mla_paged_attention(q_lat, q_rope, ckv_pool, kr_pool, block_tables,
                        lengths, *, page_size: int, scale: float):
    """Absorbed DeepSeek-MLA tree-decode over a shared latent page pool.

    q_lat: (B, H, r) query pre-multiplied by W_uk; q_rope: (B, H, rd);
    ckv_pool: (num_pages, page, r); kr_pool: (num_pages, page, rd);
    block_tables: (B, max_pages) int32 page ids (-1 pad); lengths: (B,).
    Returns the latent aggregate (B, H, r) — W_uv/W_o applied by the caller.
    """
    if _use_pallas():
        from repro.kernels.paged_attention import mla_paged_attention_pallas

        return mla_paged_attention_pallas(q_lat, q_rope, ckv_pool, kr_pool,
                                          block_tables, lengths,
                                          page_size=page_size, scale=scale,
                                          interpret=_interpret())
    from repro.kernels.ref import mla_paged_attention_ref

    return mla_paged_attention_ref(q_lat, q_rope, ckv_pool, kr_pool,
                                   block_tables, lengths,
                                   page_size=page_size, scale=scale)


def mla_fused_paged_attention(q_lat, q_rope, kv_pool, block_tables,
                              lengths, *, page_size: int, scale: float,
                              num_buffers: int = 2):
    """Pipelined absorbed-MLA tree-decode over a fused latent pool.

    q_lat: (B, H, r) query pre-multiplied by W_uk; q_rope: (B, H, rd);
    kv_pool: (num_pages, page, r + rd) with ``[ckv | k_rope]`` on the
    feature axis (``repro.kv.layout.fuse_mla``); block_tables:
    (B, max_pages) int32 page ids (-1 pad); lengths: (B,).  Returns the
    latent aggregate (B, H, r).  ``num_buffers``: DMA ring depth
    (Pallas-only scheduling knob; bitwise-invariant).
    """
    if _use_pallas():
        from repro.kernels.paged_attention import (
            mla_fused_paged_attention_pallas)

        return mla_fused_paged_attention_pallas(
            q_lat, q_rope, kv_pool, block_tables, lengths,
            page_size=page_size, scale=scale, num_buffers=num_buffers,
            interpret=_interpret())
    from repro.kernels.ref import mla_fused_paged_attention_ref

    return mla_fused_paged_attention_ref(q_lat, q_rope, kv_pool,
                                         block_tables, lengths,
                                         page_size=page_size, scale=scale)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

def mamba_scan(u, dt, B_, C_, A, D, h0, segment_ids=None):
    """Selective scan: u,dt (B,T,d_in); B_,C_ (B,T,N); A (d_in,N); D
    (d_in,); h0 (B,d_in,N) -> (y, h_final).  Pallas keeps the state in
    VMEM across the time loop (vs. an HBM round-trip per step in the XLA
    scan lowering — §Perf).

    ``segment_ids``: optional (B, T) packed-row labels — the carried
    state is zeroed at every segment start, so packed segments scan
    exactly as they would in their own rows."""
    if _use_pallas():
        from repro.kernels.mamba_scan import mamba_scan_pallas

        return mamba_scan_pallas(u, dt, B_, C_, A, D, h0, segment_ids,
                                 interpret=_interpret())
    from repro.kernels.ref import mamba_scan_ref

    return mamba_scan_ref(u, dt, B_, C_, A, D, h0,
                          segment_ids=segment_ids)


# ---------------------------------------------------------------------------
# rwkv6 wkv recurrence
# ---------------------------------------------------------------------------

def wkv6(r, k, v, w, u, state, segment_ids=None):
    """RWKV6 time-mix recurrence.

    r,k,v: (B, T, H, D); w: (B, T, H, D) decay in (0,1); u: (H, D) bonus;
    state: (B, H, D, D). Returns (out (B,T,H,D), new_state).

    ``segment_ids``: optional (B, T) packed-row labels — the carried
    state is zeroed at every segment start (no cross-segment wkv leak)."""
    if _use_pallas():
        from repro.kernels.wkv6 import wkv6_pallas

        return wkv6_pallas(r, k, v, w, u, state, segment_ids,
                           interpret=_interpret())
    from repro.kernels.ref import wkv6_ref

    return wkv6_ref(r, k, v, w, u, state, segment_ids=segment_ids)
