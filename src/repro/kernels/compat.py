"""Version compatibility for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases; kernels import :data:`CompilerParams` from here so a
single repo works against either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

# HBM-resident ("don't auto-stage into VMEM") memory space for pallas_call
# inputs the kernel DMAs manually; ``pltpu.TPUMemorySpace.ANY`` became the
# module-level ``pltpu.ANY`` alias in newer releases.
ANY_MEMORY_SPACE = getattr(pltpu, "ANY", None) \
    or pltpu.TPUMemorySpace.ANY
