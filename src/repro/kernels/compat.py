"""Version compatibility for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases; kernels import :data:`CompilerParams` from here so a
single repo works against either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
