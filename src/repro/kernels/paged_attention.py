"""Paged (tree-decode) attention Pallas kernel.

The TPU adaptation of vLLM-style PagedAttention for TreePO's shared-prefix
tree: every search path holds a *block table* of page ids into a global KV
pool; branching copies the table, never the KV data.  GPU PagedAttention
gathers pages with per-warp loads; the TPU version instead uses **scalar
prefetch** — the block table is a scalar-prefetch operand, and the kernel's
``index_map`` reads it to choose which ``(page, Hkv, D)`` tile the next grid
step DMAs from HBM into VMEM.  The MXU sees only dense, aligned tiles; page
indirection is resolved entirely in the (scalar) index map, so the gather
costs no vector compute.

Grid: ``(B, max_pages)`` with pages innermost; online softmax over pages in
f32 VMEM scratch (one (Hq, D) accumulator per path).  Invalid table entries
(-1) are clamped to page 0 and masked, so early-terminating paths of the
tree cost nothing extra.

Two kernels share the pattern:

* :func:`paged_attention_pallas` — GQA/MHA decode over per-head K/V pages.
* :func:`mla_paged_attention_pallas` — DeepSeek MLA *absorbed* decode: the
  query is pre-multiplied by W_uk into the latent space, scores are
  ``q_lat·ckv + q_rope·k_rope`` over latent pages, and the output is the
  latent aggregate (up-projected by W_uv outside the kernel).  Only the
  (page, r) latent tiles named by the block table are ever DMA'd — the
  dense ``(B, MP·page, r)`` gather of the jnp fallback never materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, page_size: int,
                  group: int, window: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (Hq, D)
    k = k_ref[...].astype(jnp.float32)                  # (page, Hkv, D)
    v = v_ref[...].astype(jnp.float32)

    Hq, D = q.shape
    page, Hkv, _ = k.shape
    # (Hkv, group, D) x (page, Hkv, D) -> (Hkv, group, page)
    qg = q.reshape(Hkv, group, D)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale     # (Hkv, group, page)

    # table pages are consecutive per path, so `lengths` alone masks both
    # the tail of the last page and the -1 (clamped-to-0) padding pages.
    pos = i * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (Hkv, group, page), 2)
    valid = pos < lengths_ref[b]
    if window > 0:
        valid &= pos >= lengths_ref[b] - window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]                                 # (Hkv, group)
    m_cur = jnp.maximum(m_prev, s.max(axis=2))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[..., None])                   # (Hkv, group, page)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=2)
    # (Hkv, group, page) x (page, Hkv, D) -> (Hkv, group, D)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_cur

    @pl.when(i == np_ - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).reshape(Hq, D).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "scale", "window",
                                    "interpret"))
def paged_attention_pallas(q, k_pool, v_pool, block_tables, lengths, *,
                           page_size: int, scale=None, window: int = 0,
                           interpret: bool = False):
    """q: (B, Hq, D); pools: (P, page, Hkv, D);
    block_tables: (B, max_pages) int32 (-1 pad); lengths: (B,)."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pool.shape
    assert page == page_size
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    max_pages = block_tables.shape[1]
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, i, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((None, page, Hkv, D),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0, 0)),
            pl.BlockSpec((None, page, Hkv, D),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, i, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, group, D), jnp.float32),
            pltpu.VMEM((Hkv, group), jnp.float32),
            pltpu.VMEM((Hkv, group), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=float(scale),
                          page_size=page_size, group=group, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(safe_tables, lengths, q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# MLA (absorbed-latent) paged decode
# ---------------------------------------------------------------------------

def _mla_paged_kernel(tables_ref, lengths_ref, q_lat_ref, q_rope_ref,
                      ckv_ref, kr_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ql = q_lat_ref[0].astype(jnp.float32)               # (H, r)
    qr = q_rope_ref[0].astype(jnp.float32)              # (H, rd)
    ckv = ckv_ref[...].astype(jnp.float32)              # (page, r)
    kr = kr_ref[...].astype(jnp.float32)                # (page, rd)

    H, _ = ql.shape
    page = ckv.shape[0]
    # absorbed scores: q_lat.ckv^T + q_rope.k_rope^T -> (H, page)
    s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale

    # pages are consecutive per path, so `lengths` alone masks the tail of
    # the last valid page and every -1 (clamped-to-0) padding page.
    pos = i * page_size + jax.lax.broadcasted_iota(jnp.int32, (H, page), 1)
    s = jnp.where(pos < lengths_ref[b], s, _NEG_INF)

    m_prev = m_ref[...]                                 # (H, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                              # (H, page)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    # (H, page) x (page, r) -> (H, r) latent aggregate
    pv = jax.lax.dot_general(p, ckv, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_cur

    @pl.when(i == np_ - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "scale", "interpret"))
def mla_paged_attention_pallas(q_lat, q_rope, ckv_pool, kr_pool,
                               block_tables, lengths, *, page_size: int,
                               scale: float, interpret: bool = False):
    """Absorbed MLA tree-decode over latent pages.

    q_lat: (B, H, r) query pre-multiplied by W_uk (latent space);
    q_rope: (B, H, rd) decoupled-rope query; ckv_pool: (P, page, r);
    kr_pool: (P, page, rd); block_tables: (B, max_pages) int32 (-1 pad);
    lengths: (B,).  Returns the latent output (B, H, r) — the caller
    up-projects with W_uv and mixes with W_o.
    """
    B, H, r = q_lat.shape
    P, page, rd = kr_pool.shape
    assert page == page_size and ckv_pool.shape[:2] == (P, page)
    max_pages = block_tables.shape[1]
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, i, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, H, rd), lambda b, i, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((None, page, r),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0)),
            pl.BlockSpec((None, page, rd),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, r), lambda b, i, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, r), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_paged_kernel, scale=float(scale),
                          page_size=page_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_lat.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(safe_tables, lengths, q_lat, q_rope, ckv_pool, kr_pool)
