"""Paged (tree-decode) attention Pallas kernels.

The TPU adaptation of vLLM-style PagedAttention for TreePO's shared-prefix
tree: every search path holds a *block table* of page ids into a global KV
pool; branching copies the table, never the KV data.  GPU PagedAttention
gathers pages with per-warp loads; the TPU version resolves page
indirection with **scalar prefetch** — the block table is a scalar-prefetch
operand, read on the scalar core, so the gather costs no vector compute.

Two generations of the pattern live here:

* **Legacy split-pool kernels** (:func:`paged_attention_pallas`,
  :func:`mla_paged_attention_pallas`) — grid ``(B, max_pages)``, one page
  tile per grid step chosen by the BlockSpec ``index_map``.  The Pallas
  pipeline double-buffers grid-step inputs for free, but K and V live in
  separate pools so every page costs two serialized DMAs, and the grid is
  padded to ``max_pages`` (invalid steps are masked, not skipped).  Kept as
  the parity oracle behind ``fused_kv=False``.

* **Pipelined fused-pool kernels** (:func:`fused_paged_attention_pallas`,
  :func:`mla_fused_paged_attention_pallas`) — grid ``(B,)``, the pool stays
  HBM-resident (``ANY`` memory space) and the kernel issues its own
  multi-buffered ``pltpu.make_async_copy`` ring over ``num_buffers`` VMEM
  slots: the copy of page *i+1* is in flight while page *i* is scored.
  K/V are fused into one head-interleaved pool (``[K0,V0,K1,V1,...]``;
  MLA: ``[ckv|k_rope]`` feature-concat — ``repro.kv.layout``), so one DMA
  ships both halves of a page.  The per-path loop runs only over the
  ``ceil(lengths[b]/page)`` *valid* pages — padding rows (``lengths==0``)
  issue **zero** DMAs and emit zeros.  The page-visit order and the online
  softmax are independent of ``num_buffers``, so outputs are bitwise
  identical across buffer depths (only DMA timing changes).

Both generations guard the fully-masked-row case: when every position of a
row is masked (a padding row in the fixed-shape serve dispatch), the
masked probabilities are zeroed *before* accumulation, so ``l == 0`` and
the flush emits exact zeros — not the mean of page 0's stale contents
(which is what ``exp(s - m) == 1`` under an all ``-1e30`` score row used
to produce).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import ANY_MEMORY_SPACE, CompilerParams

_NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, page_size: int,
                  group: int, window: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (Hq, D)
    k = k_ref[...].astype(jnp.float32)                  # (page, Hkv, D)
    v = v_ref[...].astype(jnp.float32)

    Hq, D = q.shape
    page, Hkv, _ = k.shape
    # (Hkv, group, D) x (page, Hkv, D) -> (Hkv, group, page)
    qg = q.reshape(Hkv, group, D)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale     # (Hkv, group, page)

    # table pages are consecutive per path, so `lengths` alone masks both
    # the tail of the last page and the -1 (clamped-to-0) padding pages.
    pos = i * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (Hkv, group, page), 2)
    valid = pos < lengths_ref[b]
    if window > 0:
        valid &= pos >= lengths_ref[b] - window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]                                 # (Hkv, group)
    m_cur = jnp.maximum(m_prev, s.max(axis=2))
    alpha = jnp.exp(m_prev - m_cur)
    # masked positions contribute exactly 0: on a fully-masked row m_cur
    # stays -1e30 and exp(s - m_cur) would be 1 everywhere, aggregating
    # page garbage into the flush
    p = jnp.where(valid, jnp.exp(s - m_cur[..., None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=2)
    # (Hkv, group, page) x (page, Hkv, D) -> (Hkv, group, D)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_cur

    @pl.when(i == np_ - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).reshape(Hq, D).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "scale", "window",
                                    "interpret"))
def paged_attention_pallas(q, k_pool, v_pool, block_tables, lengths, *,
                           page_size: int, scale=None, window: int = 0,
                           interpret: bool = False):
    """q: (B, Hq, D); pools: (P, page, Hkv, D);
    block_tables: (B, max_pages) int32 (-1 pad); lengths: (B,)."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pool.shape
    assert page == page_size
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    max_pages = block_tables.shape[1]
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, i, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((None, page, Hkv, D),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0, 0)),
            pl.BlockSpec((None, page, Hkv, D),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, i, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, group, D), jnp.float32),
            pltpu.VMEM((Hkv, group), jnp.float32),
            pltpu.VMEM((Hkv, group), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=float(scale),
                          page_size=page_size, group=group, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(safe_tables, lengths, q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# MLA (absorbed-latent) paged decode — legacy split pools
# ---------------------------------------------------------------------------

def _mla_paged_kernel(tables_ref, lengths_ref, q_lat_ref, q_rope_ref,
                      ckv_ref, kr_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ql = q_lat_ref[0].astype(jnp.float32)               # (H, r)
    qr = q_rope_ref[0].astype(jnp.float32)              # (H, rd)
    ckv = ckv_ref[...].astype(jnp.float32)              # (page, r)
    kr = kr_ref[...].astype(jnp.float32)                # (page, rd)

    H, _ = ql.shape
    page = ckv.shape[0]
    # absorbed scores: q_lat.ckv^T + q_rope.k_rope^T -> (H, page)
    s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale

    # pages are consecutive per path, so `lengths` alone masks the tail of
    # the last valid page and every -1 (clamped-to-0) padding page.
    pos = i * page_size + jax.lax.broadcasted_iota(jnp.int32, (H, page), 1)
    valid = pos < lengths_ref[b]
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]                                 # (H, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    # zero the masked probabilities so a fully-masked (padding) row keeps
    # l == 0 and flushes to zeros instead of page-0 garbage
    p = jnp.where(valid, jnp.exp(s - m_cur), 0.0)       # (H, page)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    # (H, page) x (page, r) -> (H, r) latent aggregate
    pv = jax.lax.dot_general(p, ckv, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_cur

    @pl.when(i == np_ - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "scale", "interpret"))
def mla_paged_attention_pallas(q_lat, q_rope, ckv_pool, kr_pool,
                               block_tables, lengths, *, page_size: int,
                               scale: float, interpret: bool = False):
    """Absorbed MLA tree-decode over latent pages.

    q_lat: (B, H, r) query pre-multiplied by W_uk (latent space);
    q_rope: (B, H, rd) decoupled-rope query; ckv_pool: (P, page, r);
    kr_pool: (P, page, rd); block_tables: (B, max_pages) int32 (-1 pad);
    lengths: (B,).  Returns the latent output (B, H, r) — the caller
    up-projects with W_uv and mixes with W_o.
    """
    B, H, r = q_lat.shape
    P, page, rd = kr_pool.shape
    assert page == page_size and ckv_pool.shape[:2] == (P, page)
    max_pages = block_tables.shape[1]
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, i, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, H, rd), lambda b, i, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((None, page, r),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0)),
            pl.BlockSpec((None, page, rd),
                         lambda b, i, tbl, ln: (tbl[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, r), lambda b, i, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, r), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_paged_kernel, scale=float(scale),
                          page_size=page_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_lat.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(safe_tables, lengths, q_lat, q_rope, ckv_pool, kr_pool)


# ---------------------------------------------------------------------------
# Pipelined fused-pool kernels: manual multi-buffered page DMA
# ---------------------------------------------------------------------------
#
# Ring-buffer schedule over ``depth`` VMEM slots (slot = page_index % depth):
#
#   warm-up:       start pages 0 .. depth-2           (slots 0 .. depth-2)
#   iteration i:   start page  i+depth-1 -> slot (i+depth-1) % depth
#                  wait  page  i         at  slot  i % depth
#                  score page  i
#
# Page i+depth-1 lands in the slot consumed at iteration i-1 — never the
# slot iteration i is about to read — so compute on page i overlaps the
# copies of pages i+1 .. i+depth-1.  depth=1 degenerates to the serial
# start-then-wait schedule.  Every started page p < n_valid is waited at
# iteration p, so no DMA is left dangling when the loop exits — including
# the n_valid == 0 (padding-row) case, which starts nothing and returns
# the zero-initialized accumulator.


def _fused_paged_kernel(tables_ref, lengths_ref, q_ref, kv_ref, o_ref,
                        buf, sem, *, scale: float, page_size: int,
                        group: int, window: int, depth: int):
    b = pl.program_id(0)
    max_pages = tables_ref.shape[1]
    n_valid = jnp.minimum(
        (lengths_ref[b] + page_size - 1) // page_size, max_pages)

    def start(j):
        pltpu.make_async_copy(kv_ref.at[tables_ref[b, j]],
                              buf.at[j % depth], sem.at[j % depth]).start()

    def wait(j):
        pltpu.make_async_copy(kv_ref.at[tables_ref[b, j]],
                              buf.at[j % depth], sem.at[j % depth]).wait()

    def warm(j, carry):
        @pl.when(j < n_valid)
        def _():
            start(j)
        return carry
    jax.lax.fori_loop(0, depth - 1, warm, 0)

    q = q_ref[0].astype(jnp.float32)                    # (Hq, D)
    Hq, D = q.shape
    Hkv = kv_ref.shape[2] // 2
    qg = q.reshape(Hkv, group, D)

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        @pl.when(i + depth - 1 < n_valid)
        def _():
            start(i + depth - 1)
        wait(i)
        tile = buf[i % depth].astype(jnp.float32)       # (page, 2*Hkv, D)
        kv = tile.reshape(page_size, Hkv, 2, D)
        k = kv[:, :, 0, :]                              # (page, Hkv, D)
        v = kv[:, :, 1, :]
        # (Hkv, group, D) x (page, Hkv, D) -> (Hkv, group, page)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale
        pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, group, page_size), 2)
        valid = pos < lengths_ref[b]
        if window > 0:
            valid &= pos >= lengths_ref[b] - window
        s = jnp.where(valid, s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=2))
        alpha = jnp.exp(m_prev - m_cur)
        # masked positions contribute 0 even when the whole tile is masked
        # (m_cur still -1e30): no page-garbage aggregation
        p = jnp.where(valid, jnp.exp(s - m_cur[..., None]), 0.0)
        l_cur = l_prev * alpha + p.sum(axis=2)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)
        acc_cur = acc_prev * alpha[..., None] + pv
        return m_cur, l_cur, acc_cur

    m0 = jnp.full((Hkv, group), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((Hkv, group), jnp.float32)
    acc0 = jnp.zeros((Hkv, group, D), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_valid, body, (m0, l0, acc0))
    denom = jnp.maximum(l, 1e-30)[..., None]
    o_ref[0] = (acc / denom).reshape(Hq, D).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "scale", "window",
                                    "num_buffers", "interpret"))
def fused_paged_attention_pallas(q, kv_pool, block_tables, lengths, *,
                                 page_size: int, scale=None,
                                 window: int = 0, num_buffers: int = 2,
                                 interpret: bool = False):
    """Pipelined tree-decode over a fused head-interleaved KV pool.

    q: (B, Hq, D); kv_pool: (P, page, 2*Hkv, D) with heads
    ``[K0,V0,K1,V1,...]`` (``repro.kv.layout.interleave_kv``);
    block_tables: (B, max_pages) int32 (-1 pad); lengths: (B,).
    ``num_buffers`` is the DMA ring depth (1 = serial copy/compute; 2/4 =
    double/quad buffering) — a pure scheduling knob, outputs are bitwise
    identical across depths.
    """
    B, Hq, D = q.shape
    P, page, Hkv2, _ = kv_pool.shape
    assert page == page_size and Hkv2 % 2 == 0
    Hkv = Hkv2 // 2
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    depth = max(1, int(num_buffers))
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, tbl, ln: (b, 0, 0)),
            pl.BlockSpec(memory_space=ANY_MEMORY_SPACE),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((depth, page, Hkv2, D), kv_pool.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_paged_kernel, scale=float(scale),
                          page_size=page_size, group=group, window=window,
                          depth=depth),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        # the manual DMA ring (buf/sem scratch) is shared state across
        # grid steps: the batch dim must not be megacore-parallelized
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(safe_tables, lengths, q, kv_pool)


def _mla_fused_paged_kernel(tables_ref, lengths_ref, q_lat_ref, q_rope_ref,
                            kv_ref, o_ref, buf, sem, *, scale: float,
                            page_size: int, rank: int, depth: int):
    b = pl.program_id(0)
    max_pages = tables_ref.shape[1]
    n_valid = jnp.minimum(
        (lengths_ref[b] + page_size - 1) // page_size, max_pages)

    def start(j):
        pltpu.make_async_copy(kv_ref.at[tables_ref[b, j]],
                              buf.at[j % depth], sem.at[j % depth]).start()

    def wait(j):
        pltpu.make_async_copy(kv_ref.at[tables_ref[b, j]],
                              buf.at[j % depth], sem.at[j % depth]).wait()

    def warm(j, carry):
        @pl.when(j < n_valid)
        def _():
            start(j)
        return carry
    jax.lax.fori_loop(0, depth - 1, warm, 0)

    ql = q_lat_ref[0].astype(jnp.float32)               # (H, r)
    qr = q_rope_ref[0].astype(jnp.float32)              # (H, rd)
    H, r = ql.shape

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        @pl.when(i + depth - 1 < n_valid)
        def _():
            start(i + depth - 1)
        wait(i)
        tile = buf[i % depth].astype(jnp.float32)       # (page, r + rd)
        ckv = tile[:, :rank]                            # (page, r)
        kr = tile[:, rank:]                             # (page, rd)
        s = (jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
             ) * scale                                  # (H, page)
        pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (H, page_size), 1)
        valid = pos < lengths_ref[b]
        s = jnp.where(valid, s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(valid, jnp.exp(s - m_cur), 0.0)   # (H, page)
        l_cur = l_prev * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, ckv, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_cur = acc_prev * alpha + pv
        return m_cur, l_cur, acc_cur

    m0 = jnp.full((H, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((H, 1), jnp.float32)
    acc0 = jnp.zeros((H, r), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_valid, body, (m0, l0, acc0))
    denom = jnp.maximum(l, 1e-30)
    o_ref[0] = (acc / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "scale", "num_buffers",
                                    "interpret"))
def mla_fused_paged_attention_pallas(q_lat, q_rope, kv_pool, block_tables,
                                     lengths, *, page_size: int,
                                     scale: float, num_buffers: int = 2,
                                     interpret: bool = False):
    """Pipelined absorbed-MLA tree-decode over a fused latent pool.

    q_lat: (B, H, r); q_rope: (B, H, rd); kv_pool: (P, page, r + rd) with
    ``[ckv | k_rope]`` on the feature axis (``repro.kv.layout.fuse_mla``);
    block_tables: (B, max_pages) int32 (-1 pad); lengths: (B,).  Returns
    the latent aggregate (B, H, r).  The rope split point is derived from
    the shapes: ``rd = kv_pool.shape[-1] - q_lat.shape[-1]``.
    """
    B, H, r = q_lat.shape
    P, page, feat = kv_pool.shape
    assert page == page_size and feat > r
    assert q_rope.shape == (B, H, feat - r)
    depth = max(1, int(num_buffers))
    safe_tables = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, r), lambda b, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, H, feat - r), lambda b, tbl, ln: (b, 0, 0)),
            pl.BlockSpec(memory_space=ANY_MEMORY_SPACE),
        ],
        out_specs=pl.BlockSpec((1, H, r), lambda b, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((depth, page, feat), kv_pool.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_fused_paged_kernel, scale=float(scale),
                          page_size=page_size, rank=r, depth=depth),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_lat.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(safe_tables, lengths, q_lat, q_rope, kv_pool)
