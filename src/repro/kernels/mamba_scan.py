"""Mamba selective-scan Pallas kernel.

The XLA lowering of the recurrence round-trips the (d_in, N) state through
HBM on every timestep (lax.scan carry), which §Perf iteration 3 measured as
the dominant memory term of the jamba prefill.  TPU mapping: grid over
(batch, d_in blocks); each program keeps its (blk_d, N) state slice
resident in f32 VMEM for the whole time loop — the state never touches HBM
between tokens.  Inputs stream through VMEM tiles; y writes stream out.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) B_t
    y_t = h_t . C_t + D * u_t

Sequence-packed rows pass per-token ``segment_ids`` (B, T): the carried
state is zeroed at every packed-segment start (derived reset mask, one
(1, T) int32 tile per program), so a segment scans exactly as it would
in its own row — recurrent state never leaks across a packing boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.ref import segment_reset_mask


def _mamba_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                  *refs, T: int, has_reset: bool):
    if has_reset:
        reset_ref, y_ref, hT_ref, state_ref = refs
    else:
        reset_ref, (y_ref, hT_ref, state_ref) = None, refs
    state_ref[...] = h0_ref[0].astype(jnp.float32)      # (blk_d, N)
    A = a_ref[...].astype(jnp.float32)                  # (blk_d, N)
    D = d_ref[...].astype(jnp.float32)                  # (blk_d,)

    def step(t, _):
        u_t = u_ref[0, t, :].astype(jnp.float32)        # (blk_d,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)        # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        h = state_ref[...]
        if has_reset:
            # packed-segment start: the carried state belongs to the
            # previous segment — zero it before this token consumes it
            h = h * (1.0 - reset_ref[0, t].astype(jnp.float32))
        dA = jnp.exp(dt_t[:, None] * A)
        h = dA * h + (dt_t * u_t)[:, None] * b_t[None, :]
        state_ref[...] = h
        y_ref[0, t, :] = ((h * c_t[None, :]).sum(axis=1)
                          + D * u_t).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    hT_ref[0] = state_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_d", "interpret"))
def mamba_scan_pallas(u, dt, B_, C_, A, D, h0, segment_ids=None, *,
                      blk_d: int = 512, interpret: bool = False):
    """u, dt: (B, T, d_in); B_, C_: (B, T, N); A: (d_in, N); D: (d_in,);
    h0: (B, d_in, N).  Returns (y (B, T, d_in), h_final (B, d_in, N)).

    ``segment_ids``: optional (B, T) int32 packed-row labels — the VMEM
    state is zeroed whenever the label changes from the previous token
    (h0 still seeds the row's first token: carried state from a previous
    chunk belongs to the same stream)."""
    B, T, d_in = u.shape
    N = B_.shape[-1]
    blk_d = min(blk_d, d_in)
    assert d_in % blk_d == 0
    nd = d_in // blk_d
    in_specs = [
        pl.BlockSpec((1, T, blk_d), lambda b, i: (b, 0, i)),   # u
        pl.BlockSpec((1, T, blk_d), lambda b, i: (b, 0, i)),   # dt
        pl.BlockSpec((1, T, N), lambda b, i: (b, 0, 0)),       # B
        pl.BlockSpec((1, T, N), lambda b, i: (b, 0, 0)),       # C
        pl.BlockSpec((blk_d, N), lambda b, i: (i, 0)),         # A
        pl.BlockSpec((blk_d,), lambda b, i: (i,)),             # D
        pl.BlockSpec((1, blk_d, N), lambda b, i: (b, i, 0)),   # h0
    ]
    inputs = [u, dt, B_, C_, A, D, h0]
    has_reset = segment_ids is not None
    if has_reset:
        inputs.append(segment_reset_mask(segment_ids))
        in_specs.append(pl.BlockSpec((1, T), lambda b, i: (b, 0)))
    y, hT = pl.pallas_call(
        functools.partial(_mamba_kernel, T=T, has_reset=has_reset),
        grid=(B, nd),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, T, blk_d), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, blk_d, N), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, d_in), u.dtype),
            jax.ShapeDtypeStruct((B, d_in, N), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((blk_d, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(*inputs)
    return y, hT
