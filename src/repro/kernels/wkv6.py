"""RWKV-6 (Finch) wkv recurrence Pallas kernel.

The recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T ,
               o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
is sequential in t but embarrassingly parallel over (batch, head).  TPU
mapping: grid ``(B, H)``; each program keeps its (D, D) state matrix
resident in f32 VMEM and walks the time axis with on-chip rank-1 updates —
the state never round-trips to HBM between tokens (on GPU this is the shared
-memory variant; on TPU VMEM plays that role).  D=64 keeps the (D, D) tile
lane-aligned.  All math f32 for the decay products.

Sequence-packed rows pass per-token ``segment_ids`` (B, T): the (D, D)
state is zeroed at every packed-segment start (derived reset mask, one
(1, T) int32 tile per program), so no wkv state leaks across a packing
boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.ref import segment_reset_mask


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, *refs,
                 T: int, has_reset: bool):
    if has_reset:
        reset_ref, o_ref, sT_ref, state_ref = refs
    else:
        reset_ref, (o_ref, sT_ref, state_ref) = None, refs
    state_ref[...] = s0_ref[0, 0].astype(jnp.float32)   # (D, D)
    u = u_ref[0].astype(jnp.float32)                    # (D,)

    def step(t, _):
        rt = r_ref[0, t, 0, :].astype(jnp.float32)      # (D,)
        kt = k_ref[0, t, 0, :].astype(jnp.float32)
        vt = v_ref[0, t, 0, :].astype(jnp.float32)
        wt = w_ref[0, t, 0, :].astype(jnp.float32)
        s = state_ref[...]
        if has_reset:
            # packed-segment start: drop the previous segment's state
            s = s * (1.0 - reset_ref[0, t].astype(jnp.float32))
        kv = kt[:, None] * vt[None, :]                  # (D, D) rank-1
        out = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)  # (D,)
        o_ref[0, t, 0, :] = out.astype(o_ref.dtype)
        state_ref[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    sT_ref[0, 0] = state_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_pallas(r, k, v, w, u, state, segment_ids=None, *,
                interpret: bool = False):
    """r,k,v,w: (B, T, H, D); u: (H, D); state: (B, H, D, D) [key-dim first].

    ``segment_ids``: optional (B, T) int32 packed-row labels — the VMEM
    state matrix is zeroed whenever the label changes from the previous
    token (``state`` still seeds the row's first token).

    Returns (out (B, T, H, D), final state (B, H, D, D))."""
    B, T, H, D = r.shape
    has_reset = segment_ids is not None
    kernel = functools.partial(_wkv6_kernel, T=T, has_reset=has_reset)
    seq_spec = pl.BlockSpec((1, T, 1, D), lambda b, h: (b, 0, h, 0))
    in_specs = [
        seq_spec, seq_spec, seq_spec, seq_spec,
        pl.BlockSpec((1, D), lambda b, h: (h, 0)),
        pl.BlockSpec((1, 1, D, D), lambda b, h: (b, h, 0, 0)),
    ]
    inputs = [r, k, v, w, u, state]
    if has_reset:
        inputs.append(segment_reset_mask(segment_ids))
        in_specs.append(pl.BlockSpec((1, T), lambda b, h: (b, 0)))
    out, s_final = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=in_specs,
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, D, D), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(*inputs)
    return out, s_final
