"""Flash attention (prefill / train) Pallas kernel.

TPU mapping: grid ``(B, Hq, num_q_blocks, num_kv_blocks)`` with the kv-block
axis innermost; a ``(blk_q, D)`` query tile stays resident in VMEM while
``(blk_k, D)`` key/value tiles stream through, maintaining the online-softmax
running max/denominator in f32 VMEM scratch.  Q/K tiles are MXU-shaped
(blk_q, blk_k multiples of 128 when the sequence allows).  GQA is handled in
the index map: the kv-head coordinate is ``q_head // group`` — no
materialized head repetition (saves Hq/Hkv × KV bandwidth).

Causal masking, sliding windows and the chunked-prefill ``q_offset`` are all
position masks computed from grid coordinates (no mask tensors in HBM).
Sequence-packed rows add one more mask term: per-token ``segment_ids``
(B, Skv) int32 over the key axis stream in as (1, blk) tiles alongside q
and k, and the score mask requires ``seg[q] == seg[kv]`` — packed segments
never attend across their boundary, at the cost of two int32 tiles (no
(S, S) mask in HBM).  The q chunk's labels are the kv labels sliced at
``q_offset`` (chunked prefill packs too), and kv labels equal to
``SHARED_SEGMENT_ID`` (-2; a per-row modality prefix) are attendable by
every query.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.ref import SHARED_SEGMENT_ID

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, *refs,
                  scale: float, causal: bool, window: int, q_offset: int,
                  blk_q: int, blk_k: int, sq: int, skv: int,
                  has_seg: bool, has_bias: bool):
    refs = list(refs)
    if has_seg:
        qseg_ref, kseg_ref = refs[:2]
        refs = refs[2:]
    if has_bias:
        bias_ref = refs[0]
        refs = refs[1:]
    o_ref, acc_ref, m_ref, l_ref = refs
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (blk_q, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (blk_k, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0) \
        + q_offset
    kpos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = kpos < skv                                   # kv padding
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    if has_seg:
        qseg = qseg_ref[0, :]                          # (blk_q,)
        kseg = kseg_ref[0, :]                          # (blk_k,)
        mask &= ((qseg[:, None] == kseg[None, :])
                 | (kseg[None, :] == SHARED_SEGMENT_ID))
    s = jnp.where(mask, s, _NEG_INF)
    if has_bias:
        # same order as attention_ref: bias lands on the already-masked
        # logits, so a masked score stays ~-1e30 for any finite bias
        s = s + bias_ref[0, 0, :, :].astype(jnp.float32)

    m_prev = m_ref[...]                                 # (blk_q,)
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "blk_q",
                     "blk_k", "interpret"),
)
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale=None, q_offset: int = 0, blk_q: int = 128,
                           blk_k: int = 128, interpret: bool = False,
                           segment_ids=None, bias=None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D).

    ``segment_ids``: optional (B, Skv) int32 labels over the key axis:
    restrict attention to same-segment pairs (sequence-packed rows).
    When Sq < Skv (chunked prefill) the q chunk's labels are the slice at
    ``q_offset``; ``SHARED_SEGMENT_ID`` kv tokens are visible to all.

    ``bias``: optional additive attention bias broadcastable to
    (B, Hq, Sq, Skv) (ALiBi slopes, relative-position buckets, soft
    prompt masks); added to the masked logits exactly as in
    ``attention_ref``, streamed as (blk_q, blk_k) tiles."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    has_seg = segment_ids is not None
    has_bias = bias is not None
    if has_seg and (segment_ids.shape[1] != Skv or q_offset + Sq > Skv):
        raise ValueError("segment_ids labels the kv axis (B, Skv); the q "
                         "chunk is its slice at q_offset")

    blk_q = min(blk_q, max(Sq, 1))
    blk_k = min(blk_k, max(Skv, 1))
    pad_q = (-Sq) % blk_q
    pad_k = (-Skv) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = q.shape[1] // blk_q
    nk = k.shape[1] // blk_k

    in_specs = [
        pl.BlockSpec((1, blk_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, blk_k, 1, D),
                     lambda b, h, i, j: (b, j, h // group, 0)),
        pl.BlockSpec((1, blk_k, 1, D),
                     lambda b, h, i, j: (b, j, h // group, 0)),
    ]
    inputs = [q, k, v]
    if has_seg:
        # -1 on the kv pad tail can never equal a real q segment id of a
        # surviving (un-sliced) row; the kpos < skv term masks it anyway.
        seg = segment_ids.astype(jnp.int32)
        qseg = jnp.pad(seg[:, q_offset: q_offset + Sq],
                       ((0, 0), (0, pad_q)), constant_values=-1)
        kseg = jnp.pad(seg, ((0, 0), (0, pad_k)), constant_values=-1)
        in_specs += [
            pl.BlockSpec((1, blk_q), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, blk_k), lambda b, h, i, j: (b, j)),
        ]
        inputs += [qseg, kseg]
    if has_bias:
        bias_full = jnp.broadcast_to(jnp.asarray(bias, jnp.float32),
                                     (B, Hq, Sq, Skv))
        # zero on the pad tail: padded scores are already masked to
        # _NEG_INF, the bias must not resurrect them
        bias_full = jnp.pad(bias_full,
                            ((0, 0), (0, 0), (0, pad_q), (0, pad_k)))
        in_specs += [
            pl.BlockSpec((1, 1, blk_q, blk_k),
                         lambda b, h, i, j: (b, h, i, j)),
        ]
        inputs += [bias_full]

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=float(scale), causal=causal, window=window,
            q_offset=q_offset, blk_q=blk_q, blk_k=blk_k, sq=Sq, skv=Skv,
            has_seg=has_seg, has_bias=has_bias),
        grid=(B, Hq, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, blk_q, 1, D),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(*inputs)
    if pad_q:
        out = out[:, :Sq]
    return out
