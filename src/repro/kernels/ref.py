"""Pure-jnp reference oracles for every kernel.

These are the semantics contracts: Pallas kernels must match them
(assert_allclose in tests/test_kernels.py) and they serve as the CPU
execution path of ``repro.kernels.ops``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kv.layout import deinterleave_kv, split_mla

# kv tokens carrying this segment id are attendable by EVERY query
# (subject to the causal/window mask) — the convention sequence packing
# uses for a per-row modality prefix that all packed segments condition
# on.  -1 stays the pad label (attended only by other pads).
SHARED_SEGMENT_ID = -2


def segment_reset_mask(segment_ids, xp=jnp):
    """(B, T) labels -> (B, T) float32 with 1.0 exactly where the carried
    recurrent state must be zeroed *before* the step consumes it: every
    token whose label differs from its predecessor's.  Token 0 is never a
    reset — the caller's h0/state seeds the row's first segment (carried
    state from a previous chunk of the same stream).  The ONE definition
    shared by the recurrent Pallas kernels and the jnp references."""
    seg = segment_ids.astype(xp.int32)
    first = xp.zeros((seg.shape[0], 1), xp.float32)
    rest = (seg[:, 1:] != seg[:, :-1]).astype(xp.float32)
    return xp.concatenate([first, rest], axis=1)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def _repeat_kv(k, num_q_heads: int):
    """(B, S, Hkv, D) -> (B, S, Hq, D) by group repetition."""
    hkv = k.shape[-2]
    if hkv == num_q_heads:
        return k
    return jnp.repeat(k, num_q_heads // hkv, axis=-2)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale=None, q_offset: int = 0, bias=None,
                  segment_ids=None):
    """Full-sequence attention. q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D).

    ``segment_ids``: optional (B, Skv) int32 labels over the KEY axis —
    tokens attend only within their own segment (sequence-packed rows).
    When Sq < Skv (chunked prefill) the query chunk's labels are the
    slice at ``q_offset``; kv labels equal to ``SHARED_SEGMENT_ID`` (-2,
    e.g. a per-row modality prefix) are attendable by every query.
    """
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    if segment_ids is None:
        logits = jnp.where(mask[None, None], logits, -1e30)
    else:
        # packed rows: the mask becomes per-batch (B, Sq, Skv) — only
        # pay that B-fold blowup when segments are actually present
        kseg = segment_ids.astype(jnp.int32)
        assert kseg.shape[1] == Skv and q_offset + Sq <= Skv, \
            "segment_ids labels the kv axis; the q chunk is its slice " \
            "at q_offset"
        qseg = kseg[:, q_offset: q_offset + Sq]
        seg_mask = mask[None] & ((qseg[:, :, None] == kseg[:, None, :])
                                 | (kseg[:, None, :] == SHARED_SEGMENT_ID))
        logits = jnp.where(seg_mask[:, None], logits, -1e30)
    if bias is not None:
        logits = logits + bias
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths, *, window: int = 0,
                         scale=None):
    """One-token decode vs dense cache. q: (B,Hq,D); cache: (B,S,Hkv,D).

    GQA is a grouped einsum (no head materialization) and the cache enters
    the dot in its stored dtype with f32 accumulation — both matter under
    GSPMD: a repeat/upcast of a sequence-sharded cache doubles (or 8x-es)
    the bytes any resharding has to move.
    """
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(S)[None, :]  # (1, S)
    valid = kpos < lengths[:, None]
    if window > 0:
        valid &= kpos >= (lengths[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, D).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                        page_size: int, scale=None, window: int = 0):
    """Tree-decode attention: gather pages per path, then masked attention.

    q: (B, Hq, D); pools: (P, page, Hkv, D); block_tables: (B, max_pages)
    int32 (-1 = unused); lengths: (B,).  ``window`` > 0 restricts keys to
    the last ``window`` positions (sliding-window layers).
    """
    B, Hq, D = q.shape
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    tables = jnp.maximum(block_tables, 0)  # (B, MP)
    k = k_pool[tables]  # (B, MP, page, Hkv, D)
    v = v_pool[tables]
    B_, MP, PG, Hkv, _ = k.shape
    k = k.reshape(B, MP * PG, Hkv, D)
    v = v.reshape(B, MP * PG, Hkv, D)
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = jnp.arange(MP * PG)[None, :]
    valid = (pos < lengths[:, None]) & (block_tables[:, pos[0] // page_size] >= 0)
    if window > 0:
        valid &= pos >= (lengths[:, None] - window)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # a fully-masked row (lengths[b] == 0 padding) must emit zeros — the
    # uniform softmax over an all -1e30 row would aggregate page garbage
    p = jnp.where(valid.any(axis=1)[:, None, None], p, 0.0)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def fused_paged_attention_ref(q, kv_pool, block_tables, lengths, *,
                              page_size: int, scale=None, window: int = 0):
    """Fused-layout oracle: de-interleave the ``[K0,V0,K1,V1,...]`` pool
    (``repro.kv.layout``) and defer to :func:`paged_attention_ref`.

    q: (B, Hq, D); kv_pool: (P, page, 2*Hkv, D) head-interleaved;
    block_tables: (B, max_pages) int32 (-1 = unused); lengths: (B,).
    """
    k_pool, v_pool = deinterleave_kv(kv_pool)
    return paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               page_size=page_size, scale=scale,
                               window=window)


def mla_paged_attention_ref(q_lat, q_rope, ckv_pool, kr_pool, block_tables,
                            lengths, *, page_size: int, scale: float):
    """Absorbed-MLA tree-decode oracle: dense page gather, then masked
    latent attention.

    q_lat: (B, H, r) latent query (already multiplied by W_uk);
    q_rope: (B, H, rd); ckv_pool: (P, page, r); kr_pool: (P, page, rd);
    block_tables: (B, max_pages) int32 (-1 = unused); lengths: (B,).
    Returns (B, H, r) latent output.
    """
    B, H, r = q_lat.shape
    tables = jnp.maximum(block_tables, 0)            # (B, MP)
    ckv = ckv_pool[tables]                           # (B, MP, page, r)
    kr = kr_pool[tables]
    _, MP, PG, _ = ckv.shape
    ckv = ckv.reshape(B, MP * PG, r).astype(jnp.float32)
    kr = kr.reshape(B, MP * PG, -1).astype(jnp.float32)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), ckv)
              + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32), kr)
              ) * scale
    pos = jnp.arange(MP * PG)[None, :]
    valid = (pos < lengths[:, None]) \
        & (block_tables[:, pos[0] // page_size] >= 0)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked (padding) rows emit zeros, not the page-0 mean
    p = jnp.where(valid.any(axis=1)[:, None, None], p, 0.0)
    out = jnp.einsum("bhs,bsr->bhr", p, ckv)
    return out.astype(q_lat.dtype)


def mla_fused_paged_attention_ref(q_lat, q_rope, kv_pool, block_tables,
                                  lengths, *, page_size: int, scale: float):
    """Fused-latent oracle: split the ``[ckv | k_rope]`` pool on the
    feature axis (rank = q_lat's trailing dim) and defer to
    :func:`mla_paged_attention_ref`.

    q_lat: (B, H, r); q_rope: (B, H, rd); kv_pool: (P, page, r + rd);
    block_tables: (B, max_pages) int32 (-1 = unused); lengths: (B,).
    """
    ckv_pool, kr_pool = split_mla(kv_pool, q_lat.shape[-1])
    return mla_paged_attention_ref(q_lat, q_rope, ckv_pool, kr_pool,
                                   block_tables, lengths,
                                   page_size=page_size, scale=scale)


def mamba_scan_ref(u, dt, B_, C_, A, D, h0, segment_ids=None):
    """Selective-scan oracle. u,dt: (B,T,d_in); B_,C_: (B,T,N);
    A: (d_in,N); D: (d_in,); h0: (B,d_in,N).

    ``segment_ids``: optional (B, T) packed-row labels — the carried
    state is zeroed at each segment start (``segment_reset_mask``)."""
    reset = (segment_reset_mask(segment_ids)
             if segment_ids is not None else None)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp[:4]
        if reset is not None:
            h = h * (1.0 - inp[4][:, None, None])
        dA = jnp.exp(dt_t[..., None] * A[None])
        h = dA * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0).astype(jnp.float32)
               for a in (u, dt, B_, C_))
    if reset is not None:
        xs = xs + (jnp.moveaxis(reset, 1, 0),)
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + u.astype(jnp.float32) * D[None, None]
    return y.astype(u.dtype), h_final.astype(h0.dtype)


def wkv6_ref(r, k, v, w, u, state, segment_ids=None):
    """RWKV6 recurrence, scanned over time in f32.

    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Shapes: r,k,v,w (B,T,H,D); u (H,D); state (B,H,D,D) [key-dim first].

    ``segment_ids``: optional (B, T) packed-row labels — the carried
    state is zeroed at each segment start (``segment_reset_mask``)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    s0 = state.astype(jnp.float32)
    reset = (segment_reset_mask(segment_ids)
             if segment_ids is not None else None)

    def step(s, inp):
        rt, kt, vt, wt = inp[:4]  # (B,H,D) each
        if reset is not None:
            s = s * (1.0 - inp[4][:, None, None, None])
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,D,D)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    if reset is not None:
        xs = xs + (jnp.moveaxis(reset, 1, 0),)
    s_final, outs = jax.lax.scan(step, s0, xs)
    out = jnp.moveaxis(outs, 0, 1)  # (B,T,H,D)
    return out.astype(r.dtype), s_final.astype(state.dtype)
