"""Fused RMSNorm Pallas kernel.

TPU mapping: rows are tiled over a grid; each program normalizes a
``(block_rows, d)`` tile held in VMEM.  The reduction runs on the VPU in
f32; the scale multiply is fused so the tile is read from HBM exactly once
(vs. twice for the unfused mean-of-squares + multiply graph).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, scale, *, eps: float = 1e-6, block_rows: int = 128,
                   interpret: bool = False):
    """x: (..., d); scale: (d,).  Tiles rows in VMEM blocks."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    blk = min(block_rows, rows)
    # pad rows to a multiple of blk so the grid is exact
    pad = (-rows) % blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // blk,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
