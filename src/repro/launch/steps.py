"""Distributed step functions + input specs for the multi-pod dry-run.

Three lowering targets per the assigned input shapes:
  train_4k                  -> ``train_step``   (PG update: fwd+bwd+AdamW)
  prefill_32k               -> ``prefill_step`` (forward + KV write-out)
  decode_32k / long_500k    -> ``serve_step``   (ONE token vs a full cache)

``input_specs`` hands back ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every model input of a case; ``build_case``
bundles the step fn with its in/out shardings for ``jax.jit(...).lower``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    to_named_sharding,
)
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.optim import adamw_init, warmup_constant_schedule
from repro.rl.packing import packing_supported
from repro.rl.update import make_ppo_update


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

# segment-table width of the packed train_4k layout: 4096-token rows
# hold at most a handful of tree trajectories each (the paper's l=512,
# d<=14 budget); 8 slots cover the FFD packer's worst case at that
# shape while keeping the (B, SEGS) tables negligible next to tokens.
TRAIN_PACK_SEGMENTS = 8


def make_train_step(cfg: ModelConfig, train_cfg: Optional[TrainConfig] = None,
                    remat: bool = True) -> Callable:
    """Multi-pod PG update: the SAME K-epoch scanned update the
    single-replica trainer jits per bucket (``repro.rl.update``), wrapped
    to the pjit dry-run's (params, opt_state, batch) calling convention.

    Every architecture (``packing_supported`` — universal since the
    segment-reset kernels landed) ships the sequence-packed compact
    layout (``packed=True``): (B, S) tokens + rollout logprobs and
    (B, SEGS) per-segment tables — masks, RoPE position resets,
    segment-masked attention, SSM/RWKV state resets and the advantage
    broadcast are all derived on device, so the pjit case ships lengths
    instead of dense (B, S) mask/advantage tensors (``input_specs``
    consults the same predicate, so specs and step never disagree).
    The REINFORCE++ global norm runs on device for packed batches under
    the same gate the single-replica trainer uses (never for
    already-normalized GRPO advantages).

    The warmup schedule is driven by the optimizer step count; the
    entropy diagnostic is skipped (full-vocab log-softmax is pure
    overhead at multi-pod scale)."""
    tc = train_cfg or TrainConfig()
    packed = packing_supported(cfg)
    update = make_ppo_update(
        cfg, tc, remat=remat, with_entropy=False, packed=packed,
        use_global_norm=(packed and tc.global_norm
                         and tc.advantage_kind != "grpo"),
        lr_fn=warmup_constant_schedule(tc.learning_rate, tc.warmup_steps))
    K = max(tc.ppo_epochs, 1)

    def train_step(params, opt_state, batch):
        # opt_state.step advances K times per train step; divide it back
        # so the warmup schedule sees the same train-step counter the
        # single-replica trainer feeds lr_fn
        return update(params, opt_state, batch, opt_state.step // K)

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        kwargs = {}
        if "prefix_embeds" in batch:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        if "enc_frames" in batch:
            kwargs["enc_frames"] = batch["enc_frames"]
        S_tot = batch["tokens"].shape[1] + (
            cfg.frontend.num_prefix_tokens
            if cfg.frontend is not None and cfg.frontend.kind == "vision"
            else 0)
        logits, cache = prefill(params, cfg, batch["tokens"], S_tot,
                                **kwargs)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, kv_update: str = "scatter"
                    ) -> Callable:
    def serve_step(params, cache, tokens_t, positions):
        logits, new_cache = decode_step(params, cfg, tokens_t, cache,
                                        positions, kv_update=kv_update)
        return logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this case."""
    seq_len, batch, mode = INPUT_SHAPES[shape_name]
    specs: Dict[str, Any] = {}
    if mode == "train":
        specs["tokens"] = _sds((batch, seq_len), jnp.int32)
        specs["logprobs_old"] = _sds((batch, seq_len), jnp.float32)
        if packing_supported(cfg):
            # sequence-packed compact layout: per-segment length/adv
            # tables replace the dense (batch, seq) mask + advantage
            # planes (2·seq f32 -> 3·SEGS words per row on the mesh)
            specs["seg_prompt_lens"] = _sds((batch, TRAIN_PACK_SEGMENTS),
                                            jnp.int32)
            specs["seg_resp_lens"] = _sds((batch, TRAIN_PACK_SEGMENTS),
                                          jnp.int32)
            specs["seg_adv"] = _sds((batch, TRAIN_PACK_SEGMENTS),
                                    jnp.float32)
        else:
            # dense fallback for a future layer kind without a
            # segment-reset path (unreachable today: the gate is
            # universally true — hybrids pack via kernel state resets)
            specs["response_mask"] = _sds((batch, seq_len), jnp.float32)
            specs["advantages"] = _sds((batch, seq_len), jnp.float32)
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            specs["prefix_embeds"] = _sds(
                (batch, cfg.frontend.num_prefix_tokens,
                 cfg.frontend.embed_dim), dtype)
        if cfg.encoder is not None:
            specs["enc_frames"] = _sds(
                (batch, cfg.encoder.max_positions, cfg.encoder.d_model),
                dtype)
    elif mode == "prefill":
        specs["tokens"] = _sds((batch, seq_len), jnp.int32)
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            specs["prefix_embeds"] = _sds(
                (batch, cfg.frontend.num_prefix_tokens,
                 cfg.frontend.embed_dim), dtype)
        if cfg.encoder is not None:
            specs["enc_frames"] = _sds(
                (batch, cfg.encoder.max_positions, cfg.encoder.d_model),
                dtype)
    else:  # decode
        specs["tokens_t"] = _sds((batch,), jnp.int32)
        specs["positions"] = _sds((batch,), jnp.int32)
        specs["cache"] = init_cache(cfg, batch, seq_len, dtype)
    return specs


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """Shape × arch applicability (DESIGN.md §5)."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 500k decode is quadratic-cost/"
                       "OOM; skipped per DESIGN.md §5")
    return True, ""


# ---------------------------------------------------------------------------
# case assembly for the dry-run
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LowerCase:
    arch: str
    shape_name: str
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    mode: str
    donate_argnums: Tuple[int, ...] = ()


def build_case(arch: str, shape_name: str, mesh: Mesh,
               dtype=jnp.bfloat16, remat: bool = True,
               kv_update: str = "scatter",
               shard_seq: bool = True,
               donate_cache: bool = False,
               moe_cf: float = 0.0,
               serve_tp_only: bool = False) -> LowerCase:
    """``kv_update`` / ``shard_seq`` / ``donate_cache`` / ``moe_cf`` are
    §Perf hillclimb levers (baseline: scatter + sequence-sharded cache,
    no donation, exact expert compute)."""
    cfg = get_config(arch)
    if moe_cf > 0 and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         ep_capacity_factor=moe_cf))
    seq_len, batch, mode = INPUT_SHAPES[shape_name]
    params_shape = jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=dtype),
        _sds((2,), jnp.uint32))
    use_fsdp = not (serve_tp_only and mode == "decode")
    p_specs = param_pspecs(cfg, params_shape, mesh, use_fsdp=use_fsdp)
    p_shard = to_named_sharding(mesh, p_specs)
    bspec = batch_pspec(mesh, batch)
    bshard = NamedSharding(mesh, bspec)
    specs = input_specs(cfg, shape_name, dtype)

    if mode == "train":
        from repro.optim.adamw import AdamWState
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        opt_shard = AdamWState(step=NamedSharding(mesh, P()),
                               m=p_shard, v=p_shard)
        batch_shard = {k: bshard for k in specs}
        fn = make_train_step(cfg, remat=remat)
        args = (params_shape, opt_shape, specs)
        in_shardings = (p_shard, opt_shard, batch_shard)
        out_shardings = (p_shard, opt_shard, None)
        # params/opt-state flow through the K-epoch scan carry: donate the
        # input buffers so weights + moments update in place on-chip
        return LowerCase(arch=arch, shape_name=shape_name, fn=fn, args=args,
                         in_shardings=in_shardings,
                         out_shardings=out_shardings, mode=mode,
                         donate_argnums=(0, 1))
    elif mode == "prefill":
        cache_shape = init_cache(
            cfg, batch,
            seq_len + (cfg.frontend.num_prefix_tokens
                       if cfg.frontend is not None
                       and cfg.frontend.kind == "vision" else 0), dtype)
        c_specs = cache_pspecs(cfg, cache_shape, mesh)
        c_shard = to_named_sharding(mesh, c_specs)
        fn = make_prefill_step(cfg)
        args = (params_shape, specs)
        in_shardings = (p_shard, {k: bshard for k in specs})
        out_shardings = (bshard, c_shard)
    else:
        cache_shape = specs["cache"]
        c_specs = cache_pspecs(cfg, cache_shape, mesh, shard_seq=shard_seq)
        c_shard = to_named_sharding(mesh, c_specs)
        fn = make_serve_step(cfg, kv_update=kv_update)
        args = (params_shape, cache_shape, specs["tokens_t"],
                specs["positions"])
        in_shardings = (p_shard, c_shard, bshard, bshard)
        out_shardings = (bshard, c_shard)
        return LowerCase(arch=arch, shape_name=shape_name, fn=fn,
                         args=args, in_shardings=in_shardings,
                         out_shardings=out_shardings, mode=mode,
                         donate_argnums=(1,) if donate_cache else ())
    return LowerCase(arch=arch, shape_name=shape_name, fn=fn, args=args,
                     in_shardings=in_shardings,
                     out_shardings=out_shardings, mode=mode)
