"""Compiled-artifact analysis: roofline terms from the dry-run.

Three terms per (arch × shape × mesh), in seconds (§Roofline):

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

# e.g.  %x = bf16[16,128,4096]{2,1,0} all-gather(...)
_HLO_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVE_OPS) + r")[\.\(]")

# tuple-result form: (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVE_OPS) + r")[\.\(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Total bytes moved by each collective kind (output-shape sized)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _TUPLE_RE.search(line)
        if m:
            kind = m.group(2)
            for sm in _SHAPE_RE.finditer(m.group(1)):
                out[kind] += _shape_bytes(sm.group(1), sm.group(2))
            continue
        m = _HLO_RE.search(line)
        if m:
            out[m.group(3)] += _shape_bytes(m.group(1), m.group(2))
    return out


@dataclasses.dataclass
class Roofline:
    """All raw quantities are PER-DEVICE: the compiled artifact is the SPMD
    (single-device) program, so ``cost_analysis`` FLOPs/bytes and the HLO
    collective shapes are one chip's share.  The roofline terms therefore
    need no further division by chip count; the *useful-compute* ratio
    compares the global analytic 6·N·D against flops × chips."""

    flops: float                 # HLO FLOPs per device
    hbm_bytes: float             # HLO bytes accessed per device
    coll_bytes: Dict[str, int]   # per collective kind, per device
    chips: int
    model_flops: float = 0.0     # global analytic 6·N·D

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.total_coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": dict(self.coll_bytes),
            "total_coll_bytes": self.total_coll_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
        }


def roofline_from_compiled(compiled, chips: int,
                           model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    chips=chips, model_flops=model_flops)


def model_flops_estimate(cfg, shape_name: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch tokens."""
    from repro.configs import INPUT_SHAPES
    seq_len, batch, mode = INPUT_SHAPES[shape_name]
    n = cfg.num_active_params()
    if mode == "train":
        return 6.0 * n * batch * seq_len
    if mode == "prefill":
        return 2.0 * n * batch * seq_len
    return 2.0 * n * batch  # one token per sequence


def memory_analysis_dict(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out or None
