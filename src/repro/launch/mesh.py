"""Production mesh builders.

Target hardware: TPU v5e pods — 256 chips per pod, 16x16 ICI torus.
Single-pod mesh: (data=16, model=16).  Multi-pod: (pod=2, data=16,
model=16) — the ``pod`` axis crosses DCN, so only data-parallel collectives
(gradient all-reduce, FSDP all-gather) ride it.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS *before* any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))
