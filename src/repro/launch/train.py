"""RL training driver (the paper's Table-1 experiment at selectable scale).

Runs the full TreePO pipeline — BC warmup (base-model stand-in), tree
rollout, reward, advantage, PG update — on the local devices.  ``--arch``
selects any assigned architecture (reduced ``-smoke`` variants train on
CPU; full configs are exercised via ``repro.launch.dryrun``).

Crash-safe resume (docs/robustness.md): with ``--ckpt-dir``, checkpoints
carry the *complete* trainer state (params, optimizer moments, step, all
host RNGs, metrics cursor) via ``RLTrainer.state_dict``; ``--resume``
restarts from the newest one and continues the SAME run — remaining
steps reproduce what the uninterrupted run would have logged.  The JSONL
metrics log is appended to on resume, with a ``resumed_from`` field on
post-resume rows.

Examples:
  python -m repro.launch.train --arch qwen2.5-7b-smoke --mode treepo \\
      --steps 20 --bc-steps 150
  python -m repro.launch.train --arch olmoe-1b-7b-smoke --mode grpo_tree
  python -m repro.launch.train --arch qwen2.5-7b-smoke --steps 200 \\
      --ckpt-dir runs/ck --log runs/metrics.jsonl --resume
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import TrainConfig, TreeConfig
from repro.core import faults
from repro.rl.trainer import RLTrainer, TrainerMode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b-smoke")
    ap.add_argument("--mode", default="treepo",
                    choices=["grpo", "grpo_tree", "treepo"])
    ap.add_argument("--advantage", default="treepo",
                    choices=["grpo", "treepo", "treepo_size_weighted",
                             "treepo_subgroup_reject", "treepo_no_root"])
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--bc-steps", type=int, default=120)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--segment", type=int, default=16)
    ap.add_argument("--branch-heuristic", default="uniform")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--keep-ckpts", type=int, default=3,
                    help="retain only the newest N checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest checkpoint in "
                         "--ckpt-dir (no-op if none exists)")
    ap.add_argument("--log", default=None, help="JSONL metrics path")
    ap.add_argument("--eval-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    tree_cfg = TreeConfig(
        max_depth=args.depth, segment_len=args.segment,
        max_width=args.width, branch_factor=2,
        init_divergence_low=2, init_divergence_high=2,
        temperature=0.9, branch_heuristic=args.branch_heuristic)
    train_cfg = TrainConfig(
        batch_size=args.queries, group_size=args.width,
        oversample_factor=2, max_resample_rounds=1,
        learning_rate=args.lr, advantage_kind=args.advantage,
        reward_shaping=0.1)
    trainer = RLTrainer(
        cfg, train_cfg, tree_cfg, TrainerMode(args.mode), seed=args.seed,
        engine_kwargs=dict(num_pages=4096, page_size=args.segment,
                           max_slots=256, max_queries=64,
                           max_prompt_len=256),
        min_difficulty=1, max_difficulty=2)

    resumed_from = None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        resumed_from = latest_step(args.ckpt_dir)
        trainer.load_state_dict(load_checkpoint(args.ckpt_dir, resumed_from))
        print(f"resumed from step {resumed_from} ({args.ckpt_dir})")

    print(f"arch={cfg.name} params={cfg.num_params():,} mode={args.mode} "
          f"devices={jax.devices()}")
    if args.bc_steps and resumed_from is None:
        # BC warmup happens exactly once per run; its effect on params
        # lives inside the checkpoint, so a resume must not repeat it
        w = trainer.bc_warmup(steps=args.bc_steps)
        print(f"bc warmup: loss={w['bc_loss']:.4f}")

    def checkpoint(step: int) -> None:
        save_checkpoint(args.ckpt_dir, step, trainer.state_dict(),
                        keep_last=args.keep_ckpts)

    # append on resume: the pre-crash rows are the same run's history
    logf = open(args.log, "a" if resumed_from is not None else "w") \
        if args.log else None
    start = trainer.step
    for i in range(start, args.steps):
        faults.kill_point("train.step")
        m = trainer.train_step(num_queries=args.queries,
                               progress=i / max(args.steps - 1, 1))
        line = (f"step {m['step']:4d} loss={m.get('loss', float('nan')):.4f} "
                f"reward={m['reward_mean']:.3f} "
                f"len={m['response_len']:.0f} leaf={m['leaf_rate']:.2f} "
                f"tokens={m['sample_model_tokens']:.0f}")
        if (i + 1) % args.eval_every == 0 or i == args.steps - 1:
            ev = trainer.evaluate(num_queries=8, k=4)
            m.update(ev)
            line += f" maj@4={ev['maj_acc']:.2f} pass={ev['pass_any']:.2f}"
        print(line, flush=True)
        if logf:
            if resumed_from is not None:
                m = dict(m, resumed_from=resumed_from)
            logf.write(json.dumps(m) + "\n")
            logf.flush()
        if args.ckpt_dir and m["step"] % args.ckpt_interval == 0:
            checkpoint(m["step"])
    if args.ckpt_dir:
        checkpoint(trainer.step)
    if logf:
        logf.close()


if __name__ == "__main__":
    main()
