"""Continuous-batching serving driver (the inference side of the paper).

Requests arrive on a seeded Poisson trace and are served by the
Scheduler / ModelRunner pair (``repro.core.scheduler``): admission is
continuous, prompt-prefill chunks and decode segments mix in one jitted
dispatch per round, and shared prompt prefixes (the --shared system
prompt) reuse KV pages across requests through the radix cache.
``--mode sync`` reproduces the old batch driver on the same serve
function — same per-request streams (the parity oracle), lower
throughput — and ``--sampler tree|sequential`` keeps the original
tree-rollout driver around for the paper's TrajPS numbers.

  python -m repro.launch.serve --arch qwen2.5-7b-smoke --requests 8
  python -m repro.launch.serve --mode sync --radix off
"""
from __future__ import annotations

import argparse
import random
import time
from collections import Counter

import jax

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_sequential, sample_trees
from repro.core.scheduler import Request, Scheduler, poisson_trace
from repro.data.reward import extract_boxed, verify_answer
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params

SYSTEM_PROMPT = ("You are a careful math assistant. Work step by step "
                 "and put the final answer in \\boxed{}. ")


def serve_requests(args, engine: TreeEngine, tok: ByteTokenizer,
                   rng) -> None:
    gen = MathTaskGenerator(seed=args.seed, min_difficulty=1,
                            max_difficulty=2)
    samples = gen.batch(args.requests)
    prefix = SYSTEM_PROMPT if args.shared == "on" else ""
    arrivals = poisson_trace(rng, args.requests, rate=args.rate)
    reqs = [Request(rid=i, prompt=tok.encode(prefix + s.query, bos=True),
                    max_new_tokens=args.max_new, arrival=a)
            for i, (s, a) in enumerate(zip(samples, arrivals))]
    sched = Scheduler(engine, mode=args.mode, max_running=args.max_running,
                      radix=args.radix == "on", base_seed=args.seed,
                      clock="wall")
    t0 = time.time()
    report = sched.run(reqs)
    wall = time.time() - t0
    print(f"{args.mode} serving summary ({args.requests} requests, "
          f"Poisson rate {args.rate}/s, seed {args.seed}):")
    print(f"  TrajPS  : {report.finished / max(wall, 1e-9):.3f}")
    print(f"  TokenPS : {report.gen_tokens / max(wall, 1e-9):.1f} "
          f"generated ({report.model_tokens / max(wall, 1e-9):.1f} "
          f"model-processed)")
    print(f"  rounds  : {report.rounds}; max admission wait "
          f"{report.max_admission_wait} rounds; "
          f"preemptions {report.preemptions}")
    print(f"  radix   : reuse ratio {report.reuse_ratio:.3f} "
          f"({report.radix_hit_tokens}/{report.prompt_tokens} prompt "
          f"tokens from cache; {report.evicted_pages} pages evicted)")
    print(f"  peak KV pages: {engine.stats.peak_pages}")


def serve_trees(args, engine: TreeEngine, tok: ByteTokenizer,
                rng) -> None:
    gen = MathTaskGenerator(seed=args.seed, min_difficulty=1,
                            max_difficulty=2)
    fn = sample_trees if args.sampler == "tree" else sample_sequential
    total_traj, total_wall = 0, 0.0
    for b in range(args.batches):
        samples = gen.batch(args.requests)
        prompts = [tok.encode(s.query, bos=True) for s in samples]
        t0 = time.time()
        trees, rep = fn(engine, prompts, [s.answer for s in samples],
                        rng=rng)
        wall = time.time() - t0
        answered = 0
        for tree, s in zip(trees, samples):
            answers = [a for p in tree.finished
                       if (a := extract_boxed(tok.decode(p.tokens)))]
            if answers and verify_answer(
                    Counter(answers).most_common(1)[0][0], s.answer):
                answered += 1
        total_traj += rep.num_trajectories
        total_wall += wall
        print(f"batch {b}: {rep.num_trajectories} trajs "
              f"({rep.num_fallbacks} fallbacks) in {wall:.1f}s, "
              f"maj-correct {answered}/{args.requests}", flush=True)
    s = engine.stats
    print(f"\n{args.sampler} rollout summary:")
    print(f"  TrajPS  : {total_traj / max(total_wall, 1e-9):.3f}")
    print(f"  TokenPS : {s.model_tokens / max(total_wall, 1e-9):.1f}")
    print(f"  tokens  : {s.model_tokens} "
          f"(prefill {s.prefill_tokens}, decode {s.decode_tokens}, "
          f"replay {s.replay_tokens})")
    print(f"  peak KV pages: {s.peak_pages}; forks {s.forks} "
          f"(COW {s.cow_pages})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b-smoke")
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "sync", "rollout"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate (requests per second)")
    ap.add_argument("--max-running", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--radix", default="on", choices=["on", "off"])
    ap.add_argument("--shared", default="on", choices=["on", "off"],
                    help="prepend a shared system prompt (radix workload)")
    ap.add_argument("--batches", type=int, default=2,
                    help="rollout mode: number of tree batches")
    ap.add_argument("--width", type=int, default=6)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--segment", type=int, default=16)
    ap.add_argument("--sampler", default="tree",
                    choices=["tree", "sequential"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tok = ByteTokenizer()
    cfg = get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    tree_cfg = TreeConfig(max_depth=args.depth, segment_len=args.segment,
                          max_width=args.width, branch_factor=2,
                          init_divergence_low=2, init_divergence_high=4,
                          temperature=1.0)
    engine = TreeEngine(params, cfg, tree_cfg, num_pages=4096,
                        page_size=args.segment, max_slots=256,
                        max_queries=64, max_prompt_len=256,
                        seed=args.seed)
    rng = random.Random(args.seed)
    if args.mode == "rollout":
        serve_trees(args, engine, tok, rng)
    else:
        serve_requests(args, engine, tok, rng)


if __name__ == "__main__":
    main()
