"""Batched tree-serving driver (the inference side of the paper).

Continuously serves batches of math queries through the TreePO engine,
reporting throughput in the paper's units (TokenPS / TrajPS) plus the
KV-amortization ratio.  Runs the reduced ``-smoke`` configs on CPU; full
configs are the dry-run's domain.

  python -m repro.launch.serve --arch yi-6b-smoke --batches 3 --width 8
"""
from __future__ import annotations

import argparse
import random
import time
from collections import Counter

import jax

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_sequential, sample_trees
from repro.data.reward import extract_boxed, verify_answer
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b-smoke")
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--width", type=int, default=6)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--segment", type=int, default=16)
    ap.add_argument("--sampler", default="tree",
                    choices=["tree", "sequential"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tok = ByteTokenizer()
    cfg = get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    tree_cfg = TreeConfig(max_depth=args.depth, segment_len=args.segment,
                          max_width=args.width, branch_factor=2,
                          init_divergence_low=2, init_divergence_high=4,
                          temperature=1.0)
    engine = TreeEngine(params, cfg, tree_cfg, num_pages=4096,
                        page_size=args.segment, max_slots=256,
                        max_queries=64, max_prompt_len=256,
                        seed=args.seed)
    gen = MathTaskGenerator(seed=args.seed, min_difficulty=1,
                            max_difficulty=2)
    fn = sample_trees if args.sampler == "tree" else sample_sequential
    rng = random.Random(args.seed)

    total_traj, total_tokens, total_wall = 0, 0, 0.0
    for b in range(args.batches):
        samples = gen.batch(args.requests)
        prompts = [tok.encode(s.query, bos=True) for s in samples]
        t0 = time.time()
        trees, rep = fn(engine, prompts, [s.answer for s in samples],
                        rng=rng)
        wall = time.time() - t0
        answered = 0
        for tree, s in zip(trees, samples):
            answers = [a for p in tree.finished
                       if (a := extract_boxed(tok.decode(p.tokens)))]
            if answers and verify_answer(
                    Counter(answers).most_common(1)[0][0], s.answer):
                answered += 1
        total_traj += rep.num_trajectories
        total_wall += wall
        print(f"batch {b}: {rep.num_trajectories} trajs "
              f"({rep.num_fallbacks} fallbacks) in {wall:.1f}s, "
              f"maj-correct {answered}/{args.requests}", flush=True)
    s = engine.stats
    total_tokens = s.model_tokens
    print(f"\n{args.sampler} serving summary:")
    print(f"  TrajPS  : {total_traj / max(total_wall, 1e-9):.3f}")
    print(f"  TokenPS : {total_tokens / max(total_wall, 1e-9):.1f}")
    print(f"  tokens  : {total_tokens} "
          f"(prefill {s.prefill_tokens}, decode {s.decode_tokens}, "
          f"replay {s.replay_tokens})")
    print(f"  peak KV pages: {s.peak_pages}; forks {s.forks} "
          f"(COW {s.cow_pages})")


if __name__ == "__main__":
    main()
