"""Multi-pod dry-run: ``lower().compile()`` every (architecture × input
shape × mesh) combination on the production placeholder mesh.

  single-pod: (data=16, model=16)        = 256 chips
  multi-pod : (pod=2, data=16, model=16) = 512 chips

Success proves the sharding config is coherent (no sharding mismatch, no
unsupported collective).  The compiled artifacts feed §Roofline:
``cost_analysis`` (FLOPs/bytes), ``memory_analysis`` (per-device bytes),
and the post-SPMD HLO (collective bytes).

Usage:
  python -m repro.launch.dryrun                      # everything
  python -m repro.launch.dryrun --arch yi-6b         # one arch
  python -m repro.launch.dryrun --shape train_4k --mesh single
  python -m repro.launch.dryrun --out /tmp/dryrun.json
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every jax import: jax locks the device count on first init.
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")  # mute SPMD chatter

import argparse
import json
import time
import traceback
from typing import Dict, List

import jax

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.analysis import (
    memory_analysis_dict,
    model_flops_estimate,
    roofline_from_compiled,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import applicable, build_case


def run_case(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, tag: str = "baseline",
             ep_moe: bool = False, **case_kw) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    rec: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "chips": chips,
        "tag": tag,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        case = build_case(arch, shape_name, mesh, **case_kw)
        # jax.set_mesh exposes the abstract mesh -> activates the explicit
        # expert-parallel shard_map MoE path (§Perf lever); the plain
        # `with mesh:` context keeps the GSPMD-propagated baseline.
        ctx = jax.set_mesh(mesh) if ep_moe else mesh
        with ctx:
            jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                             out_shardings=case.out_shardings,
                             donate_argnums=case.donate_argnums)
            lowered = jitted.lower(*case.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        roof = roofline_from_compiled(
            compiled, chips, model_flops_estimate(cfg, shape_name))
        mem = memory_analysis_dict(compiled)
        rec.update(
            status="ok",
            mode=case.mode,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            roofline=roof.as_dict(),
            memory=mem,
            params=cfg.num_params(),
            active_params=cfg.num_active_params(),
        )
        if verbose:
            print(f"[ok] {arch:18s} {shape_name:12s} "
                  f"{'multi' if multi_pod else 'single':6s} "
                  f"flops={roof.flops:.3e} bytes={roof.hbm_bytes:.3e} "
                  f"coll={roof.total_coll_bytes:.3e} "
                  f"bottleneck={roof.bottleneck} "
                  f"(compile {t_compile:.1f}s)", flush=True)
            if mem:
                print(f"     memory_analysis: {mem}", flush=True)
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR] {arch:18s} {shape_name:12s} "
                  f"{'multi' if multi_pod else 'single':6s} {e}", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None,
                    help="append JSONL records here (supports --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="skip (arch, shape, mesh) triples already in --out")
    ap.add_argument("--tag", default="baseline",
                    help="variant label stored with each record")
    ap.add_argument("--kv-update", default="scatter",
                    choices=["scatter", "masked"],
                    help="decode cache write strategy (§Perf lever)")
    ap.add_argument("--no-shard-seq", action="store_true",
                    help="replicate the cache sequence dim (§Perf lever)")
    ap.add_argument("--donate-cache", action="store_true",
                    help="alias the decode cache in/out (§Perf lever)")
    ap.add_argument("--ep-moe", action="store_true",
                    help="explicit expert-parallel shard_map MoE "
                         "(§Perf lever)")
    ap.add_argument("--moe-cf", type=float, default=0.0,
                    help="GShard capacity factor for the EP MoE path "
                         "(0 = exact)")
    ap.add_argument("--serve-tp-only", action="store_true",
                    help="decode shapes: tensor-parallel-only params "
                         "(no per-step FSDP weight gathers; §Perf lever)")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, \
        "dry-run needs the 512-device placeholder platform"
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    done = set()
    if args.resume and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("tag", "baseline")))

    outf = open(args.out, "a") if args.out else None
    records: List[Dict] = []
    # cheap shapes first so most of the table lands early
    shape_order = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]
    shapes = sorted(shapes, key=lambda s: shape_order.index(s))
    for shape in shapes:
        for arch in archs:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                if (arch, shape, mesh_name, args.tag) in done:
                    continue
                rec = run_case(arch, shape, multi, tag=args.tag,
                               ep_moe=args.ep_moe,
                               kv_update=args.kv_update,
                               shard_seq=not args.no_shard_seq,
                               donate_cache=args.donate_cache,
                               moe_cf=args.moe_cf,
                               serve_tp_only=args.serve_tp_only)
                records.append(rec)
                if outf:
                    outf.write(json.dumps(rec) + "\n")
                    outf.flush()
    n_err = sum(1 for r in records if r["status"] == "error")
    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"] == "skipped")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    if outf:
        outf.close()
        print(f"appended to {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
