"""Sharding rules: parameter / optimizer / activation / cache PartitionSpecs.

Scheme (DESIGN.md §6) — Megatron tensor parallel on the ``model`` axis +
FSDP-style parameter sharding on (``pod``, ``data``):

  * column-parallel weights (d -> out):   P(fsdp, "model")
  * row-parallel weights (in -> d):       P("model", fsdp)
  * MoE expert banks (E, ..., ...):       P("model", fsdp-ish, ...) — expert
    parallelism; GSPMD inserts the dispatch all-to-all.
  * vocab embedding / head:               vocab on "model", d on fsdp
  * norm scales / small vectors:          replicated (or channel-sharded
    when the channel dim is model-sharded downstream)

Every rule is *divisibility-filtered*: an axis is only applied if it evenly
divides the corresponding dimension (e.g. whisper's 51865-token vocab is
not divisible by 16 -> replicated).  This is a perf hint, not a semantics
change — GSPMD keeps the program correct either way.

KV-cache specs for serving: batch on ``data``, sequence on ``model``
(distributed KV — each model shard holds a slice of the context; XLA turns
the softmax over the sharded length into a distributed LSE combine).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _filter_spec(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec entries that don't evenly divide their dimension."""
    out = []
    for dim, axes in zip(shape, spec):
        if axes is not None and dim % _axis_size(mesh, axes) == 0 \
                and _axis_size(mesh, axes) > 1:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

_COL = {"w_q", "w_k", "w_v", "w_uq", "w_uk", "w_uv", "w_gate", "w_up",
        "w_in", "w_r", "w_g"}
_ROW = {"w_o", "w_down", "w_out"}
_REPL = {"scale", "mix_base", "mix_k", "router_bias", "dt_bias", "conv_b",
         "D", "decay_base", "bonus_u"}


def _param_rule(path: Tuple[str, ...], shape: Tuple[int, ...],
                fsdp) -> Tuple:
    """Raw (unfiltered) spec for one parameter, from its pytree path."""
    name = path[-1]
    in_moe = "ffn_moe" in path
    ndim = len(shape)
    if name == "embedding":                      # (V, d)
        return ("model", fsdp)
    if name == "lm_head":                        # (d, V)
        return (fsdp, "model")
    if name == "scale":
        return (None,) * ndim
    if in_moe and name in ("w_gate", "w_up"):    # (E, d, ff)
        return ("model", fsdp, None)
    if in_moe and name == "w_down":              # (E, ff, d)
        return ("model", None, fsdp)
    if in_moe and name == "router":              # (d, E)
        return (fsdp, None)
    if name in _COL and ndim == 2:               # (in, out)
        return (fsdp, "model")
    if name in _ROW and ndim == 2:               # (in, out): in is sharded
        return ("model", fsdp)
    # --- mamba ---
    if name == "conv_w":                         # (d_conv, d_in)
        return (None, "model")
    if name == "w_x":                            # (d_in, dtr + 2N)
        return ("model", None)
    if name == "w_dt":                           # (dtr, d_in)
        return (None, "model")
    if name == "A_log":                          # (d_in, N)
        return ("model", None)
    # --- rwkv ---
    if name == "decay_lora_a":                   # (d, L)
        return (fsdp, None)
    if name == "decay_lora_b":                   # (L, d)
        return (None, "model")
    if name == "mix_lora_a":                     # (d, L)
        return (fsdp, None)
    if name == "mix_lora_b":                     # (5, L, d)
        return (None, None, None)
    # --- small latent projections (MLA down-proj etc.) ---
    if name in ("w_dq", "w_dkv", "w_kr"):        # (d, r)
        return (fsdp, None)
    if name in _REPL:
        return (None,) * ndim
    # 1-D channel vectors riding a model-sharded dimension
    if ndim == 1:
        return (None,)
    # default: FSDP on dim 0 only
    return (fsdp,) + (None,) * (ndim - 1)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_pspecs(cfg: ModelConfig, params_shape, mesh: Mesh,
                 use_fsdp: bool = True):
    """PartitionSpec pytree matching ``params_shape`` (ShapeDtypeStructs).

    ``use_fsdp=False``: tensor-parallel only (params replicated over the
    data axes) — the right layout for decode serving, where per-step FSDP
    weight gathers dominate the collective roofline (§Perf)."""
    fsdp = fsdp_axes(mesh) if use_fsdp else ()
    fsdp = fsdp if fsdp else None

    def rule(path, leaf):
        names = _path_names(path)
        raw = _param_rule(names, leaf.shape, fsdp)
        return _filter_spec(raw, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, batch: int) -> P:
    """Batch axis over (pod, data) when divisible."""
    axes = fsdp_axes(mesh)
    if axes and batch % _axis_size(mesh, axes) == 0:
        return P(axes)
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def cache_pspecs(cfg: ModelConfig, cache_shape, mesh: Mesh,
                 *, shard_seq: bool = True):
    """Dense decode-cache specs: batch on data, sequence on model.

    Recurrent state: batch on data, channel/head dim on model.
    """
    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape
        spec: list = [None] * len(shape)
        # dim 0 is always batch
        if "data" in mesh.axis_names and shape[0] % mesh.shape["data"] == 0 \
                and mesh.shape["data"] > 1:
            spec[0] = "data"
        if name in ("k", "v", "ckv", "k_rope"):
            # (B, S, H, hd) or (B, S, r): shard sequence on model
            if shard_seq and shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
        elif name in ("conv", "ssm", "shift", "shift_ffn", "wkv"):
            # recurrent state: channel/head dim on model
            ch_dim = {"conv": 2, "ssm": 1, "shift": 1, "shift_ffn": 1,
                      "wkv": 1}[name]
            if shape[ch_dim] % mesh.shape["model"] == 0:
                spec[ch_dim] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def to_named_sharding(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
