from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    fsdp_axes,
    param_pspecs,
    to_named_sharding,
)

__all__ = ["param_pspecs", "cache_pspecs", "batch_pspec", "fsdp_axes",
           "to_named_sharding"]
