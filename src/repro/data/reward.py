"""Outcome reward: boxed-answer exact-match equivalence (paper's RLVR).

``is_equivalent(a, o_i)`` from Eq. 1 — binary terminal reward; a trajectory
is a LEAF iff it contains a legal boxed answer or [EOS] (the paper's leaf
criterion, §2.2 footnote 1).
"""
from __future__ import annotations

import re
from typing import Optional

_BOXED_RE = re.compile(r"\\boxed\{([^{}]*)\}")


def extract_boxed(text: str) -> Optional[str]:
    """Last \\boxed{...} content, or None."""
    matches = _BOXED_RE.findall(text)
    return matches[-1].strip() if matches else None


def _canon(s: str) -> Optional[str]:
    s = s.strip().replace(",", "").replace(" ", "")
    if not s:
        return None
    try:
        # canonicalize numerics: 7.0 == 7, -0 == 0
        f = float(s)
        if f == int(f):
            return str(int(f))
        return repr(f)
    except ValueError:
        return s.lower()


def verify_answer(prediction: str, target: str) -> bool:
    """is_equivalent: canonical numeric / lowered-string match."""
    p, t = _canon(prediction), _canon(target)
    return p is not None and p == t


def reward_fn(response_text: str, target: str,
              shaping: float = 0.0) -> float:
    """Terminal reward from raw generated text.

    Binary (paper-faithful) by default; ``shaping`` grants partial credit
    for a well-formatted but wrong boxed answer (toy-scale aid)."""
    boxed = extract_boxed(response_text)
    if boxed is None:
        return 0.0
    return 1.0 if verify_answer(boxed, target) else shaping
