"""Synthetic verifiable math-reasoning tasks (MATH/DeepScaleR stand-in).

The RLVR contract the paper trains under: a query with a unique numeric
answer, a sparse terminal reward = exact-match of the ``\\boxed{}`` answer.
Tasks are multi-step integer arithmetic chains whose intermediate steps form
a natural chain-of-thought, so a small model *can* learn them RL-zero style
and trajectories exhibit the shared-prefix structure the paper exploits
(§2.1): the problem restatement and early derivation steps coincide across
rollouts.

Difficulty levels 3–5 (matching the paper's MATH subset) map to chain
length / operand magnitude.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MathSample:
    query: str          # natural-language prompt
    answer: str         # ground-truth final answer (canonical string)
    cot: str            # a reference chain-of-thought (for analysis only)
    difficulty: int


_OPS = [("+", lambda a, b: a + b),
        ("-", lambda a, b: a - b),
        ("*", lambda a, b: a * b)]


class MathTaskGenerator:
    """Deterministic-by-seed generator of verifiable arithmetic CoT tasks."""

    def __init__(self, seed: int = 0, min_difficulty: int = 3,
                 max_difficulty: int = 5):
        self.rng = random.Random(seed)
        self.min_difficulty = min_difficulty
        self.max_difficulty = max_difficulty

    def sample(self) -> MathSample:
        diff = self.rng.randint(self.min_difficulty, self.max_difficulty)
        n_steps = diff  # chain length grows with difficulty
        lo, hi = 2, 6 + 2 * diff
        x = self.rng.randint(lo, hi)
        steps: List[str] = []
        expr_parts = [f"start with {x}"]
        val = x
        for s in range(n_steps):
            op_name, op = self.rng.choice(_OPS)
            y = self.rng.randint(lo, hi)
            new_val = op(val, y)
            verb = {"+": "add", "-": "subtract", "*": "multiply by"}[op_name]
            expr_parts.append(f"{verb} {y}")
            steps.append(f"Step {s + 1}: {val} {op_name} {y} = {new_val}.")
            val = new_val
        query = ("Compute the following: " + ", then ".join(expr_parts)
                 + ". Show your steps and put the final answer in \\boxed{}.")
        cot = " ".join(steps) + f" The final answer is \\boxed{{{val}}}."
        return MathSample(query=query, answer=str(val), cot=cot,
                          difficulty=diff)

    def batch(self, n: int) -> List[MathSample]:
        return [self.sample() for _ in range(n)]


def make_dataset(num_samples: int, seed: int = 0,
                 min_difficulty: int = 3,
                 max_difficulty: int = 5) -> List[MathSample]:
    gen = MathTaskGenerator(seed, min_difficulty, max_difficulty)
    return gen.batch(num_samples)
