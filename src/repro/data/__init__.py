from repro.data.tokenizer import ByteTokenizer
from repro.data.synthetic_math import MathTaskGenerator, MathSample
from repro.data.reward import extract_boxed, verify_answer, reward_fn

__all__ = [
    "ByteTokenizer", "MathTaskGenerator", "MathSample",
    "extract_boxed", "verify_answer", "reward_fn",
]
