"""Byte-level tokenizer with reserved special tokens.

Vocabulary layout: [0, 256) raw bytes, then specials.  Matches the RLVR
setting: the policy emits bytes; ``[EOS]`` terminates a trajectory;
``[PAD]`` right-pads fixed-shape device batches (TPU-friendly).
"""
from __future__ import annotations

from typing import Iterable, List


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258

    SPECIALS = {PAD: "[PAD]", BOS: "[BOS]", EOS: "[EOS]"}

    @property
    def vocab_size(self) -> int:
        return 256 + len(self.SPECIALS)

    def encode(self, text: str, *, bos: bool = False,
               eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out = bytearray()
        for t in ids:
            t = int(t)
            if t < 256:
                out.append(t)
            # specials are dropped in text form
        return out.decode("utf-8", errors="replace")

    def decode_with_specials(self, ids: Iterable[int]) -> str:
        parts = []
        buf = bytearray()
        for t in ids:
            t = int(t)
            if t < 256:
                buf.append(t)
            else:
                if buf:
                    parts.append(buf.decode("utf-8", errors="replace"))
                    buf = bytearray()
                parts.append(self.SPECIALS.get(t, f"[UNK{t}]"))
        if buf:
            parts.append(buf.decode("utf-8", errors="replace"))
        return "".join(parts)
