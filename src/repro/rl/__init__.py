from repro.rl.trainer import (
    LegacyRolloutBatch,
    RLTrainer,
    RolloutBatch,
    TrainerMode,
)
from repro.rl.update import make_pg_loss, make_ppo_update

__all__ = ["LegacyRolloutBatch", "RLTrainer", "RolloutBatch",
           "TrainerMode", "make_pg_loss", "make_ppo_update"]
