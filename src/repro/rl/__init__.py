from repro.rl.packing import (
    PackedRolloutBatch,
    bucket_segments,
    first_fit_decreasing,
    packed_batch_tensors,
    packed_row_tensors,
    packing_supported,
)
from repro.rl.trainer import (
    LegacyRolloutBatch,
    RLTrainer,
    RolloutBatch,
    TrainerMode,
)
from repro.rl.update import make_pg_loss, make_ppo_update

__all__ = ["LegacyRolloutBatch", "PackedRolloutBatch", "RLTrainer",
           "RolloutBatch", "TrainerMode", "bucket_segments",
           "first_fit_decreasing", "make_pg_loss", "make_ppo_update",
           "packed_batch_tensors", "packed_row_tensors",
           "packing_supported"]
