from repro.rl.trainer import RLTrainer, RolloutBatch, TrainerMode

__all__ = ["RLTrainer", "RolloutBatch", "TrainerMode"]
