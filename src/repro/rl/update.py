"""Shared jitted K-epoch PG update (paper Eq. 1 + AdamW).

One builder serves both training paths:

* :class:`repro.rl.trainer.RLTrainer` jits it per (N, L) bucket with
  donated params/opt-state buffers (the single-replica hot path), and
* :func:`repro.launch.steps.make_train_step` wraps it for the pjit
  multi-pod lowering (same math, shardings applied outside).

The K ``ppo_epochs`` run inside ONE jitted call as a ``jax.lax.scan``
over the (params, opt_state) carry — one dispatch per step instead of K,
and XLA can keep the donated weight/moment buffers in place across
epochs.  Metrics are reported from the final epoch (matching the
previous per-epoch loop's "last write wins" semantics).

Two batch layouts are supported, selected by ``packed``:

* dense (``packed=False``): one trajectory per row, batch keys
  ``tokens`` / ``response_mask`` / ``logprobs_old`` / ``advantages``;
* sequence-packed (``packed=True``): several trajectories (segments)
  per row, compact batch keys ``tokens`` / ``logprobs_old`` plus the
  (N, S) per-segment tables ``seg_prompt_lens`` / ``seg_resp_lens`` /
  ``seg_adv``.  The dense segment-id / RoPE-position / response-mask /
  advantage tensors and the optional REINFORCE++ global norm are all
  derived on device (``repro.rl.packing.packed_batch_tensors``), the
  forward pass gets segment-masked attention + per-segment-reset
  positions (and, through ``model.forward``, per-segment state resets
  in SSM/RWKV layers), and the loss mask drops any token whose
  predecessor lies in a different segment — a segment's first scored
  token is never aligned against the previous segment's last token.
  A modality prefix is labeled ``SHARED_SEGMENT_ID`` so every packed
  segment attends it, exactly as each trajectory would in its own row.

``donate_logprobs=True`` additionally threads the rollout-logprobs
plane — the largest float32 batch input — through to an extra output,
so callers can donate its buffer per (N, L) bucket (XLA aliases it in
place instead of keeping a second copy live across the K-epoch scan).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.advantage import global_normalize
from repro.kernels.ref import SHARED_SEGMENT_ID
from repro.core.loss import dapo_pg_loss, entropy_from_logits, \
    token_logprobs_from_logits
from repro.models.model import forward
from repro.optim import adamw_update, clip_by_global_norm
from repro.rl import packing

Batch = Dict[str, jnp.ndarray]


def _modality_kwargs(cfg: ModelConfig, batch: Batch) -> Dict[str, Any]:
    kwargs = {}
    if "prefix_embeds" in batch:
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    if "enc_frames" in batch:
        kwargs["enc_frames"] = batch["enc_frames"]
    return kwargs


def make_pg_loss(cfg: ModelConfig, tc: TrainConfig, *,
                 remat: bool = False,
                 with_entropy: bool = True,
                 packed: bool = False,
                 use_global_norm: bool = False) -> Callable[[Any, Batch],
                                                            Tuple]:
    """Token-level clipped PG loss over a batch dict (dense or packed
    layout — see the module docstring for the keys; optional
    ``prefix_embeds`` / ``enc_frames`` modality stubs ride along in
    both).

    ``with_entropy=False`` skips the full-vocab log-softmax entropy
    metric — the multi-pod lowering doesn't pay (N, S, V) extra HBM
    traffic for a diagnostics value.

    ``use_global_norm`` (packed only): apply the REINFORCE++ global
    normalization to the derived token advantages on device; the dense
    layout receives already-normalized advantages from the caller.

    Packed + modality contract: ``prefix_embeds`` / ``enc_frames`` are
    per-ROW — every segment of a packed row conditions on that row's
    tensor.  A caller that packs conditioned trajectories must co-bin
    same-conditioning trajectories into each row (FFD bins by length
    only; the trainer's own batches carry no conditioning, so this
    binds only hand-assembled batches — see packing_supported).
    """
    if packed:
        return _make_packed_pg_loss(cfg, tc, remat=remat,
                                    with_entropy=with_entropy,
                                    use_global_norm=use_global_norm)

    def loss_fn(params, batch: Batch):
        kwargs = _modality_kwargs(cfg, batch)
        logits, aux = forward(params, cfg, batch["tokens"], remat=remat,
                              **kwargs)
        S = batch["tokens"].shape[1]
        logits = logits[:, -S:]  # drop modality prefix positions
        lp_new = token_logprobs_from_logits(logits[:, :-1],
                                            batch["tokens"][:, 1:])
        # align: response token at t is predicted from t-1
        mask = batch["response_mask"][:, 1:]
        loss, metrics = dapo_pg_loss(
            lp_new, batch["logprobs_old"][:, 1:],
            batch["advantages"][:, 1:], mask,
            clip_eps_low=tc.clip_eps_low,
            clip_eps_high=tc.clip_eps_high)
        if with_entropy:
            metrics = dict(metrics, entropy=entropy_from_logits(
                logits[:, :-1], mask))
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_coef * aux
        metrics = dict(metrics, moe_aux=aux)
        return loss, metrics

    return loss_fn


def _make_packed_pg_loss(cfg: ModelConfig, tc: TrainConfig, *,
                         remat: bool, with_entropy: bool,
                         use_global_norm: bool):
    def loss_fn(params, batch: Batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        sid, pos, rmask, advs = packing.packed_batch_tensors(
            batch["seg_prompt_lens"], batch["seg_resp_lens"],
            batch["seg_adv"], S, xp=jnp)
        if use_global_norm:
            advs = global_normalize(advs, rmask)
        kwargs = _modality_kwargs(cfg, batch)
        pos_full, sid_full = pos, sid
        if "prefix_embeds" in batch and cfg.encoder is None:
            # The modality prefix occupies positions [0, P) and carries
            # the SHARED segment label: every packed segment attends it
            # (it is the row's conditioning signal), each segment's own
            # positions shift up by P — exactly what each trajectory
            # would see in its own unpacked row behind the same prefix.
            P = batch["prefix_embeds"].shape[1]
            pos_full = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P)),
                 pos + P], axis=1)
            sid_full = jnp.concatenate(
                [jnp.full((B, P), SHARED_SEGMENT_ID, jnp.int32), sid],
                axis=1)
        logits, aux = forward(params, cfg, tokens, remat=remat,
                              positions=pos_full, segment_ids=sid_full,
                              **kwargs)
        logits = logits[:, -S:]  # drop modality prefix positions
        lp_new = token_logprobs_from_logits(logits[:, :-1], tokens[:, 1:])
        # align: token t is predicted from t-1 — AND t-1 must belong to
        # the same segment, so a segment's first scored token never reads
        # the previous segment's last token (boundary leakage guard;
        # segment starts are prompt tokens, so rmask already zeroes them,
        # but the guard keeps the contract explicit and shape-derived)
        mask = rmask[:, 1:] * (sid[:, 1:] == sid[:, :-1]).astype(
            jnp.float32)
        loss, metrics = dapo_pg_loss(
            lp_new, batch["logprobs_old"][:, 1:], advs[:, 1:], mask,
            clip_eps_low=tc.clip_eps_low,
            clip_eps_high=tc.clip_eps_high)
        if with_entropy:
            metrics = dict(metrics, entropy=entropy_from_logits(
                logits[:, :-1], mask))
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_coef * aux
        metrics = dict(metrics, moe_aux=aux)
        return loss, metrics

    return loss_fn


def make_ppo_update(cfg: ModelConfig, tc: TrainConfig, *,
                    remat: bool = False,
                    ppo_epochs: Optional[int] = None,
                    lr_fn: Optional[Callable] = None,
                    with_entropy: bool = True,
                    packed: bool = False,
                    use_global_norm: bool = False,
                    donate_logprobs: bool = False) -> Callable:
    """Build ``update(params, opt_state, batch, step) -> (params,
    opt_state, metrics)`` running all K ppo epochs in one traced scan.

    ``lr_fn(step)`` defaults to the constant ``tc.learning_rate``; the
    trainer passes its warmup schedule.  ``packed`` selects the
    sequence-packed compact batch layout (see module docstring).  The
    returned function is pure — callers jit/pjit it with their own
    shardings and donation.

    ``donate_logprobs=True`` changes the return to ``(params, opt_state,
    logprobs_old, metrics)``: the rollout-logprobs plane is passed
    through to an output so a caller that donates its buffer gets an
    exact input-output alias — the (N, L) float32 buffer is reused in
    place per bucket instead of staying live alongside the update's
    scratch (the per-bucket twin of the params/opt-state donation).
    """
    K = int(ppo_epochs if ppo_epochs is not None else tc.ppo_epochs)
    K = max(K, 1)
    loss_fn = make_pg_loss(cfg, tc, remat=remat, with_entropy=with_entropy,
                           packed=packed, use_global_norm=use_global_norm)
    if lr_fn is None:
        lr_fn = lambda step: jnp.asarray(tc.learning_rate, jnp.float32)

    guard = bool(tc.nonfinite_guard)

    def update(params, opt_state, batch: Batch, step):
        lr = lr_fn(step)

        def epoch(carry, _):
            params, opt_state = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, tc.max_grad_norm)
            new_params, new_opt = adamw_update(
                params, grads, opt_state, lr=lr, beta1=tc.beta1,
                beta2=tc.beta2, eps=tc.eps, weight_decay=tc.weight_decay)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
            if guard:
                # numeric quarantine (docs/robustness.md): a poisoned
                # batch must not corrupt params — select the OLD
                # params/opt-state leafwise (bitwise-preserving) when
                # loss or any grad leaf is non-finite, and report the
                # skip instead of the silent NaN cascade
                ok = _all_finite(loss, grads)
                new_params = jax.tree.map(
                    lambda n, o: jnp.where(ok, n, o), new_params, params)
                new_opt = jax.tree.map(
                    lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
                metrics = dict(
                    metrics,
                    skipped_nonfinite=1.0 - ok.astype(jnp.float32))
            return (new_params, new_opt), metrics

        (params, opt_state), ms = jax.lax.scan(
            epoch, (params, opt_state), None, length=K)
        metrics = {k: v[-1] for k, v in ms.items()}
        if guard:
            # total skips across the K epochs, not just the last one
            metrics["skipped_nonfinite"] = ms["skipped_nonfinite"].sum()
        if donate_logprobs:
            return params, opt_state, batch["logprobs_old"], metrics
        return params, opt_state, metrics

    return update


def _all_finite(loss, grads) -> jnp.ndarray:
    """Scalar bool: the loss and every grad leaf are finite.  Runs fully
    inside the jitted scan epoch — no host sync on the hot path."""
    ok = jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        ok = ok & jnp.all(jnp.isfinite(g))
    return ok
