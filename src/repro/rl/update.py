"""Shared jitted K-epoch PG update (paper Eq. 1 + AdamW).

One builder serves both training paths:

* :class:`repro.rl.trainer.RLTrainer` jits it per (N, L) bucket with
  donated params/opt-state buffers (the single-replica hot path), and
* :func:`repro.launch.steps.make_train_step` wraps it for the pjit
  multi-pod lowering (same math, shardings applied outside).

The K ``ppo_epochs`` run inside ONE jitted call as a ``jax.lax.scan``
over the (params, opt_state) carry — one dispatch per step instead of K,
and XLA can keep the donated weight/moment buffers in place across
epochs.  Metrics are reported from the final epoch (matching the
previous per-epoch loop's "last write wins" semantics).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.loss import dapo_pg_loss, entropy_from_logits, \
    token_logprobs_from_logits
from repro.models.model import forward
from repro.optim import adamw_update, clip_by_global_norm

Batch = Dict[str, jnp.ndarray]


def make_pg_loss(cfg: ModelConfig, tc: TrainConfig, *,
                 remat: bool = False,
                 with_entropy: bool = True) -> Callable[[Any, Batch],
                                                        Tuple]:
    """Token-level clipped PG loss over a dense batch dict with keys
    ``tokens`` / ``response_mask`` / ``logprobs_old`` / ``advantages``
    (+ optional ``prefix_embeds`` / ``enc_frames`` modality stubs).

    ``with_entropy=False`` skips the full-vocab log-softmax entropy
    metric — the multi-pod lowering doesn't pay (N, S, V) extra HBM
    traffic for a diagnostics value."""

    def loss_fn(params, batch: Batch):
        kwargs = {}
        if "prefix_embeds" in batch:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        if "enc_frames" in batch:
            kwargs["enc_frames"] = batch["enc_frames"]
        logits, aux = forward(params, cfg, batch["tokens"], remat=remat,
                              **kwargs)
        S = batch["tokens"].shape[1]
        logits = logits[:, -S:]  # drop modality prefix positions
        lp_new = token_logprobs_from_logits(logits[:, :-1],
                                            batch["tokens"][:, 1:])
        # align: response token at t is predicted from t-1
        mask = batch["response_mask"][:, 1:]
        loss, metrics = dapo_pg_loss(
            lp_new, batch["logprobs_old"][:, 1:],
            batch["advantages"][:, 1:], mask,
            clip_eps_low=tc.clip_eps_low,
            clip_eps_high=tc.clip_eps_high)
        if with_entropy:
            metrics = dict(metrics, entropy=entropy_from_logits(
                logits[:, :-1], mask))
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_coef * aux
        metrics = dict(metrics, moe_aux=aux)
        return loss, metrics

    return loss_fn


def make_ppo_update(cfg: ModelConfig, tc: TrainConfig, *,
                    remat: bool = False,
                    ppo_epochs: Optional[int] = None,
                    lr_fn: Optional[Callable] = None,
                    with_entropy: bool = True) -> Callable:
    """Build ``update(params, opt_state, batch, step) -> (params,
    opt_state, metrics)`` running all K ppo epochs in one traced scan.

    ``lr_fn(step)`` defaults to the constant ``tc.learning_rate``; the
    trainer passes its warmup schedule.  The returned function is pure —
    callers jit/pjit it with their own shardings and donation.
    """
    K = int(ppo_epochs if ppo_epochs is not None else tc.ppo_epochs)
    K = max(K, 1)
    loss_fn = make_pg_loss(cfg, tc, remat=remat, with_entropy=with_entropy)
    if lr_fn is None:
        lr_fn = lambda step: jnp.asarray(tc.learning_rate, jnp.float32)

    def update(params, opt_state, batch: Batch, step):
        lr = lr_fn(step)

        def epoch(carry, _):
            params, opt_state = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads, gnorm = clip_by_global_norm(grads, tc.max_grad_norm)
            new_params, new_opt = adamw_update(
                params, grads, opt_state, lr=lr, beta1=tc.beta1,
                beta2=tc.beta2, eps=tc.eps, weight_decay=tc.weight_decay)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
            return (new_params, new_opt), metrics

        (params, opt_state), ms = jax.lax.scan(
            epoch, (params, opt_state), None, length=K)
        metrics = {k: v[-1] for k, v in ms.items()}
        return params, opt_state, metrics

    return update
