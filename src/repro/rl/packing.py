"""Sequence packing for the training hot path.

TreePO's segment-wise tree sampling produces trajectories of wildly
varying depth — early-stopped paths are a few segments long while
max-depth survivors fill the whole ``(N, L)`` bucket row — so the dense
per-trajectory-per-row pack burns a large fraction of its fwd/bwd FLOPs
on pad tokens.  This module bins multiple short trajectories into each
row (first-fit-decreasing on total length) and derives, on device, the
per-token tensors the PPO loss needs to treat each packed *segment* as
an independent trajectory:

* ``segment_ids`` (N, L)  — which segment a token belongs to (-1 = pad);
  fed to the attention mask so no token attends across a segment
  boundary;
* ``positions`` (N, L)    — RoPE positions, reset to 0 at each segment
  start (a packed segment sees exactly the positions its unpacked row
  would);
* ``response_mask`` (N, L) / ``advantages`` (N, L) — response-token mask
  and the per-segment advantage broadcast over that segment's response
  span.

Only the compact tables cross the host->device boundary: ``(N, L)``
tokens + rollout logprobs and three ``(N, S)`` per-segment tables
(prompt lengths, response lengths, advantages).  Everything dense is
derived inside the jitted update (``repro.rl.update`` with
``packed=True``) — the same compact-pack discipline PR 3 introduced for
the unpacked path, now amortized over multiple trajectories per row.

The unpacked path (``RolloutBatch`` + ``RLTrainer.update``) stays as
the parity oracle: a packed batch must produce the same loss and the
same parameter update as its unpacked twin (tests/test_train_hotpath).

Packing is exact for ALL architectures (:func:`packing_supported`):
attention layers mask cross-segment pairs, SSM/RWKV layers zero their
carried recurrent/token-shift state at segment starts (the
``segment_ids`` argument of the scan kernels), a modality prefix is a
``SHARED_SEGMENT_ID`` kv block every segment may attend, and encoder
cross-attention conditions all of a row's segments on the row's
encoder output by convention (documented in docs/architecture.md).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


def packing_supported(cfg) -> bool:
    """Whether sequence packing is *exact* for this architecture.

    True for every architecture since the segment-reset kernels landed:
    attention layers are segment-masked, Mamba/RWKV scan kernels zero
    their carried state at packed-segment starts, a modality prefix
    rides along as a shared kv segment, and encoder cross-attention
    shares the row's conditioning across its segments by convention.
    That convention is a CALLER contract for conditioned batches:
    modality tensors are per-row, so whoever packs trajectories that
    carry ``enc_frames`` / ``prefix_embeds`` must co-bin
    same-conditioning trajectories into each row
    (:func:`first_fit_decreasing` bins by length only; the trainer's
    own batches are text-only, and the pjit specs ship one conditioning
    tensor per row by construction).
    Kept as the single gate the trainer, the pjit ``train_4k`` input
    specs and the step function all consult, so a future layer kind
    without a reset path can fall back to the dense layout in one
    place."""
    del cfg
    return True


def first_fit_decreasing(lengths: Sequence[int], capacity: int
                         ) -> List[List[int]]:
    """Greedy FFD bin packing: sort items by length (desc), place each in
    the first row with room, open a new row otherwise.

    An item longer than ``capacity`` gets a dedicated row (the caller's
    bucket length then grows to cover it); it is never truncated.
    Returns a list of rows, each a list of item indices in placement
    order (the order segments are laid out left-to-right in the row).
    """
    order = sorted(range(len(lengths)), key=lambda i: (-lengths[i], i))
    rows: List[List[int]] = []
    space: List[int] = []
    for i in order:
        n = lengths[i]
        for r in range(len(rows)):
            if space[r] >= n:
                rows[r].append(i)
                space[r] -= n
                break
        else:
            rows.append([i])
            space.append(max(capacity - n, 0))
    return rows


def fill_packed_rows(prompts: Sequence, responses: Sequence,
                     packing_rows: Sequence[Sequence[int]], length: int, *,
                     num_rows: int, seg_slots: int, pad_token: int
                     ) -> Tuple:
    """Lay FFD rows out contiguously from column 0 — the ONE fill loop
    shared by ``RLTrainer.build_batch_packed`` and the packed BC warmup.

    ``prompts[j]`` / ``responses[j]`` are the j-th item's token
    sequences; ``packing_rows`` is ``first_fit_decreasing``'s output.
    Returns (tokens (num_rows, length), seg_prompt_lens,
    seg_resp_lens (num_rows, seg_slots), placements) where placements
    lists ``(row, slot, item_index, column_offset)`` so callers can
    scatter per-item extras (rollout logprobs, advantages, rewards)
    into the same layout."""
    tokens = np.full((num_rows, length), pad_token, np.int32)
    seg_p = np.zeros((num_rows, seg_slots), np.int32)
    seg_r = np.zeros((num_rows, seg_slots), np.int32)
    placements = []
    for i, members in enumerate(packing_rows):
        off = 0
        for s, j in enumerate(members):
            p, r = prompts[j], responses[j]
            tokens[i, off: off + len(p)] = p
            tokens[i, off + len(p): off + len(p) + len(r)] = r
            seg_p[i, s], seg_r[i, s] = len(p), len(r)
            placements.append((i, s, j, off))
            off += len(p) + len(r)
    return tokens, seg_p, seg_r, placements


def bucket_segments(n: int, quantum: int = 2) -> int:
    """Pad the per-row segment-table width to a small bucket (multiples
    of ``quantum``) so the packed update's compile cache is keyed by few
    distinct (N, L, S) shapes."""
    return max(quantum, -(-n // quantum) * quantum)


def packed_row_tensors(seg_prompt_lens, seg_resp_lens, length: int, xp=np
                       ) -> Tuple:
    """Derive (segment_ids, positions, response_mask) from the compact
    per-segment tables — the ONE definition shared by the on-device
    packed update (xp=jnp) and host-side inspection views (xp=np).

    seg_prompt_lens / seg_resp_lens: (N, S) int32, zero-padded (a
    zero-total segment is a pad slot).  Segments occupy the row
    contiguously from column 0.  Returns:

      segment_ids   (N, L) int32, -1 on pad columns
      positions     (N, L) int32, within-segment position (0 on pads)
      response_mask (N, L) float32, 1 on generated tokens
    """
    plens = seg_prompt_lens.astype(xp.int32)
    tot = plens + seg_resp_lens.astype(xp.int32)          # (N, S)
    ends = xp.cumsum(tot, axis=1)                         # (N, S)
    starts = ends - tot
    t = xp.arange(length, dtype=xp.int32)[None, :, None]  # (1, L, 1)
    in_seg = (t >= starts[:, None, :]) & (t < ends[:, None, :])  # (N, L, S)
    in_i = in_seg.astype(xp.int32)
    valid = in_seg.any(axis=2)                            # (N, L)
    sid = xp.where(valid, xp.argmax(in_seg, axis=2), -1).astype(xp.int32)
    seg_start = (in_i * starts[:, None, :]).sum(axis=2)   # (N, L)
    seg_prompt = (in_i * plens[:, None, :]).sum(axis=2)
    pos = xp.where(valid,
                   xp.arange(length, dtype=xp.int32)[None, :] - seg_start,
                   0).astype(xp.int32)
    rmask = (valid & (pos >= seg_prompt)).astype(xp.float32)
    return sid, pos, rmask


def packed_batch_tensors(seg_prompt_lens, seg_resp_lens, seg_adv,
                         length: int, xp=np) -> Tuple:
    """packed_row_tensors + the per-segment advantage broadcast over each
    segment's response span: returns (segment_ids, positions,
    response_mask, advantages), all (N, L)."""
    sid, pos, rmask = packed_row_tensors(seg_prompt_lens, seg_resp_lens,
                                         length, xp=xp)
    S = seg_adv.shape[1]
    onehot = (sid[:, :, None] ==
              xp.arange(S, dtype=xp.int32)[None, None, :])     # (N, L, S)
    adv = (onehot.astype(xp.float32) *
           seg_adv[:, None, :].astype(xp.float32)).sum(axis=2) * rmask
    return sid, pos, rmask, adv


@dataclasses.dataclass
class PackedRolloutBatch:
    """Compact sequence-packed host-side batch for the PG update.

    Only ``tokens`` / ``logprobs_old`` (N, L) and the three (N, S)
    per-segment tables are shipped to the device (``host_pack_bytes``);
    ``segment_ids`` / ``positions`` / ``response_mask`` / ``advantages``
    below are lazy *inspection* views for tests and metrics — the hot
    path derives them on device inside the jitted packed update.
    """

    tokens: np.ndarray           # (N, L) packed prompt+response rows
    logprobs_old: np.ndarray     # (N, L) rollout logprobs (0 elsewhere)
    seg_prompt_lens: np.ndarray  # (N, S) int32, 0 = pad segment
    seg_resp_lens: np.ndarray    # (N, S) int32
    seg_adv: np.ndarray          # (N, S) per-trajectory advantage
    seg_rewards: np.ndarray      # (N, S) terminal rewards (metrics only)
    num_queries: int = 0
    num_trajectories: int = 0
    mean_response_len: float = 0.0
    leaf_rate: float = 0.0
    host_pack_bytes: int = 0
    padded_rows: int = 0         # Nb: row-bucket the update really runs

    @classmethod
    def empty(cls) -> "PackedRolloutBatch":
        z2 = np.zeros((0, 1), np.int32)
        zs = np.zeros((0, 1), np.int32)
        return cls(z2, np.zeros((0, 1), np.float32), zs, zs.copy(),
                   np.zeros((0, 1), np.float32), np.zeros((0, 1),
                                                          np.float32))

    @property
    def segment_ids(self) -> np.ndarray:
        sid, _, _ = packed_row_tensors(self.seg_prompt_lens,
                                       self.seg_resp_lens,
                                       self.tokens.shape[1])
        return sid

    @property
    def positions(self) -> np.ndarray:
        _, pos, _ = packed_row_tensors(self.seg_prompt_lens,
                                       self.seg_resp_lens,
                                       self.tokens.shape[1])
        return pos

    @property
    def response_mask(self) -> np.ndarray:
        _, _, rmask = packed_row_tensors(self.seg_prompt_lens,
                                         self.seg_resp_lens,
                                         self.tokens.shape[1])
        return rmask

    @property
    def advantages(self) -> np.ndarray:
        _, _, _, adv = packed_batch_tensors(
            self.seg_prompt_lens, self.seg_resp_lens, self.seg_adv,
            self.tokens.shape[1])
        return adv

    @property
    def rewards(self) -> np.ndarray:
        """(num_trajectories,) flat rewards of the real segments."""
        real = (self.seg_prompt_lens + self.seg_resp_lens) > 0
        return self.seg_rewards[real]

    @property
    def padded_token_fraction(self) -> float:
        """Fraction of the token grid the jitted update really runs
        (``max(N, padded_rows)`` × L — row-bucket padding included)
        occupied by pad tokens — the FLOP-waste metric packing exists
        to shrink."""
        n, L = self.tokens.shape
        n = max(n, self.padded_rows)
        if n == 0 or L == 0:
            return 0.0
        used = int((self.seg_prompt_lens + self.seg_resp_lens).sum())
        return 1.0 - used / float(n * L)
