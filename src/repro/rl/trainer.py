"""RL post-training loop — the paper's three main configurations (Table 1):

  grpo        sequential sampling + GRPO advantage (Eq. 2)
  grpo_tree   TreePO sampling     + GRPO advantage ("GRPO w/ TreePO Sampling")
  treepo      TreePO sampling     + tree advantage (Eq. 5, + variants)

Pipeline per step (paper §3.1): oversample queries (3×bsz) → rollout →
verifiable reward → DAPO dynamic-sampling filter (0 < #correct < G) →
advantage → K epochs of the clipped token-level PG update (Eq. 1) with
AdamW (lr 1e-6, 10 warmup steps) — all from a base (untrained) model,
the "RL-zero" setting the paper emphasizes.

The training half is a device-resident hot path (the twin of the
device-resident decode loop):

* rewards are memoized per trajectory at sampling time (``score_fn``) —
  each path is decoded + verified exactly once, ever;
* the advantage for ALL kept queries is ONE jitted
  ``batch_treepo_advantage`` dispatch over padded (Q, G[, J]) tensors
  whose ancestor rows were recorded incrementally during sampling;
* the host packs only the compact batch — (N, L) tokens + rollout
  logprobs, (N,) lengths and per-trajectory advantages; response masks,
  token-broadcast advantages and the REINFORCE++ global normalization
  are derived on device inside the update;
* all K ppo epochs run in ONE jitted call per (N, L) bucket
  (``lax.scan`` carry, donated params/opt-state buffers);
* with ``TrainConfig.pack_sequences`` the batch is *sequence-packed*
  (``repro.rl.packing``): multiple short trajectories share one (N, L)
  row (first-fit-decreasing), the host ships only (N, L) tokens +
  logprobs and three (N, S) per-segment tables, and the jitted update
  derives segment-masked attention, per-segment RoPE resets (and
  SSM/RWKV state resets — packing is exact for every arch, hybrids
  included), masks and advantages on device — shrinking the pad-token
  fraction the tree's mixed-depth trajectories otherwise burn;
* the rollout-logprobs plane is donated per (N, L) bucket alongside
  params/opt-state, so the largest f32 batch input is reused in place
  instead of staying live next to the update's scratch (the returned
  alias is dropped immediately — only ``_donated_lp_buckets`` records
  which buckets donate, for tests/observability).

The previous per-tree / per-epoch host loop is kept as
``build_batch_legacy`` / ``update_legacy`` — the parity reference for
tests and the "before" side of ``benchmarks/train_hotpath.py``; the
unpacked ``build_batch`` / ``update`` pair plays the same oracle role
for the packed path.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig, TreeConfig
from repro.core import advantage as adv_mod
from repro.core import faults
from repro.core.engine import TreeEngine
from repro.core.guard import annotated_transfer
from repro.core.loss import token_logprobs_from_logits
from repro.core.sampler import sample_sequential, sample_trees
from repro.core.tree import (
    Path,
    QueryTree,
    Status,
    ancestor_matrix,
    batch_group_tensors,
)
from repro.data.reward import reward_fn
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import forward, init_params
from repro.optim import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    warmup_constant_schedule,
)
from repro.rl.packing import (
    PackedRolloutBatch,
    bucket_segments,
    fill_packed_rows,
    first_fit_decreasing,
    packed_row_tensors,
    packing_supported,
)
from repro.rl.update import make_pg_loss, make_ppo_update


class TrainerMode(str, enum.Enum):
    GRPO = "grpo"
    GRPO_TREE = "grpo_tree"
    TREEPO = "treepo"


@dataclasses.dataclass
class RolloutBatch:
    """Compact host-side batch for the PG update.

    Only these arrays cross to the device (``host_pack_bytes``); the
    dense (N, L) response mask and token-broadcast advantages are
    derived on device inside the jitted update.  ``response_mask`` /
    ``advantages`` below are lazy *inspection* views for tests, metrics
    and the legacy comparison — the hot path never materializes them.
    """

    tokens: np.ndarray          # (N, L) prompt+response, right-padded
    prompt_lens: np.ndarray     # (N,) int32 prompt token counts
    resp_lens: np.ndarray       # (N,) int32 response token counts
    logprobs_old: np.ndarray    # (N, L) rollout logprobs (0 elsewhere)
    adv_traj: np.ndarray        # (N,) per-trajectory advantage (pre-norm)
    rewards: np.ndarray         # (N,)
    num_queries: int = 0
    mean_response_len: float = 0.0
    leaf_rate: float = 0.0
    host_pack_bytes: int = 0    # bytes shipped host->device for the update
    padded_rows: int = 0        # Nb: row-bucket the update really runs

    @classmethod
    def empty(cls) -> "RolloutBatch":
        return cls(np.zeros((0, 1), np.int32), np.zeros((0,), np.int32),
                   np.zeros((0,), np.int32), np.zeros((0, 1), np.float32),
                   np.zeros((0,), np.float32), np.zeros((0,), np.float32))

    @property
    def response_mask(self) -> np.ndarray:
        """(N, L) dense view: 1 on generated tokens."""
        return _response_mask_from_lens(self.prompt_lens, self.resp_lens,
                                        self.tokens.shape[1])

    @property
    def advantages(self) -> np.ndarray:
        """(N, L) dense view: per-trajectory advantage broadcast over its
        response tokens (before global normalization)."""
        return self.adv_traj[:, None] * self.response_mask

    @property
    def padded_token_fraction(self) -> float:
        """Fraction of the token grid the jitted update really runs
        (``max(N, padded_rows)`` × L — row-bucket padding included)
        occupied by pad tokens — the waste sequence packing
        (PackedRolloutBatch) shrinks."""
        n, L = self.tokens.shape
        n = max(n, self.padded_rows)
        if n == 0 or L == 0:
            return 0.0
        used = int((self.prompt_lens + self.resp_lens).sum())
        return 1.0 - used / float(n * L)


@dataclasses.dataclass
class LegacyRolloutBatch:
    """Dense batch produced by the pre-refactor host loop (parity /
    benchmark reference only)."""

    tokens: np.ndarray
    response_mask: np.ndarray
    logprobs_old: np.ndarray
    advantages: np.ndarray
    rewards: np.ndarray
    num_queries: int = 0
    host_pack_bytes: int = 0


def _bucket_len(n: int, quantum: int = 64) -> int:
    return max(quantum, -(-n // quantum) * quantum)


def _response_mask_from_lens(prompt_lens, resp_lens, length: int, xp=np):
    """(N, L) mask with 1 on generated tokens, derived from per-row
    lengths — the ONE definition shared by the on-device update
    (xp=jnp) and the host-side inspection view (xp=np)."""
    pos = xp.arange(length)[None, :]
    lo = prompt_lens[:, None]
    hi = (prompt_lens + resp_lens)[:, None]
    return ((pos >= lo) & (pos < hi)).astype(xp.float32)


def _bucket_rows(n: int, quantum: int = 4, pow2_from: int = 32) -> int:
    """Pad the batch dimension to a bucket so the per-(N, L) update
    compile cache stays small: fine-grained (multiples of ``quantum``)
    for small batches — padding a 4-row batch to 8 would double the
    fwd/bwd compute — and powers of two beyond ``pow2_from``."""
    if n <= pow2_from:
        return max(quantum, -(-n // quantum) * quantum)
    b = pow2_from
    while b < n:
        b *= 2
    return b


class RLTrainer:
    """Single-replica RL trainer (the distributed variant lives in
    repro.launch: same update function under pjit)."""

    def __init__(self, cfg: ModelConfig, train_cfg: TrainConfig,
                 tree_cfg: TreeConfig,
                 mode: TrainerMode = TrainerMode.TREEPO, *,
                 seed: int = 0, engine_kwargs: Optional[Dict] = None,
                 data_seed: int = 0, min_difficulty: int = 1,
                 max_difficulty: int = 2):
        self.cfg = cfg
        self.train_cfg = train_cfg
        self.tree_cfg = tree_cfg
        self.mode = TrainerMode(mode)
        self.tok = ByteTokenizer()
        if cfg.vocab_size < self.tok.vocab_size:
            raise ValueError("model vocab too small for the byte tokenizer")
        if train_cfg.pack_sequences and not packing_supported(cfg):
            # the gate is universally true today (segment-reset kernels);
            # kept so a future non-resettable layer kind fails loudly
            raise ValueError(
                f"pack_sequences is not exact for {cfg.name} "
                "(repro.rl.packing.packing_supported) — train unpacked")
        key = jax.random.PRNGKey(seed)
        self.params = init_params(key, cfg)
        self.opt_state = adamw_init(self.params)
        self.lr_fn = warmup_constant_schedule(train_cfg.learning_rate,
                                              train_cfg.warmup_steps)
        self.gen = MathTaskGenerator(data_seed, min_difficulty,
                                     max_difficulty)
        self.engine_kwargs = dict(engine_kwargs or {})
        self._update_fns: Dict[Tuple[int, int], Any] = {}
        self._packed_update_fns: Dict[Tuple[int, int, int], Any] = {}
        self._legacy_update_fns: Dict[Tuple[int, int], Any] = {}
        # buckets whose jitted update donated the rollout-logprobs plane
        # (keys only — retaining the returned alias would pin one
        # (Nb, L) f32 buffer per bucket and undo the donation's point;
        # in-place reuse is proven by the compile-time aliasing tests)
        self._donated_lp_buckets: set = set()
        self.step = 0
        self.metrics_log: List[Dict[str, float]] = []
        self._rng = np.random.default_rng(seed)
        import random as _random
        self._pyrng = _random.Random(seed)

    # -- crash-safe state (docs/robustness.md) -----------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """Complete resumable training state.

        Covers every source of run-to-run divergence: params, optimizer
        moments, the step counter, the metrics cursor (how many rows of
        the JSONL stream were already emitted), and all three host RNGs —
        ``_rng`` (numpy; also seeds each rollout engine's device keys, so
        capturing it captures device sampling), ``_pyrng`` (tree
        branching), and ``gen.rng`` (task generation).  RNG states are
        pickled to bytes: numpy's PCG64 state carries 128-bit ints that
        overflow msgpack, and ``random.Random`` state is a nested tuple —
        an opaque bytes blob round-trips both exactly.
        """
        import pickle

        return {
            "params": self.params,
            "opt_state": self.opt_state,
            "step": int(self.step),
            "metrics_cursor": len(self.metrics_log),
            "np_rng": pickle.dumps(self._rng.bit_generator.state),
            "py_rng": pickle.dumps(self._pyrng.getstate()),
            "gen_rng": pickle.dumps(self.gen.rng.getstate()),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore :meth:`state_dict` output — the next ``train_step``
        is bit-identical to the one an uninterrupted run would take."""
        import pickle

        self.params = state["params"]
        # the checkpoint skeleton round-trips NamedTuples as plain tuples
        self.opt_state = AdamWState(*state["opt_state"])
        self.step = int(state["step"])
        del self.metrics_log[int(state["metrics_cursor"]):]
        self._rng.bit_generator.state = pickle.loads(state["np_rng"])
        self._pyrng.setstate(pickle.loads(state["py_rng"]))
        self.gen.rng.setstate(pickle.loads(state["gen_rng"]))

    # -- engine ----------------------------------------------------------------

    def _make_engine(self) -> TreeEngine:
        """Fresh engine view over the *current* params (on-policy rollout)."""
        return TreeEngine(self.params, self.cfg, self.tree_cfg,
                          seed=int(self._rng.integers(2 ** 31)),
                          **self.engine_kwargs)

    # -- rollout ---------------------------------------------------------------

    def _sample_queries(self, n: int):
        samples = self.gen.batch(n)
        prompts = [self.tok.encode(s.query, bos=True) for s in samples]
        return samples, prompts

    def _score_path(self, tree: QueryTree, path: Path) -> float:
        """Terminal reward for one finished LEAF trajectory (invoked once
        per path, at finish time — the memoized score)."""
        return reward_fn(self.tok.decode(path.tokens), tree.target,
                         shaping=self.train_cfg.reward_shaping)

    def rollout(self, num_queries: int, progress: float = 0.0
                ) -> Tuple[List[QueryTree], TreeEngine]:
        samples, prompts = self._sample_queries(num_queries)
        engine = self._make_engine()
        targets = [s.answer for s in samples]
        if self.mode == TrainerMode.GRPO:
            trees, _ = sample_sequential(engine, prompts, targets,
                                         rng=self._pyrng,
                                         progress=progress,
                                         score_fn=self._score_path)
        else:
            trees, _ = sample_trees(engine, prompts, targets,
                                    rng=self._pyrng, progress=progress,
                                    score_fn=self._score_path)
        return trees, engine

    # -- reward + advantage ------------------------------------------------------

    def _tree_rewards(self, tree: QueryTree) -> np.ndarray:
        """Memoized per-path rewards (scored at sampling time via
        ``score_fn``; this only fills in paths from trees sampled without
        one — tests / external callers)."""
        for p in tree.finished:
            if p.reward is None:
                p.reward = 0.0 if p.status == Status.FAILED else \
                    self._score_path(tree, p)
        return tree.rewards()

    @property
    def _advantage_variant(self) -> str:
        return (self.train_cfg.advantage_kind
                if self.mode == TrainerMode.TREEPO else "grpo")

    @property
    def _use_global_norm(self) -> bool:
        return (self.train_cfg.global_norm
                and self.mode == TrainerMode.TREEPO
                and self.train_cfg.advantage_kind != "grpo")

    def _kept_trees(self, trees: List[QueryTree]
                    ) -> List[Tuple[QueryTree, np.ndarray]]:
        """Reward + DAPO dynamic-sampling filter (rewards memoized)."""
        kept = []
        for tree in trees:
            if not tree.finished:
                continue
            rewards = self._tree_rewards(tree)
            if self.train_cfg.dynamic_sampling and rewards.std() <= 1e-6:
                continue  # DAPO: drop all-correct / all-wrong groups
            kept.append((tree, rewards))
        return kept

    def _advantage_rows(self, trees: List[QueryTree]):
        """Reward + DAPO filter + ONE batched advantage dispatch.

        Returns (kept, rows) with rows = [(prompt, resp, logprobs,
        reward, advantage), ...] — the per-trajectory material both the
        unpacked and the packed pack layouts are built from.
        """
        kept = self._kept_trees(trees)
        if not kept:
            return kept, []
        # bucket Q and pad G to the width cap so the jitted advantage
        # dispatch compiles once per bucket, not once per (Q, G) combo
        anc, rew_qg, gmask = batch_group_tensors(
            [t for t, _ in kept], self.tree_cfg.max_depth,
            group_pad=self.tree_cfg.max_width,
            query_pad=_bucket_rows(len(kept)))
        rew_qg, anc, gmask = annotated_transfer(
            (rew_qg, anc, gmask), to="device", reason="advantage-pack")
        adv_qg = annotated_transfer(adv_mod.batch_treepo_advantage(
            rew_qg, anc, gmask,
            variant=self._advantage_variant, use_global_norm=False),
            reason="advantage-rows")

        rows = []
        for qi, (tree, rewards) in enumerate(kept):
            for gi, (p, r) in enumerate(zip(tree.finished, rewards)):
                rows.append((tree.prompt_tokens, p.tokens, p.logprobs,
                             float(r), float(adv_qg[qi, gi])))
        return kept, rows

    def build_batch(self, trees: List[QueryTree]) -> RolloutBatch:
        """Reward, dynamic-sampling filter, ONE batched advantage
        dispatch, compact fixed-shape pack."""
        kept, rows = self._advantage_rows(trees)
        if not rows:
            return RolloutBatch.empty()
        L = _bucket_len(max(len(pr) + len(t) for pr, t, *_ in rows))
        N = len(rows)
        tokens = np.full((N, L), ByteTokenizer.PAD, np.int32)
        prompt_lens = np.zeros((N,), np.int32)
        resp_lens = np.zeros((N,), np.int32)
        lp_old = np.zeros((N, L), np.float32)
        adv_traj = np.zeros((N,), np.float32)
        rew = np.zeros((N,), np.float32)
        n_leaves = 0
        for i, (prompt, resp, lps, r, a) in enumerate(rows):
            n_p, n_r = len(prompt), len(resp)
            tokens[i, : n_p] = prompt
            tokens[i, n_p: n_p + n_r] = resp
            prompt_lens[i] = n_p
            resp_lens[i] = n_r
            lp_old[i, n_p: n_p + n_r] = lps
            adv_traj[i] = a
            rew[i] = r
        for tree, _ in kept:
            n_leaves += tree.num_leaves
        # what update() will actually ship: the ROW-PADDED (Nb, L)
        # buffers, not the unpadded (N, L) pack built here
        Nb = _bucket_rows(N)
        pack_bytes = Nb * (tokens.itemsize * L + lp_old.itemsize * L +
                           prompt_lens.itemsize + resp_lens.itemsize +
                           adv_traj.itemsize)
        return RolloutBatch(
            tokens=tokens, prompt_lens=prompt_lens, resp_lens=resp_lens,
            logprobs_old=lp_old, adv_traj=adv_traj, rewards=rew,
            num_queries=len(kept),
            mean_response_len=float(resp_lens.mean()),
            leaf_rate=n_leaves / max(sum(len(t.finished)
                                         for t, _ in kept), 1),
            host_pack_bytes=pack_bytes, padded_rows=Nb)

    def build_batch_packed(self, trees: List[QueryTree]
                           ) -> PackedRolloutBatch:
        """Sequence-packed twin of :meth:`build_batch`: same rewards /
        filter / batched advantage, then first-fit-decreasing packing of
        the trajectories into shared (N, L) rows with (N, S) per-segment
        tables (``repro.rl.packing``) instead of one row each."""
        kept, rows = self._advantage_rows(trees)
        if not rows:
            return PackedRolloutBatch.empty()
        totals = [len(pr) + len(t) for pr, t, *_ in rows]
        # pack into the SAME bucket length the unpacked layout would use,
        # so packing strictly reduces N at equal L
        L = _bucket_len(max(totals))
        packing_rows = first_fit_decreasing(totals, L)
        N = len(packing_rows)
        S = bucket_segments(max(len(r) for r in packing_rows))
        tokens, seg_plens, seg_rlens, placements = fill_packed_rows(
            [pr for pr, *_ in rows], [t for _, t, *_ in rows],
            packing_rows, L, num_rows=N, seg_slots=S,
            pad_token=ByteTokenizer.PAD)
        lp_old = np.zeros((N, L), np.float32)
        seg_adv = np.zeros((N, S), np.float32)
        seg_rew = np.zeros((N, S), np.float32)
        for i, s, j, off in placements:
            prompt, _, lps, r, a = rows[j]
            lp_old[i, off + len(prompt): off + len(prompt) + len(lps)] = lps
            seg_adv[i, s] = a
            seg_rew[i, s] = r
        n_leaves = sum(t.num_leaves for t, _ in kept)
        # what update_packed() will actually ship: the ROW-PADDED (Nb, ·)
        # buffers, not the unpadded pack built here
        Nb = _bucket_rows(N)
        pack_bytes = Nb * (tokens.itemsize * L + lp_old.itemsize * L +
                           S * (seg_plens.itemsize + seg_rlens.itemsize +
                                seg_adv.itemsize))
        return PackedRolloutBatch(
            tokens=tokens, logprobs_old=lp_old,
            seg_prompt_lens=seg_plens, seg_resp_lens=seg_rlens,
            seg_adv=seg_adv, seg_rewards=seg_rew,
            num_queries=len(kept), num_trajectories=len(rows),
            mean_response_len=float(np.mean([len(t) for _, t, *_ in rows])),
            leaf_rate=n_leaves / max(sum(len(t.finished)
                                         for t, _ in kept), 1),
            host_pack_bytes=pack_bytes, padded_rows=Nb)

    # -- update -----------------------------------------------------------------

    def _get_update_fn(self, N: int, L: int):
        """One jitted K-epoch update per (N, L) bucket: derives the dense
        mask/advantages on device, runs global normalization there, scans
        the ppo epochs, and donates the params/opt-state buffers plus the
        rollout-logprobs plane (aliased back out as the 3rd result)."""
        key = (N, L)
        if key not in self._update_fns:
            base_update = make_ppo_update(self.cfg, self.train_cfg,
                                          lr_fn=self.lr_fn,
                                          donate_logprobs=True)
            apply_global = self._use_global_norm

            def update(params, opt_state, tokens, prompt_lens, resp_lens,
                       lp_old, adv_traj, step):
                rmask = _response_mask_from_lens(
                    prompt_lens, resp_lens, tokens.shape[1], xp=jnp)
                advs = adv_traj[:, None] * rmask
                if apply_global:
                    advs = adv_mod.global_normalize(advs, rmask)
                batch = {"tokens": tokens, "response_mask": rmask,
                         "logprobs_old": lp_old, "advantages": advs}
                return base_update(params, opt_state, batch, step)

            self._update_fns[key] = jax.jit(update,
                                            donate_argnums=(0, 1, 5))
        return self._update_fns[key]

    def update(self, batch: RolloutBatch) -> Dict[str, float]:
        """All K ppo epochs in one jitted dispatch (per (N, L) bucket)."""
        N = batch.tokens.shape[0]
        if N == 0:
            return {"skipped": 1.0}
        L = batch.tokens.shape[1]
        Nb = _bucket_rows(N)
        tokens = np.full((Nb, L), ByteTokenizer.PAD, np.int32)
        tokens[:N] = batch.tokens
        prompt_lens = np.zeros((Nb,), np.int32)
        prompt_lens[:N] = batch.prompt_lens
        resp_lens = np.zeros((Nb,), np.int32)   # padded rows: empty mask
        resp_lens[:N] = batch.resp_lens
        lp_old = np.zeros((Nb, L), np.float32)
        lp_old[:N] = batch.logprobs_old
        # fault-injection site: poisoning one response-position logprob
        # NaNs the loss/grads inside the jitted scan, which the
        # nonfinite guard must absorb (tests/test_faults.py)
        lp_old = faults.corrupt_array("trainer.batch_logprobs", lp_old,
                                      col=int(batch.prompt_lens[0]))
        adv_traj = np.zeros((Nb,), np.float32)
        adv_traj[:N] = batch.adv_traj
        fn = self._get_update_fn(Nb, L)
        pack = annotated_transfer(
            (tokens, prompt_lens, resp_lens, lp_old, adv_traj,
             np.asarray(self.step, np.int32)),
            to="device", reason="update-pack")
        self.params, self.opt_state, _, m = fn(
            self.params, self.opt_state, *pack)
        self._donated_lp_buckets.add((Nb, L))
        m = annotated_transfer(m, reason="update-metrics")
        return {k: float(v) for k, v in m.items()}

    def _get_packed_update_fn(self, N: int, L: int, S: int):
        """One jitted K-epoch update per (N, L, S) bucket over the
        sequence-packed compact layout: segment-ids / RoPE positions /
        masks / advantages (+ optional global norm) all derived on
        device by ``repro.rl.update`` with ``packed=True``.  Flat
        arguments so exactly params / opt-state / rollout logprobs are
        donated (a donated dict would drag the int32 tables along)."""
        key = (N, L, S)
        if key not in self._packed_update_fns:
            base = make_ppo_update(self.cfg, self.train_cfg,
                                   lr_fn=self.lr_fn, packed=True,
                                   use_global_norm=self._use_global_norm,
                                   donate_logprobs=True)

            def update(params, opt_state, tokens, lp_old, seg_plens,
                       seg_rlens, seg_adv, step):
                batch = {"tokens": tokens, "logprobs_old": lp_old,
                         "seg_prompt_lens": seg_plens,
                         "seg_resp_lens": seg_rlens, "seg_adv": seg_adv}
                return base(params, opt_state, batch, step)

            self._packed_update_fns[key] = jax.jit(
                update, donate_argnums=(0, 1, 3))
        return self._packed_update_fns[key]

    def update_packed(self, batch: PackedRolloutBatch) -> Dict[str, float]:
        """All K ppo epochs in one jitted dispatch per (N, L, S) bucket
        over a sequence-packed batch (rows padded with zero-width
        segments, invisible to the loss)."""
        N = batch.tokens.shape[0]
        if N == 0:
            return {"skipped": 1.0}
        L = batch.tokens.shape[1]
        S = batch.seg_prompt_lens.shape[1]
        Nb = _bucket_rows(N)
        tokens = np.full((Nb, L), ByteTokenizer.PAD, np.int32)
        tokens[:N] = batch.tokens
        lp_old = np.zeros((Nb, L), np.float32)
        lp_old[:N] = batch.logprobs_old
        # fault-injection site (see update()): poison the first response
        # token of row 0's first packed segment
        lp_old = faults.corrupt_array(
            "trainer.batch_logprobs", lp_old,
            col=int(batch.seg_prompt_lens[0, 0]))
        seg_plens = np.zeros((Nb, S), np.int32)   # padded rows: 0-width segs
        seg_plens[:N] = batch.seg_prompt_lens
        seg_rlens = np.zeros((Nb, S), np.int32)
        seg_rlens[:N] = batch.seg_resp_lens
        seg_adv = np.zeros((Nb, S), np.float32)
        seg_adv[:N] = batch.seg_adv
        fn = self._get_packed_update_fn(Nb, L, S)
        pack = annotated_transfer(
            (tokens, lp_old, seg_plens, seg_rlens, seg_adv,
             np.asarray(self.step, np.int32)),
            to="device", reason="update-pack")
        self.params, self.opt_state, _, m = fn(
            self.params, self.opt_state, *pack)
        self._donated_lp_buckets.add((Nb, L, S))
        m = annotated_transfer(m, reason="update-metrics")
        return {k: float(v) for k, v in m.items()}

    # -- legacy reference path ---------------------------------------------------
    #
    # The pre-refactor host loop: per-tree unjitted advantage calls, dense
    # (N, L) host packing (mask + broadcast advantages + host-side global
    # norm) and one jitted dispatch per ppo epoch.  Kept verbatim as the
    # parity oracle for tests and the "before" side of
    # benchmarks/train_hotpath.py.  Not used by train_step.

    def _tree_advantages_legacy(self, tree: QueryTree,
                                rewards: np.ndarray) -> np.ndarray:
        variant = self._advantage_variant
        if variant == "grpo":
            r_dev = annotated_transfer(rewards, to="device",
                                       reason="legacy-advantage")
            return annotated_transfer(adv_mod.grpo_advantage(r_dev),
                                      reason="legacy-advantage")
        anc = ancestor_matrix(tree.finished, self.tree_cfg.max_depth)
        r_dev, anc_dev = annotated_transfer(
            (rewards, anc), to="device", reason="legacy-advantage")
        return annotated_transfer(
            adv_mod.treepo_advantage(r_dev, anc_dev, variant=variant),
            reason="legacy-advantage")

    def build_batch_legacy(self, trees: List[QueryTree]
                           ) -> LegacyRolloutBatch:
        kept: List[Tuple[QueryTree, np.ndarray, np.ndarray]] = []
        for tree, rewards in self._kept_trees(trees):
            advs = self._tree_advantages_legacy(tree, rewards)
            kept.append((tree, rewards, advs))
        if not kept:
            return LegacyRolloutBatch(
                np.zeros((0, 1), np.int32), np.zeros((0, 1), np.float32),
                np.zeros((0, 1), np.float32), np.zeros((0, 1), np.float32),
                np.zeros((0,), np.float32))
        rows = []
        for tree, rewards, advs in kept:
            for p, r, a in zip(tree.finished, rewards, advs):
                rows.append((tree.prompt_tokens, p.tokens, p.logprobs,
                             float(r), float(a)))
        L = _bucket_len(max(len(pr) + len(t) for pr, t, *_ in rows))
        N = len(rows)
        tokens = np.full((N, L), ByteTokenizer.PAD, np.int32)
        rmask = np.zeros((N, L), np.float32)
        lp_old = np.zeros((N, L), np.float32)
        advsb = np.zeros((N, L), np.float32)
        rew = np.zeros((N,), np.float32)
        for i, (prompt, resp, lps, r, a) in enumerate(rows):
            n_p, n_r = len(prompt), len(resp)
            tokens[i, : n_p] = prompt
            tokens[i, n_p: n_p + n_r] = resp
            rmask[i, n_p: n_p + n_r] = 1.0
            lp_old[i, n_p: n_p + n_r] = lps
            advsb[i, n_p: n_p + n_r] = a
            rew[i] = r
        if self._use_global_norm:
            advs_dev, rmask_dev = annotated_transfer(
                (advsb, rmask), to="device", reason="legacy-globalnorm")
            advsb = annotated_transfer(
                adv_mod.global_normalize(advs_dev, rmask_dev),
                reason="legacy-globalnorm")
        pack_bytes = (tokens.nbytes + rmask.nbytes + lp_old.nbytes +
                      advsb.nbytes)
        return LegacyRolloutBatch(
            tokens=tokens, response_mask=rmask, logprobs_old=lp_old,
            advantages=advsb, rewards=rew, num_queries=len(kept),
            host_pack_bytes=pack_bytes)

    def _get_legacy_update_fn(self, N: int, L: int):
        key = (N, L)
        if key not in self._legacy_update_fns:
            loss_fn = make_pg_loss(self.cfg, self.train_cfg)
            tc = self.train_cfg

            def update(params, opt_state, tokens, rmask, lp_old, advs,
                       step):
                batch = {"tokens": tokens, "response_mask": rmask,
                         "logprobs_old": lp_old, "advantages": advs}
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                grads, gnorm = clip_by_global_norm(grads,
                                                   tc.max_grad_norm)
                lr = self.lr_fn(step)
                new_params, new_opt = adamw_update(
                    params, grads, opt_state, lr=lr, beta1=tc.beta1,
                    beta2=tc.beta2, eps=tc.eps,
                    weight_decay=tc.weight_decay)
                metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
                return new_params, new_opt, metrics

            self._legacy_update_fns[key] = jax.jit(update)
        return self._legacy_update_fns[key]

    def update_legacy(self, batch: LegacyRolloutBatch) -> Dict[str, float]:
        """Pre-refactor update: one jitted dispatch per ppo epoch, no
        donation, dense host-packed inputs re-shipped every epoch."""
        if batch.tokens.shape[0] == 0:
            return {"skipped": 1.0}
        N, L = batch.tokens.shape
        fn = self._get_legacy_update_fn(N, L)
        metrics: Dict[str, float] = {}
        for _ in range(self.train_cfg.ppo_epochs):
            # the legacy inefficiency under measurement is the re-ship
            # per epoch — annotated so the guard can tally its cost
            pack = annotated_transfer(
                (batch.tokens, batch.response_mask, batch.logprobs_old,
                 batch.advantages, np.asarray(self.step, np.int32)),
                to="device", reason="legacy-epoch-pack")
            self.params, self.opt_state, m = fn(
                self.params, self.opt_state, *pack)
            m = annotated_transfer(m, reason="update-metrics")
            metrics = {k: float(v) for k, v in m.items()}
        return metrics

    # -- outer loop ---------------------------------------------------------------

    def train_step(self, num_queries: Optional[int] = None,
                   progress: float = 0.0) -> Dict[str, float]:
        """One full RL iteration: oversampled rollout → filter → update.

        ``num_queries``: queries per *attempt* (default: batch_size); the
        paper oversamples 3× and resamples up to 2 extra rounds if dynamic
        sampling starves the batch.
        """
        t0 = time.time()
        nq = num_queries or self.train_cfg.batch_size
        all_trees: List[QueryTree] = []
        sample_tokens = 0
        rounds = 0
        target_queries = nq
        while rounds <= self.train_cfg.max_resample_rounds:
            want = (target_queries - self._count_kept(all_trees))
            if want <= 0:
                break
            n = want * (self.train_cfg.oversample_factor
                        if rounds == 0 else 1)
            trees, engine = self.rollout(n, progress)
            all_trees.extend(trees)
            sample_tokens += engine.stats.model_tokens
            rounds += 1
            if not self.train_cfg.dynamic_sampling:
                break
        if self.train_cfg.pack_sequences:
            batch = self.build_batch_packed(all_trees)
            metrics = self.update_packed(batch)
        else:
            batch = self.build_batch(all_trees)
            metrics = self.update(batch)
        self.step += 1
        rewards = batch.rewards
        metrics.update(
            step=self.step,
            reward_mean=float(rewards.mean()) if rewards.size else 0.0,
            num_trajectories=float(rewards.size),
            num_queries_kept=float(batch.num_queries),
            response_len=batch.mean_response_len,
            leaf_rate=batch.leaf_rate,
            host_pack_bytes=float(batch.host_pack_bytes),
            padded_token_fraction=batch.padded_token_fraction,
            sample_model_tokens=float(sample_tokens),
            wall_time=time.time() - t0,
        )
        self.metrics_log.append(metrics)
        return metrics

    def _count_kept(self, trees: List[QueryTree]) -> int:
        """Number of kept queries so far — memoized rewards make this a
        cache lookup, not a re-decode of every accumulated tree."""
        return len(self._kept_trees(trees))

    # -- behavior-cloning warmup ----------------------------------------------------
    #
    # The paper trains from the *pretrained* Qwen2.5-7B base model, which
    # already emits \boxed{} answers under few-shot prompting.  Our toy model
    # starts from random weights, so a short supervised warmup on synthetic
    # CoT traces stands in for "base model with a prior" (recorded as a
    # deviation in DESIGN.md §8).  RL proper then starts from this
    # checkpoint — still no *RL* signal is used here.

    def bc_warmup(self, steps: int = 100, batch_size: int = 16,
                  lr: float = 3e-3,
                  packed: Optional[bool] = None) -> Dict[str, float]:
        """Supervised CoT warmup.  ``packed=None`` follows
        ``TrainConfig.pack_sequences``: with packing on, the (query, cot)
        rows are FFD-binned into shared (N, L) rows and the CE loss runs
        over segment-masked attention + per-segment resets — the same
        token set and normalization as the dense layout, on fewer rows."""
        cfg = self.cfg
        packed = self.train_cfg.pack_sequences if packed is None else packed

        def ce_from(lp, m, aux):
            loss = -(lp * m).sum() / jnp.maximum(m.sum(), 1.0)
            if cfg.moe is not None:
                loss = loss + cfg.moe.aux_loss_coef * aux
            return loss

        def ce_loss(params, tokens, mask):
            logits, aux = forward(params, cfg, tokens)
            lp = token_logprobs_from_logits(logits[:, :-1], tokens[:, 1:])
            return ce_from(lp, mask[:, 1:], aux)

        def ce_loss_packed(params, tokens, seg_plens, seg_rlens):
            sid, pos, rmask = packed_row_tensors(
                seg_plens, seg_rlens, tokens.shape[1], xp=jnp)
            logits, aux = forward(params, cfg, tokens, positions=pos,
                                  segment_ids=sid)
            lp = token_logprobs_from_logits(logits[:, :-1], tokens[:, 1:])
            # boundary guard: never score a token against another
            # segment's last token (mirrors the packed PG loss)
            m = rmask[:, 1:] * (sid[:, 1:] == sid[:, :-1]).astype(
                jnp.float32)
            return ce_from(lp, m, aux)

        def _step(loss_fn):
            def run(params, opt_state, *batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
                grads, _ = clip_by_global_norm(grads, 1.0)
                new_params, new_opt = adamw_update(params, grads,
                                                   opt_state, lr=lr)
                return new_params, new_opt, loss
            return jax.jit(run, donate_argnums=(0, 1))

        bc_step = _step(ce_loss_packed if packed else ce_loss)

        L = None
        last = 0.0
        for it in range(steps):
            samples = self.gen.batch(batch_size)
            rows = []
            for s in samples:
                ids = (self.tok.encode(s.query, bos=True),
                       self.tok.encode(" " + s.cot, eos=True))
                rows.append(ids)
            maxlen = max(len(a) + len(b) for a, b in rows)
            if L is None or maxlen > L:
                L = _bucket_len(maxlen)
            if packed:
                lens = [len(q) + len(c) for q, c in rows]
                packing_rows = first_fit_decreasing(lens, L)
                toks, seg_plens, seg_rlens, _ = fill_packed_rows(
                    [q for q, _ in rows], [c for _, c in rows],
                    packing_rows, L,
                    num_rows=_bucket_rows(len(packing_rows)),
                    seg_slots=bucket_segments(
                        max(len(r) for r in packing_rows)),
                    pad_token=ByteTokenizer.PAD)
                pack = annotated_transfer(
                    (toks, seg_plens, seg_rlens), to="device",
                    reason="bc-pack")
                self.params, self.opt_state, loss = bc_step(
                    self.params, self.opt_state, *pack)
            else:
                toks = np.full((batch_size, L), ByteTokenizer.PAD,
                               np.int32)
                mask = np.zeros((batch_size, L), np.float32)
                for i, (q, c) in enumerate(rows):
                    toks[i, : len(q)] = q
                    toks[i, len(q): len(q) + len(c)] = c
                    mask[i, len(q): len(q) + len(c)] = 1.0
                pack = annotated_transfer((toks, mask), to="device",
                                          reason="bc-pack")
                self.params, self.opt_state, loss = bc_step(
                    self.params, self.opt_state, *pack)
            last = float(annotated_transfer(loss, reason="bc-loss"))
        # reset optimizer state for the RL phase (fresh moments)
        self.opt_state = adamw_init(self.params)
        return {"bc_loss": last, "bc_steps": float(steps),
                "bc_packed": float(packed)}

    # -- evaluation ----------------------------------------------------------------

    def evaluate(self, num_queries: int = 16, k: int = 4,
                 seed: int = 1234) -> Dict[str, float]:
        """maj@k accuracy on held-out synthetic tasks (paper's val metric)."""
        gen = MathTaskGenerator(seed, self.gen.min_difficulty,
                                self.gen.max_difficulty)
        samples = gen.batch(num_queries)
        prompts = [self.tok.encode(s.query, bos=True) for s in samples]
        eval_tree_cfg = dataclasses.replace(
            self.tree_cfg, max_width=k,
            init_divergence_low=k, init_divergence_high=k,
            branch_factor=1, fallback=False)
        engine = TreeEngine(self.params, self.cfg, eval_tree_cfg,
                            seed=seed, **self.engine_kwargs)
        trees, _ = sample_trees(engine, prompts,
                                [s.answer for s in samples],
                                eval_tree_cfg, rng=__import__(
                                    "random").Random(seed))
        from collections import Counter
        from repro.data.reward import extract_boxed, verify_answer
        correct = 0
        any_correct = 0
        for tree, s in zip(trees, samples):
            answers = []
            got_one = False
            for p in tree.finished:
                a = extract_boxed(self.tok.decode(p.tokens))
                if a is not None:
                    answers.append(a)
                    if verify_answer(a, s.answer):
                        got_one = True
            any_correct += int(got_one)
            if answers:
                maj = Counter(answers).most_common(1)[0][0]
                correct += int(verify_answer(maj, s.answer))
        return {"maj_acc": correct / num_queries,
                "pass_any": any_correct / num_queries}
