"""RL post-training loop — the paper's three main configurations (Table 1):

  grpo        sequential sampling + GRPO advantage (Eq. 2)
  grpo_tree   TreePO sampling     + GRPO advantage ("GRPO w/ TreePO Sampling")
  treepo      TreePO sampling     + tree advantage (Eq. 5, + variants)

Pipeline per step (paper §3.1): oversample queries (3×bsz) → rollout →
verifiable reward → DAPO dynamic-sampling filter (0 < #correct < G) →
advantage → K epochs of the clipped token-level PG update (Eq. 1) with
AdamW (lr 1e-6, 10 warmup steps) — all from a base (untrained) model,
the "RL-zero" setting the paper emphasizes.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig, TreeConfig
from repro.core import advantage as adv_mod
from repro.core.engine import TreeEngine
from repro.core.loss import dapo_pg_loss, entropy_from_logits, \
    token_logprobs_from_logits
from repro.core.sampler import sample_sequential, sample_trees
from repro.core.tree import QueryTree, Status, ancestor_matrix
from repro.data.reward import reward_fn
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import forward, init_params
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    warmup_constant_schedule,
)


class TrainerMode(str, enum.Enum):
    GRPO = "grpo"
    GRPO_TREE = "grpo_tree"
    TREEPO = "treepo"


@dataclasses.dataclass
class RolloutBatch:
    """Fixed-shape device batch for the PG update."""

    tokens: np.ndarray          # (N, L) prompt+response, right-padded
    response_mask: np.ndarray   # (N, L) 1 on generated tokens
    logprobs_old: np.ndarray    # (N, L) rollout logprobs (0 elsewhere)
    advantages: np.ndarray      # (N, L) token-broadcast advantage
    rewards: np.ndarray         # (N,)
    num_queries: int = 0
    mean_response_len: float = 0.0
    leaf_rate: float = 0.0


def _bucket_len(n: int, quantum: int = 64) -> int:
    return max(quantum, -(-n // quantum) * quantum)


class RLTrainer:
    """Single-replica RL trainer (the distributed variant lives in
    repro.launch: same update function under pjit)."""

    def __init__(self, cfg: ModelConfig, train_cfg: TrainConfig,
                 tree_cfg: TreeConfig,
                 mode: TrainerMode = TrainerMode.TREEPO, *,
                 seed: int = 0, engine_kwargs: Optional[Dict] = None,
                 data_seed: int = 0, min_difficulty: int = 1,
                 max_difficulty: int = 2):
        self.cfg = cfg
        self.train_cfg = train_cfg
        self.tree_cfg = tree_cfg
        self.mode = TrainerMode(mode)
        self.tok = ByteTokenizer()
        if cfg.vocab_size < self.tok.vocab_size:
            raise ValueError("model vocab too small for the byte tokenizer")
        key = jax.random.PRNGKey(seed)
        self.params = init_params(key, cfg)
        self.opt_state = adamw_init(self.params)
        self.lr_fn = warmup_constant_schedule(train_cfg.learning_rate,
                                              train_cfg.warmup_steps)
        self.gen = MathTaskGenerator(data_seed, min_difficulty,
                                     max_difficulty)
        self.engine_kwargs = dict(engine_kwargs or {})
        self._update_fns: Dict[Tuple[int, int], Any] = {}
        self.step = 0
        self.metrics_log: List[Dict[str, float]] = []
        self._rng = np.random.default_rng(seed)
        import random as _random
        self._pyrng = _random.Random(seed)

    # -- engine ----------------------------------------------------------------

    def _make_engine(self) -> TreeEngine:
        """Fresh engine view over the *current* params (on-policy rollout)."""
        return TreeEngine(self.params, self.cfg, self.tree_cfg,
                          seed=int(self._rng.integers(2 ** 31)),
                          **self.engine_kwargs)

    # -- rollout ---------------------------------------------------------------

    def _sample_queries(self, n: int):
        samples = self.gen.batch(n)
        prompts = [self.tok.encode(s.query, bos=True) for s in samples]
        return samples, prompts

    def rollout(self, num_queries: int, progress: float = 0.0
                ) -> Tuple[List[QueryTree], TreeEngine]:
        samples, prompts = self._sample_queries(num_queries)
        engine = self._make_engine()
        targets = [s.answer for s in samples]
        if self.mode == TrainerMode.GRPO:
            trees, _ = sample_sequential(engine, prompts, targets,
                                         rng=self._pyrng,
                                         progress=progress)
        else:
            trees, _ = sample_trees(engine, prompts, targets,
                                    rng=self._pyrng, progress=progress)
        return trees, engine

    # -- reward + advantage ------------------------------------------------------

    def _tree_rewards(self, tree: QueryTree) -> np.ndarray:
        rs = []
        for p in tree.finished:
            if p.status == Status.FAILED:
                rs.append(0.0)
            else:
                rs.append(reward_fn(self.tok.decode(p.tokens), tree.target,
                                    shaping=self.train_cfg.reward_shaping))
        return np.asarray(rs, np.float32)

    def _tree_advantages(self, tree: QueryTree,
                         rewards: np.ndarray) -> np.ndarray:
        variant = (self.train_cfg.advantage_kind
                   if self.mode == TrainerMode.TREEPO else "grpo")
        if variant == "grpo":
            return np.asarray(adv_mod.grpo_advantage(jnp.asarray(rewards)))
        anc = ancestor_matrix(tree.finished, self.tree_cfg.max_depth)
        return np.asarray(adv_mod.treepo_advantage(
            jnp.asarray(rewards), jnp.asarray(anc), variant=variant))

    def build_batch(self, trees: List[QueryTree]) -> RolloutBatch:
        """Reward, dynamic-sampling filter, advantage, fixed-shape pack."""
        kept: List[Tuple[QueryTree, np.ndarray, np.ndarray]] = []
        for tree in trees:
            if not tree.finished:
                continue
            rewards = self._tree_rewards(tree)
            if self.train_cfg.dynamic_sampling and rewards.std() <= 1e-6:
                continue  # DAPO: drop all-correct / all-wrong groups
            advs = self._tree_advantages(tree, rewards)
            kept.append((tree, rewards, advs))
        if not kept:
            return RolloutBatch(np.zeros((0, 1), np.int32),
                                np.zeros((0, 1), np.float32),
                                np.zeros((0, 1), np.float32),
                                np.zeros((0, 1), np.float32),
                                np.zeros((0,), np.float32))
        rows = []
        for tree, rewards, advs in kept:
            for p, r, a in zip(tree.finished, rewards, advs):
                rows.append((tree.prompt_tokens, p.tokens, p.logprobs,
                             float(r), float(a)))
        L = _bucket_len(max(len(pr) + len(t) for pr, t, *_ in rows))
        N = len(rows)
        tokens = np.full((N, L), ByteTokenizer.PAD, np.int32)
        rmask = np.zeros((N, L), np.float32)
        lp_old = np.zeros((N, L), np.float32)
        advsb = np.zeros((N, L), np.float32)
        rew = np.zeros((N,), np.float32)
        resp_lens = []
        n_leaves = 0
        for i, (prompt, resp, lps, r, a) in enumerate(rows):
            n_p, n_r = len(prompt), len(resp)
            tokens[i, : n_p] = prompt
            tokens[i, n_p: n_p + n_r] = resp
            rmask[i, n_p: n_p + n_r] = 1.0
            lp_old[i, n_p: n_p + n_r] = lps
            advsb[i, n_p: n_p + n_r] = a
            rew[i] = r
            resp_lens.append(n_r)
        if self.train_cfg.global_norm and \
                self.mode == TrainerMode.TREEPO and \
                self.train_cfg.advantage_kind != "grpo":
            advsb = np.asarray(adv_mod.global_normalize(
                jnp.asarray(advsb), jnp.asarray(rmask)))
        for tree, _, _ in kept:
            n_leaves += tree.num_leaves
        return RolloutBatch(
            tokens=tokens, response_mask=rmask, logprobs_old=lp_old,
            advantages=advsb, rewards=rew, num_queries=len(kept),
            mean_response_len=float(np.mean(resp_lens)),
            leaf_rate=n_leaves / max(sum(len(t.finished)
                                         for t, _, _ in kept), 1))

    # -- update -----------------------------------------------------------------

    def _get_update_fn(self, N: int, L: int):
        key = (N, L)
        if key not in self._update_fns:
            cfg, tc = self.cfg, self.train_cfg

            def loss_fn(params, tokens, rmask, lp_old, advs):
                logits, aux = forward(params, cfg, tokens)
                lp_new = token_logprobs_from_logits(
                    logits[:, :-1], tokens[:, 1:])
                # align: response token at t is predicted from t-1
                mask = rmask[:, 1:]
                loss, metrics = dapo_pg_loss(
                    lp_new, lp_old[:, 1:], advs[:, 1:], mask,
                    clip_eps_low=tc.clip_eps_low,
                    clip_eps_high=tc.clip_eps_high)
                ent = entropy_from_logits(logits[:, :-1], mask)
                if cfg.moe is not None:
                    loss = loss + cfg.moe.aux_loss_coef * aux
                metrics = dict(metrics, entropy=ent, moe_aux=aux)
                return loss, metrics

            def update(params, opt_state, tokens, rmask, lp_old, advs,
                       step):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, tokens, rmask, lp_old,
                                           advs)
                grads, gnorm = clip_by_global_norm(grads,
                                                   tc.max_grad_norm)
                lr = self.lr_fn(step)
                new_params, new_opt = adamw_update(
                    params, grads, opt_state, lr=lr, beta1=tc.beta1,
                    beta2=tc.beta2, eps=tc.eps,
                    weight_decay=tc.weight_decay)
                metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
                return new_params, new_opt, metrics

            self._update_fns[key] = jax.jit(update)
        return self._update_fns[key]

    def update(self, batch: RolloutBatch) -> Dict[str, float]:
        if batch.tokens.shape[0] == 0:
            return {"skipped": 1.0}
        N, L = batch.tokens.shape
        fn = self._get_update_fn(N, L)
        metrics: Dict[str, float] = {}
        for _ in range(self.train_cfg.ppo_epochs):
            self.params, self.opt_state, m = fn(
                self.params, self.opt_state,
                jnp.asarray(batch.tokens),
                jnp.asarray(batch.response_mask),
                jnp.asarray(batch.logprobs_old),
                jnp.asarray(batch.advantages),
                jnp.asarray(self.step, jnp.int32))
            metrics = {k: float(v) for k, v in m.items()}
        return metrics

    # -- outer loop ---------------------------------------------------------------

    def train_step(self, num_queries: Optional[int] = None,
                   progress: float = 0.0) -> Dict[str, float]:
        """One full RL iteration: oversampled rollout → filter → update.

        ``num_queries``: queries per *attempt* (default: batch_size); the
        paper oversamples 3× and resamples up to 2 extra rounds if dynamic
        sampling starves the batch.
        """
        t0 = time.time()
        nq = num_queries or self.train_cfg.batch_size
        all_trees: List[QueryTree] = []
        sample_tokens = 0
        rounds = 0
        target_queries = nq
        while rounds <= self.train_cfg.max_resample_rounds:
            want = (target_queries - self._count_kept(all_trees))
            if want <= 0:
                break
            n = want * (self.train_cfg.oversample_factor
                        if rounds == 0 else 1)
            trees, engine = self.rollout(n, progress)
            all_trees.extend(trees)
            sample_tokens += engine.stats.model_tokens
            rounds += 1
            if not self.train_cfg.dynamic_sampling:
                break
        batch = self.build_batch(all_trees)
        metrics = self.update(batch)
        self.step += 1
        rewards = batch.rewards
        metrics.update(
            step=self.step,
            reward_mean=float(rewards.mean()) if rewards.size else 0.0,
            num_trajectories=float(rewards.size),
            num_queries_kept=float(batch.num_queries),
            response_len=batch.mean_response_len,
            leaf_rate=batch.leaf_rate,
            sample_model_tokens=float(sample_tokens),
            wall_time=time.time() - t0,
        )
        self.metrics_log.append(metrics)
        return metrics

    def _count_kept(self, trees: List[QueryTree]) -> int:
        n = 0
        for tree in trees:
            if not tree.finished:
                continue
            rewards = self._tree_rewards(tree)
            if (not self.train_cfg.dynamic_sampling
                    or rewards.std() > 1e-6):
                n += 1
        return n

    # -- behavior-cloning warmup ----------------------------------------------------
    #
    # The paper trains from the *pretrained* Qwen2.5-7B base model, which
    # already emits \boxed{} answers under few-shot prompting.  Our toy model
    # starts from random weights, so a short supervised warmup on synthetic
    # CoT traces stands in for "base model with a prior" (recorded as a
    # deviation in DESIGN.md §8).  RL proper then starts from this
    # checkpoint — still no *RL* signal is used here.

    def bc_warmup(self, steps: int = 100, batch_size: int = 16,
                  lr: float = 3e-3) -> Dict[str, float]:
        cfg = self.cfg

        def ce_loss(params, tokens, mask):
            logits, aux = forward(params, cfg, tokens)
            lp = token_logprobs_from_logits(logits[:, :-1], tokens[:, 1:])
            m = mask[:, 1:]
            loss = -(lp * m).sum() / jnp.maximum(m.sum(), 1.0)
            if cfg.moe is not None:
                loss = loss + cfg.moe.aux_loss_coef * aux
            return loss

        @jax.jit
        def bc_step(params, opt_state, tokens, mask):
            loss, grads = jax.value_and_grad(ce_loss)(params, tokens, mask)
            grads, _ = clip_by_global_norm(grads, 1.0)
            new_params, new_opt = adamw_update(params, grads, opt_state,
                                               lr=lr)
            return new_params, new_opt, loss

        L = None
        last = 0.0
        for it in range(steps):
            samples = self.gen.batch(batch_size)
            rows = []
            for s in samples:
                ids = (self.tok.encode(s.query, bos=True),
                       self.tok.encode(" " + s.cot, eos=True))
                rows.append(ids)
            maxlen = max(len(a) + len(b) for a, b in rows)
            if L is None or maxlen > L:
                L = _bucket_len(maxlen)
            toks = np.full((batch_size, L), ByteTokenizer.PAD, np.int32)
            mask = np.zeros((batch_size, L), np.float32)
            for i, (q, c) in enumerate(rows):
                toks[i, : len(q)] = q
                toks[i, len(q): len(q) + len(c)] = c
                mask[i, len(q): len(q) + len(c)] = 1.0
            self.params, self.opt_state, loss = bc_step(
                self.params, self.opt_state, jnp.asarray(toks),
                jnp.asarray(mask))
            last = float(loss)
        # reset optimizer state for the RL phase (fresh moments)
        self.opt_state = adamw_init(self.params)
        return {"bc_loss": last, "bc_steps": float(steps)}

    # -- evaluation ----------------------------------------------------------------

    def evaluate(self, num_queries: int = 16, k: int = 4,
                 seed: int = 1234) -> Dict[str, float]:
        """maj@k accuracy on held-out synthetic tasks (paper's val metric)."""
        gen = MathTaskGenerator(seed, self.gen.min_difficulty,
                                self.gen.max_difficulty)
        samples = gen.batch(num_queries)
        prompts = [self.tok.encode(s.query, bos=True) for s in samples]
        eval_tree_cfg = dataclasses.replace(
            self.tree_cfg, max_width=k,
            init_divergence_low=k, init_divergence_high=k,
            branch_factor=1, fallback=False)
        engine = TreeEngine(self.params, self.cfg, eval_tree_cfg,
                            seed=seed, **self.engine_kwargs)
        trees, _ = sample_trees(engine, prompts,
                                [s.answer for s in samples],
                                eval_tree_cfg, rng=__import__(
                                    "random").Random(seed))
        from collections import Counter
        from repro.data.reward import extract_boxed, verify_answer
        correct = 0
        any_correct = 0
        for tree, s in zip(trees, samples):
            answers = []
            got_one = False
            for p in tree.finished:
                a = extract_boxed(self.tok.decode(p.tokens))
                if a is not None:
                    answers.append(a)
                    if verify_answer(a, s.answer):
                        got_one = True
            any_correct += int(got_one)
            if answers:
                maj = Counter(answers).most_common(1)[0][0]
                correct += int(verify_answer(maj, s.answer))
        return {"maj_acc": correct / num_queries,
                "pass_any": any_correct / num_queries}
