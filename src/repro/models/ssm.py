"""State-space layers: Mamba-1 selective scan (jamba) and RWKV-6 time/channel
mix (Finch, data-dependent decay).

Branch-state contract for the tree sampler: both layers expose a compact
recurrent state (``*_state_shape``) that is snapshotted/copied when a search
path branches — there is no KV cache to share (DESIGN.md §4).

Sequence-packing contract: every stateful input (mamba conv window + SSM
scan, rwkv token-shift + wkv recurrence) accepts ``segment_ids`` and
resets its carried state at packed-segment starts, so a packed segment
computes exactly what it would in its own row (the same guarantee the
attention layers get from the segment mask).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.kernels.ref import segment_reset_mask
from repro.models.layers import _dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# Mamba-1 (jamba)
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    ks = jax.random.split(key, 8)
    A = jnp.broadcast_to(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32),
                         (d_in, mc.d_state))
    return {
        "w_in": _dense_init(ks[0], (d, 2 * d_in), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, d_in)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "w_x": _dense_init(ks[2], (d_in, dtr + 2 * mc.d_state), dtype=dtype),
        "w_dt": _dense_init(ks[3], (dtr, d_in), dtype=dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((d_in,), dtype),
        "w_out": _dense_init(ks[4], (d_in, d), dtype=dtype),
    }


def _mamba_ssm_scan(u, dt, B_, C_, A, D, h0):
    """Selective scan. u,dt: (B,T,d_in); B_,C_: (B,T,N); A: (d_in,N);
    h0: (B,d_in,N). Returns (y (B,T,d_in), h_final).

    dA / dBu are formed *inside* the scan body: materializing the
    (B, T, d_in, N) discretized tensors up front costs T x the state size
    in HBM traffic and dominated the jamba prefill roofline (§Perf #2,
    iteration 3)."""
    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp                       # (B,d_in)/(B,N)
        dA_t = jnp.exp(dt_t[..., None] * A[None])       # (B,d_in,N)
        dBu_t = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA_t * h + dBu_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C_, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u * D[None, None]
    return y, h_final


def mamba_forward(params, cfg: ModelConfig, x, state=None, mask=None,
                  last_idx=None, segment_ids=None):
    """x: (B,T,d). state: {"conv": (B,d_conv-1,d_in), "ssm": (B,d_in,N)}.
    Returns (y, new_state).

    ``mask`` (B,T): right-padding mask.  Padded steps freeze the SSM state
    (dt -> 0 makes dA=I, dBu=0); ``last_idx`` (B,) selects the conv context
    ending at the last *real* token so new_state matches the unpadded run.

    ``segment_ids`` (B,T): sequence-packed rows.  The SSM state is zeroed
    at each segment start (inside the scan kernel) and the depthwise conv
    windows are masked to same-segment taps — a packed segment sees
    exactly the zero conv context + zero h0 a fresh row would.
    """
    mc = cfg.mamba
    B, T, d = x.shape
    d_in = mc.expand * d
    dtr = mc.resolved_dt_rank(d)
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)  # (B,T,d_in) each
    # depthwise causal conv over time, with carried context
    if state is None:
        conv_ctx = jnp.zeros((B, mc.d_conv - 1, d_in), u.dtype)
        h0 = jnp.zeros((B, d_in, mc.d_state), jnp.float32)
    else:
        conv_ctx, h0 = state["conv"].astype(u.dtype), state["ssm"].astype(jnp.float32)
    u_pad = jnp.concatenate([conv_ctx, u], axis=1)  # (B, T+dc-1, d_in)
    idx = jnp.arange(T)[:, None] + jnp.arange(mc.d_conv)[None, :]
    windows = u_pad[:, idx]                          # (B,T,dc,d_in)
    if segment_ids is not None:
        # prepended conv context belongs to token 0's stream; a window
        # tap from another segment is zeroed (== fresh-row conv context)
        seg = segment_ids.astype(jnp.int32)
        seg_pad = jnp.concatenate(
            [jnp.broadcast_to(seg[:, :1], (B, mc.d_conv - 1)), seg], axis=1)
        win_seg = seg_pad[:, idx]                    # (B,T,dc)
        windows = windows * (win_seg == seg[:, :, None]
                             )[..., None].astype(windows.dtype)
    u_conv = jax.nn.silu(jnp.einsum("btcd,cd->btd", windows, params["conv_w"])
                         + params["conv_b"])
    xp = u_conv @ params["w_x"]
    dt_in, B_, C_ = jnp.split(xp, [dtr, dtr + mc.d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["w_dt"] + params["dt_bias"])
    if mask is not None:
        dt = dt * mask[..., None].astype(dt.dtype)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, h_final = kops.mamba_scan(u_conv.astype(jnp.float32),
                                 dt.astype(jnp.float32),
                                 B_.astype(jnp.float32),
                                 C_.astype(jnp.float32), A,
                                 params["D"].astype(jnp.float32), h0,
                                 segment_ids=segment_ids)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["w_out"]
    if last_idx is not None:
        # conv context ending at the last real token: u_pad rows
        # [L, L+dc-2] where L = last_idx+1 (u_pad row t+dc-1 = token t)
        new_conv = jax.vmap(
            lambda up, s: jax.lax.dynamic_slice(
                up, (s, 0), (mc.d_conv - 1, d_in)))(u_pad, last_idx + 1)
    else:
        new_conv = u_pad[:, -(mc.d_conv - 1):]
    new_state = {"conv": new_conv, "ssm": h_final}
    return y, new_state


def mamba_state_shape(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, mc.d_conv - 1, d_in), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, d_in, mc.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

def rwkv6_init(key, cfg: ModelConfig, dtype=jnp.float32):
    rc = cfg.rwkv
    d = cfg.d_model
    H = d // rc.head_dim
    ks = jax.random.split(key, 16)
    p = {
        # token-shift data-dependent mixing (LoRA over 5 targets: r,k,v,w,g)
        "mix_base": (jax.random.normal(ks[0], (5, d)) * 0.02).astype(dtype),
        "mix_lora_a": _dense_init(ks[1], (d, rc.token_shift_lora), dtype=dtype),
        "mix_lora_b": (jax.random.normal(ks[2], (5, rc.token_shift_lora, d)) * 0.02).astype(dtype),
        "w_r": _dense_init(ks[3], (d, d), dtype=dtype),
        "w_k": _dense_init(ks[4], (d, d), dtype=dtype),
        "w_v": _dense_init(ks[5], (d, d), dtype=dtype),
        "w_g": _dense_init(ks[6], (d, d), dtype=dtype),
        "w_o": _dense_init(ks[7], (d, d), dtype=dtype),
        # data-dependent decay: w = exp(-exp(base + lora(x)))
        "decay_base": jnp.full((d,), -6.0, dtype),
        "decay_lora_a": _dense_init(ks[8], (d, rc.decay_lora), dtype=dtype),
        "decay_lora_b": (jax.random.normal(ks[9], (rc.decay_lora, d)) * 0.02).astype(dtype),
        "bonus_u": (jax.random.normal(ks[10], (H, rc.head_dim)) * 0.02).astype(dtype),
        "ln_x": rmsnorm_init(d, dtype),
    }
    return p


def rwkv6_time_mix(params, cfg: ModelConfig, x, state, mask=None,
                   last_idx=None, segment_ids=None):
    """RWKV6 time-mix. x: (B,T,d); state {"wkv": (B,H,D,D) f32,
    "shift": (B,d)}. Returns (y, new_state).

    ``mask`` (B,T): padded steps freeze the wkv state (w -> 1, k -> 0);
    ``last_idx`` picks the token-shift state at the last real token.

    ``segment_ids`` (B,T): sequence-packed rows.  The wkv state is zeroed
    at each segment start (inside the recurrence kernel) and the
    token-shift input at a segment start is zeroed — a packed segment
    sees exactly the zero shift/wkv state a fresh row would.
    """
    rc = cfg.rwkv
    B, T, d = x.shape
    H, D = d // rc.head_dim, rc.head_dim
    x_prev = jnp.concatenate([state["shift"][:, None, :].astype(x.dtype),
                              x[:, :-1]], axis=1)
    if segment_ids is not None:
        x_prev = x_prev * (1.0 - segment_reset_mask(segment_ids)
                           )[..., None].astype(x_prev.dtype)
    dx = x_prev - x
    # data-dependent token-shift mix per target (r,k,v,w,g)
    lora = jnp.tanh(x @ params["mix_lora_a"])  # (B,T,L)
    mixes = params["mix_base"][:, None, None, :] + jnp.einsum(
        "btl,sld->sbtd", lora, params["mix_lora_b"])  # (5,B,T,d)
    xr, xk, xv, xw, xg = (x + dx * mixes[i] for i in range(5))
    r = (xr @ params["w_r"]).reshape(B, T, H, D)
    k = (xk @ params["w_k"]).reshape(B, T, H, D)
    v = (xv @ params["w_v"]).reshape(B, T, H, D)
    g = jax.nn.silu(xg @ params["w_g"])
    decay_in = params["decay_base"] + jnp.tanh(
        xw @ params["decay_lora_a"]) @ params["decay_lora_b"]
    w = jnp.exp(-jnp.exp(decay_in.astype(jnp.float32))).reshape(B, T, H, D)
    if mask is not None:
        m = mask[:, :, None, None].astype(w.dtype)
        w = w * m + (1.0 - m)   # identity decay on pads
        k = k * m.astype(k.dtype)  # no kv contribution from pads
    out, wkv_new = kops.wkv6(r, k, v, w.astype(r.dtype), params["bonus_u"],
                             state["wkv"], segment_ids=segment_ids)
    out = rmsnorm(params["ln_x"], out.reshape(B, T, d), cfg.norm_eps)
    y = (out * g) @ params["w_o"]
    if last_idx is not None:
        shift = x[jnp.arange(B), last_idx]
    else:
        shift = x[:, -1, :]
    return y, {"wkv": wkv_new, "shift": shift}


def rwkv6_channel_mix_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "mix_k": (jax.random.normal(ks[0], (d,)) * 0.02).astype(dtype),
        "w_k": _dense_init(ks[1], (d, cfg.d_ff), dtype=dtype),
        "w_v": _dense_init(ks[2], (cfg.d_ff, d), dtype=dtype),
        "w_r": _dense_init(ks[3], (d, d), dtype=dtype),
    }


def rwkv6_channel_mix(params, x, shift_state, last_idx=None,
                      segment_ids=None):
    """x: (B,T,d); shift_state: (B,d). Returns (y, new_shift).

    ``segment_ids`` (B,T): packed rows — the token-shift input at a
    segment start is zeroed (fresh-row shift state)."""
    x_prev = jnp.concatenate([shift_state[:, None, :].astype(x.dtype),
                              x[:, :-1]], axis=1)
    if segment_ids is not None:
        x_prev = x_prev * (1.0 - segment_reset_mask(segment_ids)
                           )[..., None].astype(x_prev.dtype)
    xk = x + (x_prev - x) * params["mix_k"]
    kk = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    rr = jax.nn.sigmoid(x @ params["w_r"])
    if last_idx is not None:
        shift = x[jnp.arange(x.shape[0]), last_idx]
    else:
        shift = x[:, -1, :]
    return rr * (kk @ params["w_v"]), shift


def rwkv6_state_shape(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    rc = cfg.rwkv
    d = cfg.d_model
    H = d // rc.head_dim
    return {
        "wkv": jax.ShapeDtypeStruct((batch, H, rc.head_dim, rc.head_dim),
                                    jnp.float32),
        "shift": jax.ShapeDtypeStruct((batch, d), dtype),
        "shift_ffn": jax.ShapeDtypeStruct((batch, d), dtype),
    }
