"""Mixture-of-Experts FFN: top-k router + grouped-GEMM experts.

TPU-native formulation: tokens are sorted by expert id and processed with
``jax.lax.ragged_dot`` (megablox-style grouped matmul) — fixed shapes, no
capacity-factor token dropping, no (T, E, C) dispatch one-hot.  Experts are
sharded on the ``model`` mesh axis (expert parallelism); GSPMD inserts the
dispatch collectives.

Supports olmoe (64e top-8), jamba (16e top-2, alternating layers) and
deepseek-v3 (1 shared + 256 routed top-8, aux-loss-free bias routing,
sigmoid gates).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import _act, _dense_init, mlp, mlp_init


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": _dense_init(ks[0], (d, m.num_experts), scale=0.02, dtype=jnp.float32),
        # experts stacked on a leading E axis -> shardable / ragged_dot rhs
        "w_gate": (jax.random.normal(ks[1], (m.num_experts, d, m.expert_d_ff)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (m.num_experts, d, m.expert_d_ff)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (m.num_experts, m.expert_d_ff, d))
                   * (1.0 / jnp.sqrt(m.expert_d_ff))).astype(dtype),
    }
    if m.router_aux_free_bias:
        p["router_bias"] = jnp.zeros((m.num_experts,), jnp.float32)
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.num_shared_experts * m.shared_d_ff,
                               kind="gated", dtype=dtype)
    return p


def router_probs(params, m: MoEConfig, x_flat) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (topk_weights (T,k), topk_ids (T,k)) plus aux info via closure.

    deepseek-v3 style: sigmoid affinity + additive bias for selection, weight
    from unbiased affinity, renormalized over the selected k.  Classic
    softmax routing otherwise.
    """
    logits = x_flat.astype(jnp.float32) @ params["router"]
    if m.router_aux_free_bias:
        affinity = jax.nn.sigmoid(logits)
        sel_scores = affinity + params["router_bias"][None, :]
        _, ids = jax.lax.top_k(sel_scores, m.top_k)
        w = jnp.take_along_axis(affinity, ids, axis=-1)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, m.top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    return w, ids, logits


def load_balance_aux_loss(logits, ids, num_experts: int) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits, axis=-1)
    counts = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def _ep_mesh_axes():
    """Detect an ambient mesh with a 'model' axis (set via
    jax.sharding.use_mesh).  Returns (mesh, fsdp_axes) or (None, ())."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None, ()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return None, ()
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return mesh, fsdp


def moe_forward(params, cfg: ModelConfig, x, act: str = "silu"):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Under an ambient mesh with a ``model`` axis (jax.sharding.use_mesh)
    and divisible expert count, dispatches to the explicit expert-parallel
    shard_map path (``moe_forward_ep``) — GSPMD's native handling of a
    sharded ragged_dot all-reduces the full (T·k, d_ff) partials, which is
    catastrophic (§Perf); the EP path reduces one (T, d) psum instead.
    """
    m = cfg.moe
    mesh, fsdp = _ep_mesh_axes()
    if mesh is not None and m.num_experts % mesh.shape["model"] == 0 \
            and mesh.shape["model"] > 1:
        return moe_forward_ep(params, cfg, x, act, fsdp)
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    T = B * S
    w, ids, logits = router_probs(params, m, xf)

    # sort token-replicas by expert id -> grouped layout for ragged_dot
    flat_ids = ids.reshape(-1)                       # (T*k,)
    sort_idx = jnp.argsort(flat_ids)                 # (T*k,)
    tok_idx = sort_idx // m.top_k                    # original token per replica
    x_rep = xf[tok_idx]                              # (T*k, d)
    group_sizes = jnp.bincount(flat_ids, length=m.num_experts)

    gate = jax.lax.ragged_dot(x_rep, params["w_gate"], group_sizes)
    up = jax.lax.ragged_dot(x_rep, params["w_up"], group_sizes)
    h = _act(gate, act) * up
    y_rep = jax.lax.ragged_dot(h, params["w_down"], group_sizes)  # (T*k, d)

    # unsort and combine with routing weights (f32 accumulation)
    w_sorted = w.reshape(-1)[sort_idx][:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok_idx].add(
        y_rep.astype(jnp.float32) * w_sorted)

    if m.num_shared_experts:
        out = out + mlp(params["shared"], xf, act)
    aux = load_balance_aux_loss(logits, ids, m.num_experts)
    return out.reshape(B, S, d).astype(x.dtype), aux


def _moe_local_compute(xf, w_k, ids, w_gate, w_up, w_down, act: str,
                       num_experts_global: int,
                       capacity_factor: float = 0.0):
    """Per-shard expert compute inside shard_map.

    xf: (T_local, d) tokens; ids/w_k: (T_local, k) global expert routing;
    w_*: (E_local, ...) this shard's experts (+ we append a zero 'trash'
    expert for foreign tokens).  Returns this shard's (T_local, d) partial
    output — summing over the model axis yields the full MoE output.

    ``capacity_factor`` > 0 packs rows into per-expert capacity slots
    (GShard): expert GEMMs shrink from T·k rows to E_local·cap rows
    (~8-16x less compute when E >> E_local); overflow rows drop.
    """
    E_local = w_gate.shape[0]
    shard = jax.lax.axis_index("model")
    lo = shard * E_local
    T, k = ids.shape
    d = xf.shape[1]
    flat_ids = ids.reshape(-1)
    is_local = (flat_ids >= lo) & (flat_ids < lo + E_local)
    gid = jnp.where(is_local, flat_ids - lo, E_local)   # trash group last
    sort_idx = jnp.argsort(gid)
    tok_idx = sort_idx // k
    zpad = lambda w: jnp.concatenate(
        [w, jnp.zeros((1,) + w.shape[1:], w.dtype)], axis=0)
    wts_sorted = (w_k.reshape(-1)[sort_idx]
                  * is_local[sort_idx].astype(w_k.dtype))

    if capacity_factor > 0:
        cap = max(int(capacity_factor * T * k / num_experts_global), 1)
        gid_s = gid[sort_idx]
        counts = jnp.bincount(gid_s, length=E_local + 1)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])[:-1]
        rank = jnp.arange(T * k) - starts[gid_s]        # rank within group
        kept = (gid_s < E_local) & (rank < cap)
        slot = jnp.where(kept, gid_s * cap + rank, E_local * cap)
        x_comp = jnp.zeros((E_local * cap + 1, d), xf.dtype).at[slot].set(
            xf[tok_idx])
        group_sizes = jnp.concatenate(
            [jnp.full((E_local,), cap, jnp.int32),
             jnp.ones((1,), jnp.int32)])
        gate = jax.lax.ragged_dot(x_comp, zpad(w_gate), group_sizes)
        up = jax.lax.ragged_dot(x_comp, zpad(w_up), group_sizes)
        h = _act(gate, act) * up
        y_comp = jax.lax.ragged_dot(h, zpad(w_down), group_sizes)
        y_rep = y_comp[slot] * kept[:, None].astype(y_comp.dtype)
    else:
        x_rep = xf[tok_idx]
        group_sizes = jnp.bincount(gid[sort_idx], length=E_local + 1)
        gate = jax.lax.ragged_dot(x_rep, zpad(w_gate), group_sizes)
        up = jax.lax.ragged_dot(x_rep, zpad(w_up), group_sizes)
        h = _act(gate, act) * up
        y_rep = jax.lax.ragged_dot(h, zpad(w_down), group_sizes)
    out = jnp.zeros((T, d), jnp.float32).at[tok_idx].add(
        y_rep.astype(jnp.float32) * wts_sorted[:, None])
    return jax.lax.psum(out, "model")


def moe_forward_ep(params, cfg: ModelConfig, x, act: str, fsdp) -> tuple:
    """Expert-parallel MoE via shard_map over the ambient mesh.

    Experts live on the ``model`` axis; tokens stay batch-sharded on
    (pod, data).  The router runs replicated (its params are replicated);
    each shard runs ragged_dot over its local experts only and contributes
    a (T, d) partial that one psum combines — this is the collective
    schedule GSPMD cannot find on its own (§Perf hillclimb #2).
    """
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    w, ids, logits = router_probs(params, m, xf)

    P = jax.sharding.PartitionSpec
    tok = fsdp if fsdp else None
    n_tok_shards = 1
    if tok is not None:
        mesh = jax.sharding.get_abstract_mesh()
        for a in tok:
            n_tok_shards *= mesh.shape[a]
    if tok is not None and (B * S) % n_tok_shards != 0:
        tok = None  # tiny decode batches: replicate tokens instead
    body = functools.partial(_moe_local_compute, act=act,
                             num_experts_global=m.num_experts,
                             capacity_factor=m.ep_capacity_factor)
    out = jax.shard_map(
        body,
        in_specs=(P(tok, None), P(tok, None), P(tok, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(tok, None),
        check_vma=False,
    )(xf, w.astype(xf.dtype), ids, params["w_gate"], params["w_up"],
      params["w_down"])

    if m.num_shared_experts:
        out = out + mlp(params["shared"], xf, act)
    aux = load_balance_aux_loss(logits, ids, m.num_experts)
    return out.reshape(B, S, d).astype(x.dtype), aux


def update_router_bias(params, ids, m: MoEConfig, lr: float = 1e-3):
    """deepseek-v3 aux-free balancing: nudge bias against overloaded experts.

    Applied outside the gradient path (the bias receives no gradient).
    """
    counts = jnp.zeros((m.num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    mean = counts.mean()
    return params["router_bias"] + lr * jnp.sign(mean - counts)
