"""Shared layer primitives: norms, rope, MLPs, embeddings.

All layers are pure functions over param pytrees (nested dicts of jnp
arrays).  Initialization helpers return params; apply helpers consume them.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    from repro.kernels import ops as kops

    return kops.rmsnorm(x, params["scale"], eps=eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    if theta <= 0:
        return None
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return inv  # (half,)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0:
        return x
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated swiglu / plain)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, kind: str = "gated",
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind == "gated":
        return {
            "w_gate": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": _dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": _dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": _dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def _act(x, act: str):
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(act)


def mlp(params, x, act: str = "silu"):
    if "w_gate" in params:
        h = _act(x @ params["w_gate"], act) * (x @ params["w_up"])
    else:
        h = _act(x @ params["w_up"], act)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, tie: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    p = {"embedding": (jax.random.normal(ks[0], (vocab, d_model)) * 0.02).astype(dtype)}
    if not tie:
        p["lm_head"] = _dense_init(ks[1], (d_model, vocab), dtype=dtype)
    return p


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x, tie: bool):
    if tie:
        return x @ params["embedding"].T
    return x @ params["lm_head"]


# ---------------------------------------------------------------------------
# Sinusoidal positions (whisper)
# ---------------------------------------------------------------------------

def sinusoidal_positions(num_positions: int, d_model: int):
    pos = jnp.arange(num_positions, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def config_eps(cfg: ModelConfig) -> float:
    return cfg.norm_eps
