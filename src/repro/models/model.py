"""Generic decoder assembly: every assigned architecture is this one module
instantiated by its ``ModelConfig``.

Public API
----------
  init_params(key, cfg, dtype)                     -> params pytree
  forward(params, cfg, tokens, prefix_embeds=None) -> (logits, aux)   (train)
  init_cache(cfg, batch, max_seq, dtype)           -> cache *specs*
  zeros_cache(cfg, batch, max_seq, dtype)          -> concrete zero cache
  prefill(params, cfg, tokens, cache, ...)         -> (logits, cache)
  decode_step(params, cfg, tokens_t, cache, pos)   -> (logits, cache)

Cache layout: ``{"layers": (per-layer dict, ...), "cross": optional}`` —
per-layer entries are dense ring-buffer KV (attn), latent KV (mla), or
recurrent state (mamba / rwkv).  The tree sampler uses its own paged cache
(repro/kv) and drives the same per-layer blocks through
``repro.core.engine``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    embed,
    embed_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
    unembed,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, layer_idx: int, dtype) -> Params:
    kind = cfg.layer_kind(layer_idx)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.attention_kind == "mla":
            p["attn"] = attn.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm.mamba_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = ssm.rwkv6_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = ssm.rwkv6_channel_mix_init(ks[1], cfg, dtype)
    else:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.moe is not None and cfg.moe.is_moe_layer(layer_idx):
            p["ffn_moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
        else:
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                kind=cfg.mlp_kind, dtype=dtype)
    if cfg.encoder is not None:  # whisper decoder layer: add cross-attn
        p["norm_cross"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn.cross_attn_init(ks[2], cfg, cfg.encoder.d_model,
                                          dtype)
    return p


def _encoder_init(key, cfg: ModelConfig, dtype) -> Params:
    e = cfg.encoder
    ks = jax.random.split(key, e.num_layers + 1)
    enc_cfg = ModelConfig(
        name="enc", arch_type="dense", num_layers=e.num_layers,
        d_model=e.d_model, num_heads=e.num_heads, num_kv_heads=e.num_heads,
        d_ff=e.d_ff, vocab_size=1, rope_theta=0.0, act=cfg.act,
        mlp_kind="plain",
    )
    layers = []
    for i in range(e.num_layers):
        lk = jax.random.split(ks[i], 2)
        layers.append({
            "norm1": rmsnorm_init(e.d_model, dtype),
            "attn": attn.gqa_init(lk[0], enc_cfg, dtype),
            "norm2": rmsnorm_init(e.d_model, dtype),
            "ffn": mlp_init(lk[1], e.d_model, e.d_ff, kind="plain",
                            dtype=dtype),
        })
    return {"layers": tuple(layers),
            "norm_f": rmsnorm_init(e.d_model, dtype),
            "_cfg": enc_cfg}


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, cfg.num_layers + 3)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                            cfg.tie_embeddings, dtype),
        "norm_f": rmsnorm_init(cfg.d_model, dtype),
        "layers": tuple(
            _layer_init(ks[i + 1], cfg, i, dtype)
            for i in range(cfg.num_layers)
        ),
    }
    if cfg.encoder is not None:
        enc = _encoder_init(ks[-1], cfg, dtype)
        enc.pop("_cfg")
        params["encoder"] = enc
    return params


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames):
    """frames: (B, S_enc, d_enc) precomputed stub embeddings -> (B,S_enc,d)."""
    e = cfg.encoder
    x = frames + sinusoidal_positions(frames.shape[1], e.d_model).astype(
        frames.dtype)[None]
    ecfg = ModelConfig(
        name="enc", arch_type="dense", num_layers=e.num_layers,
        d_model=e.d_model, num_heads=e.num_heads, num_kv_heads=e.num_heads,
        d_ff=e.d_ff, vocab_size=1, rope_theta=0.0, act=cfg.act,
        mlp_kind="plain",
    )
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])
    for lp in params["encoder"]["layers"]:
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        x = x + attn.gqa_forward(lp["attn"], ecfg, h, positions, 0,
                                 causal=False)
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp(lp["ffn"], h, cfg.act)
    return rmsnorm(params["encoder"]["norm_f"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# full-sequence forward (train / eval / prefill-logits)
# ---------------------------------------------------------------------------

def _ffn_apply(lp: Params, cfg: ModelConfig, h):
    """Returns (out, aux_loss)."""
    if "ffn_moe" in lp:
        return moe_mod.moe_forward(lp["ffn_moe"], cfg, h, cfg.act)
    return mlp(lp["ffn"], h, cfg.act), jnp.float32(0.0)


def _decoder_layer_body(lp: Params, x, positions, segment_ids, cross_k,
                        cross_v, *, cfg: ModelConfig, layer_idx: int):
    """One decoder layer (attention/ssm + FFN [+ cross-attn]).

    Standalone so ``jax.checkpoint`` can wrap it for activation remat in
    the distributed train step.  Returns (x, aux_loss).

    ``segment_ids`` (None or (B, S)) isolates sequence-packed segments in
    EVERY layer kind: attention is restricted to same-segment pairs, and
    SSM/RWKV layers zero their carried recurrent/token-shift state at
    each segment start (inside the scan kernels), so a packed segment
    computes exactly what it would in its own row.  Encoder
    cross-attention stays per-row: all of a row's segments share its
    conditioning signal by convention.
    """
    i = layer_idx
    B = x.shape[0]
    kind = cfg.layer_kind(i)
    h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention_kind == "mla":
            y = attn.mla_forward(lp["attn"], cfg, h, positions, i,
                                 segment_ids=segment_ids)
        else:
            y = attn.gqa_forward(lp["attn"], cfg, h, positions, i,
                                 segment_ids=segment_ids)
    elif kind == "mamba":
        y, _ = ssm.mamba_forward(lp["mamba"], cfg, h,
                                 segment_ids=segment_ids)
    elif kind == "rwkv":
        zero_shift = jnp.zeros((B, cfg.d_model), h.dtype)
        zero_wkv = jnp.zeros(
            (B, cfg.d_model // cfg.rwkv.head_dim, cfg.rwkv.head_dim,
             cfg.rwkv.head_dim), jnp.float32)
        y, _ = ssm.rwkv6_time_mix(lp["rwkv"], cfg, h,
                                  {"wkv": zero_wkv, "shift": zero_shift},
                                  segment_ids=segment_ids)
    x = x + y
    if cfg.encoder is not None:
        h = rmsnorm(lp["norm_cross"], x, cfg.norm_eps)
        x = x + attn.cross_attn_forward(lp["cross"], cfg, h, cross_k,
                                        cross_v)
    h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if kind == "rwkv":
        y, _ = ssm.rwkv6_channel_mix(lp["ffn"], h,
                                     jnp.zeros((B, cfg.d_model), h.dtype),
                                     segment_ids=segment_ids)
        aux = jnp.float32(0.0)
    else:
        y, aux = _ffn_apply(lp, cfg, h)
    x = x + y
    return x, aux


def forward(params, cfg: ModelConfig, tokens, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            enc_frames: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            segment_ids: Optional[jnp.ndarray] = None,
            remat: bool = False,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (logits (B, S_total, V), moe_aux scalar).

    ``prefix_embeds``: (B, P, d) modality prefix (vlm/audio stub) prepended
    before token embeddings; logits cover the full combined sequence.
    ``positions``: (B, S_total) RoPE/sinusoidal positions (default:
    0..S_total-1) — sequence-packed rows pass per-segment-reset positions
    here (encoder archs gather their sinusoidal table by these too).
    ``segment_ids``: (B, S_total) int32 packing labels (-1 = pad,
    ``SHARED_SEGMENT_ID`` = per-row prefix every segment may attend);
    when given, attention masks out cross-segment pairs and SSM/RWKV
    layers reset their recurrent state at segment starts.
    ``remat``: checkpoint each decoder layer (training memory).
    """
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None and cfg.encoder is None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S_tot = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S_tot), (B, S_tot))
    enc_out = None
    cross_kv = None
    if cfg.encoder is not None:
        frames = enc_frames if enc_frames is not None else prefix_embeds
        enc_out = encode(params, cfg, frames)
        cross_kv = [attn.cross_attn_kv(lp["cross"], cfg, enc_out)
                    for lp in params["layers"]]
        # gather by the (possibly per-segment-reset) positions so packed
        # segments see the same embeddings their own row would
        x = x + sinusoidal_positions(S_tot, cfg.d_model)[positions].astype(
            x.dtype)
    aux_total = jnp.float32(0.0)
    dummy_kv = jnp.zeros((B, 1, 1), x.dtype)
    for i, lp in enumerate(params["layers"]):
        body = functools.partial(_decoder_layer_body, cfg=cfg, layer_idx=i)
        if remat:
            body = jax.checkpoint(body)
        ck, cv = cross_kv[i] if cross_kv is not None else (dummy_kv, dummy_kv)
        x, aux = body(lp, x, positions, segment_ids, ck, cv)
        aux_total = aux_total + aux
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits, aux_total


# ---------------------------------------------------------------------------
# dense cache for serve_step / dry-run decode shapes
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct cache specs (no allocation)."""
    layers = []
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            if cfg.attention_kind == "mla":
                layers.append(attn.mla_cache_shape(cfg, batch, max_seq, i,
                                                   dtype))
            else:
                layers.append(attn.gqa_cache_shape(cfg, batch, max_seq, i,
                                                   dtype))
        elif kind == "mamba":
            layers.append(ssm.mamba_state_shape(cfg, batch, dtype))
        elif kind == "rwkv":
            layers.append(ssm.rwkv6_state_shape(cfg, batch, dtype))
    cache: Dict[str, Any] = {"layers": tuple(layers)}
    if cfg.encoder is not None:
        e = cfg.encoder
        hd = cfg.resolved_head_dim
        cache["cross"] = tuple(
            {"k": jax.ShapeDtypeStruct(
                (batch, e.max_positions, cfg.num_kv_heads, hd), dtype),
             "v": jax.ShapeDtypeStruct(
                (batch, e.max_positions, cfg.num_kv_heads, hd), dtype)}
            for _ in range(cfg.num_layers)
        )
    return cache


def zeros_cache(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache(cfg, batch, max_seq, dtype))


# ---------------------------------------------------------------------------
# decode step (one token per sequence)
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, tokens_t, cache: Dict[str, Any],
                position, kv_update: str = "scatter"
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """tokens_t: (B,) int32; position: (B,) write index; returns
    (logits (B, V), new cache).

    ``kv_update``: "scatter" (per-row dynamic_update_slice) or "masked"
    (one-hot where; GSPMD-friendly — see attention._cache_write)."""
    B = tokens_t.shape[0]
    x = embed(params["embed"], tokens_t)  # (B, d)
    if cfg.encoder is not None:
        pos_emb = sinusoidal_positions(cfg.max_position_embeddings,
                                       cfg.d_model)
        x = x + pos_emb[position].astype(x.dtype)
    new_layers = []
    for i, lp in enumerate(params["layers"]):
        kind = cfg.layer_kind(i)
        lc = cache["layers"][i]
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        if kind == "attn":
            if cfg.attention_kind == "mla":
                y, lc = attn.mla_decode(lp["attn"], cfg, h, lc, position, i,
                                        kv_update=kv_update)
            else:
                y, lc = attn.gqa_decode(lp["attn"], cfg, h, lc, position, i,
                                        kv_update=kv_update)
        elif kind == "mamba":
            y1, st = ssm.mamba_forward(
                lp["mamba"], cfg, h[:, None, :],
                {"conv": lc["conv"], "ssm": lc["ssm"]})
            y, lc = y1[:, 0], {"conv": st["conv"].astype(lc["conv"].dtype),
                               "ssm": st["ssm"]}
        elif kind == "rwkv":
            y1, st = ssm.rwkv6_time_mix(
                lp["rwkv"], cfg, h[:, None, :],
                {"wkv": lc["wkv"], "shift": lc["shift"]})
            y = y1[:, 0]
            lc = {"wkv": st["wkv"], "shift": st["shift"].astype(lc["shift"].dtype),
                  "shift_ffn": lc["shift_ffn"]}
        x = x + y
        if cfg.encoder is not None:
            h = rmsnorm(lp["norm_cross"], x, cfg.norm_eps)
            ck, cv = cache["cross"][i]["k"], cache["cross"][i]["v"]
            hd = cfg.resolved_head_dim
            q = (h @ lp["cross"]["w_q"]).reshape(B, cfg.num_heads, hd)
            lengths = jnp.full((B,), ck.shape[1], jnp.int32)
            from repro.kernels import ops as kops

            o = kops.decode_attention(q, ck, cv, lengths)
            x = x + o.reshape(B, -1) @ lp["cross"]["w_o"]
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if kind == "rwkv":
            y1, sh = ssm.rwkv6_channel_mix(lp["ffn"], h[:, None, :],
                                           lc["shift_ffn"])
            y = y1[:, 0]
            lc = dict(lc, shift_ffn=sh.astype(lc["shift_ffn"].dtype))
        else:
            y, _ = _ffn_apply(lp, cfg, h[:, None, :])
            y = y[:, 0] if y.ndim == 3 else y
        x = x + y
        new_layers.append(lc)
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    new_cache = dict(cache, layers=tuple(new_layers))
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: full forward + cache population
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, max_seq: int, *,
            prefix_embeds=None, enc_frames=None, dtype=jnp.bfloat16
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Run full forward over the prompt and build a dense decode cache.

    Returns (last-position logits (B, V), cache ready for decode at
    position = S_total).
    """
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None and cfg.encoder is None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S_tot = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_tot), (B, S_tot))
    cache = zeros_cache(cfg, B, max_seq, dtype)
    enc_out = None
    if cfg.encoder is not None:
        frames = enc_frames if enc_frames is not None else prefix_embeds
        enc_out = encode(params, cfg, frames)
        x = x + sinusoidal_positions(S_tot, cfg.d_model).astype(x.dtype)[None]
    new_layers = []
    cross = []
    for i, lp in enumerate(params["layers"]):
        kind = cfg.layer_kind(i)
        lc = cache["layers"][i]
        h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
        if kind == "attn":
            if cfg.attention_kind == "mla":
                y, (ckv, k_rope) = attn.mla_forward(lp["attn"], cfg, h,
                                                    positions, i,
                                                    return_kv=True)
                lc = {
                    "ckv": jax.lax.dynamic_update_slice(
                        lc["ckv"], ckv.astype(lc["ckv"].dtype), (0, 0, 0)),
                    "k_rope": jax.lax.dynamic_update_slice(
                        lc["k_rope"], k_rope.astype(lc["k_rope"].dtype),
                        (0, 0, 0)),
                }
            else:
                y, (k, v) = attn.gqa_forward(lp["attn"], cfg, h, positions, i,
                                             return_kv=True)
                Sc = lc["k"].shape[1]
                if Sc < S_tot:  # windowed ring buffer: keep last Sc tokens
                    k, v = k[:, -Sc:], v[:, -Sc:]
                    # ring layout: token p lives at slot p % Sc
                    start = (S_tot - Sc) % Sc
                    k = jnp.roll(k, start, axis=1)
                    v = jnp.roll(v, start, axis=1)
                    lc = {"k": k.astype(lc["k"].dtype),
                          "v": v.astype(lc["v"].dtype)}
                else:
                    lc = {
                        "k": jax.lax.dynamic_update_slice(
                            lc["k"], k.astype(lc["k"].dtype), (0, 0, 0, 0)),
                        "v": jax.lax.dynamic_update_slice(
                            lc["v"], v.astype(lc["v"].dtype), (0, 0, 0, 0)),
                    }
        elif kind == "mamba":
            y, st = ssm.mamba_forward(lp["mamba"], cfg, h)
            lc = {"conv": st["conv"].astype(lc["conv"].dtype),
                  "ssm": st["ssm"]}
        elif kind == "rwkv":
            zero = {"wkv": jnp.zeros_like(lc["wkv"]),
                    "shift": jnp.zeros_like(lc["shift"])}
            y, st = ssm.rwkv6_time_mix(lp["rwkv"], cfg, h, zero)
            lc = {"wkv": st["wkv"],
                  "shift": st["shift"].astype(lc["shift"].dtype),
                  "shift_ffn": lc["shift_ffn"]}
        x = x + y
        if cfg.encoder is not None:
            hc = rmsnorm(lp["norm_cross"], x, cfg.norm_eps)
            k_c, v_c = attn.cross_attn_kv(lp["cross"], cfg, enc_out)
            x = x + attn.cross_attn_forward(lp["cross"], cfg, hc, k_c, v_c)
            cross.append({"k": k_c.astype(dtype), "v": v_c.astype(dtype)})
        h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if kind == "rwkv":
            y, sh = ssm.rwkv6_channel_mix(
                lp["ffn"], h, jnp.zeros((B, cfg.d_model), h.dtype))
            lc = dict(lc, shift_ffn=sh.astype(lc["shift_ffn"].dtype))
        else:
            y, _ = _ffn_apply(lp, cfg, h)
        x = x + y
        new_layers.append(lc)
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1], cfg.tie_embeddings)
    new_cache: Dict[str, Any] = {"layers": tuple(new_layers)}
    if cfg.encoder is not None:
        new_cache["cross"] = tuple(cross)
    return logits, new_cache
