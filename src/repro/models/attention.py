"""Attention blocks: GQA (w/ qk-norm, sliding window) and MLA (deepseek).

Each block exposes:
  *_init(key, cfg)                      -> params
  *_forward(params, cfg, x, positions, layer_idx, kv_write=None)
        full-sequence (train / prefill); optionally returns written K/V
  *_decode(params, cfg, x_t, cache, position, layer_idx)
        one-token decode against a dense cache dict
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import _dense_init, apply_rope, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "w_q": _dense_init(ks[0], (d, cfg.num_heads * hd), dtype=dtype),
        "w_k": _dense_init(ks[1], (d, cfg.num_kv_heads * hd), dtype=dtype),
        "w_v": _dense_init(ks[2], (d, cfg.num_kv_heads * hd), dtype=dtype),
        "w_o": _dense_init(ks[3], (cfg.num_heads * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _gqa_qkv(params, cfg: ModelConfig, x, positions):
    """x: (B, S, d) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with rope+qknorm."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(B, S, cfg.num_heads, hd)
    k = (x @ params["w_k"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ params["w_v"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(params, cfg: ModelConfig, x, positions, layer_idx: int,
                *, causal: bool = True,
                return_kv: bool = False,
                segment_ids=None):
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    window = 0
    if cfg.sliding_window > 0 and not cfg.is_global_attn_layer(layer_idx):
        window = cfg.sliding_window
    out = kops.flash_attention(q, k, v, causal=causal, window=window,
                               segment_ids=segment_ids)
    B, S, _, _ = out.shape
    y = out.reshape(B, S, -1) @ params["w_o"]
    if return_kv:
        return y, (k, v)
    return y


def _cache_write(cache_arr, new_vals, slot, kv_update: str):
    """Insert one token per row into a (B, S, ...) cache.

    ``scatter``: per-row dynamic_update_slice (vmap -> scatter HLO).  Under
    GSPMD with the sequence dim sharded this forces an involuntary
    resharding/remat of the whole cache (observed in the baseline dry-run).
    ``masked``: one-hot jnp.where — elementwise, so the cache's sharding is
    preserved and only the (tiny) new KV is replicated.  Same result.
    """
    if kv_update == "masked":
        S = cache_arr.shape[1]
        iota = jnp.arange(S, dtype=slot.dtype)
        onehot = iota[None, :] == slot[:, None]           # (B, S)
        onehot = onehot.reshape(onehot.shape + (1,) * (cache_arr.ndim - 2))
        return jnp.where(onehot, new_vals[:, None].astype(cache_arr.dtype),
                         cache_arr)
    return jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice(
        c, n[None].astype(c.dtype), (s,) + (0,) * (c.ndim - 1)
    ))(cache_arr, new_vals, slot)


def gqa_decode(params, cfg: ModelConfig, x_t, cache: dict, position,
               layer_idx: int, kv_update: str = "scatter"
               ) -> Tuple[jnp.ndarray, dict]:
    """x_t: (B, d); cache {k,v: (B, S, Hkv, hd)}; position: (B,) int32."""
    B, _ = x_t.shape
    hd = cfg.resolved_head_dim
    x1 = x_t[:, None, :]  # (B,1,d)
    q, k, v = _gqa_qkv(params, cfg, x1, position[:, None])
    q = q[:, 0]  # (B,Hq,hd)
    k, v = k[:, 0], v[:, 0]
    window = 0
    if cfg.sliding_window > 0 and not cfg.is_global_attn_layer(layer_idx):
        window = cfg.sliding_window
    S = cache["k"].shape[1]
    # ring-buffer write for windowed layers whose cache is only `window` long
    slot = position % S
    k_cache = _cache_write(cache["k"], k, slot, kv_update)
    v_cache = _cache_write(cache["v"], v, slot, kv_update)
    lengths = jnp.minimum(position + 1, S)
    eff_window = window if (window > 0 and S > window) else 0
    out = kops.decode_attention(q, k_cache, v_cache, lengths,
                                window=eff_window)
    y = out.reshape(B, -1) @ params["w_o"]
    return y, {"k": k_cache, "v": v_cache}


def gqa_cache_shape(cfg: ModelConfig, batch: int, seq: int, layer_idx: int,
                    dtype=jnp.bfloat16):
    """Dense-cache spec for this layer (windowed layers store only window)."""
    S = seq
    if cfg.sliding_window > 0 and not cfg.is_global_attn_layer(layer_idx):
        S = min(seq, cfg.sliding_window)
    hd = cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, S, cfg.num_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, S, cfg.num_kv_heads, hd), dtype),
    }


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig, enc_d: int, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "w_q": _dense_init(ks[0], (d, cfg.num_heads * hd), dtype=dtype),
        "w_k": _dense_init(ks[1], (enc_d, cfg.num_kv_heads * hd), dtype=dtype),
        "w_v": _dense_init(ks[2], (enc_d, cfg.num_kv_heads * hd), dtype=dtype),
        "w_o": _dense_init(ks[3], (cfg.num_heads * hd, d), dtype=dtype),
    }


def cross_attn_kv(params, cfg: ModelConfig, enc_out):
    """Precompute cross K/V once per request (shared by the whole tree)."""
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["w_k"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (enc_out @ params["w_v"]).reshape(B, S, cfg.num_kv_heads, hd)
    return k, v


def cross_attn_forward(params, cfg: ModelConfig, x, k, v):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(B, S, cfg.num_heads, hd)
    out = kops.flash_attention(q, k, v, causal=False)
    return out.reshape(B, S, -1) @ params["w_o"]


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): latent-compressed KV with decoupled rope
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": _dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, H * m.qk_head_dim), dtype=dtype),
        "w_dkv": _dense_init(ks[2], (d, m.kv_lora_rank), dtype=dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": _dense_init(ks[3], (d, m.qk_rope_head_dim), dtype=dtype),
        "w_uk": _dense_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype=dtype),
        "w_uv": _dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim), dtype=dtype),
        "w_o": _dense_init(ks[6], (H * m.v_head_dim, d), dtype=dtype),
    }


def _mla_q(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"], cfg.norm_eps)
    q = (cq @ params["w_uq"]).reshape(B, S, H, m.qk_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        cfg.rope_theta or 10_000.0)
    return q_nope, q_rope


def _mla_latents(params, cfg, x, positions):
    m = cfg.mla
    ckv = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta or 10_000.0)[:, :, 0]
    return ckv, k_rope  # (B,S,r), (B,S,rope_dim)


def mla_forward(params, cfg: ModelConfig, x, positions, layer_idx: int,
                *, causal: bool = True, return_kv: bool = False,
                segment_ids=None):
    """Decompressed (train/prefill) MLA: materialize per-head K/V."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv, k_rope = _mla_latents(params, cfg, x, positions)
    k_nope = (ckv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (ckv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = 1.0 / (m.qk_head_dim ** 0.5)
    out = kops.flash_attention(q, k, v, causal=causal, scale=scale,
                               segment_ids=segment_ids)
    y = out.reshape(B, S, -1) @ params["w_o"]
    if return_kv:
        return y, (ckv, k_rope)
    return y


def mla_decode(params, cfg: ModelConfig, x_t, cache: dict, position,
               layer_idx: int, kv_update: str = "scatter"):
    """Absorbed-form decode: score/aggregate in the 512-d latent space.

    The KV cache stores only (ckv, k_rope) per token — the MLA compression
    the paper's tree sharing composes with (DESIGN.md §4).
    """
    m = cfg.mla
    B, _ = x_t.shape
    H = cfg.num_heads
    x1 = x_t[:, None, :]
    q_nope, q_rope = _mla_q(params, cfg, x1, position[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]  # (B,H,·)
    ckv_t, kr_t = _mla_latents(params, cfg, x1, position[:, None])
    S = cache["ckv"].shape[1]
    ckv_cache = _cache_write(cache["ckv"], ckv_t[:, 0], position, kv_update)
    kr_cache = _cache_write(cache["k_rope"], kr_t[:, 0], position,
                            kv_update)
    # absorb W_uk into q: q_lat (B,H,r)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / (m.qk_head_dim ** 0.5)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat,
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                           kr_cache.astype(jnp.float32))) * scale
    valid = jnp.arange(S)[None, :] < (position + 1)[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, ckv_cache.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    y = o.reshape(B, -1).astype(x_t.dtype) @ params["w_o"]
    return y, {"ckv": ckv_cache, "k_rope": kr_cache}


def mla_cache_shape(cfg: ModelConfig, batch: int, seq: int, layer_idx: int,
                    dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, seq, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, seq, m.qk_rope_head_dim), dtype),
    }
