"""AdamW from scratch (decoupled weight decay), pytree-native.

Built for sharded training: the (m, v) moments are pytrees with the same
structure as params, so whatever sharding rule applies to a parameter applies
to its optimizer state (ZeRO-3-equivalent under pjit — DESIGN.md §6).
Master-weight discipline: moments and updates in f32 even for bf16 params.

Schedule per the paper (§3.1): linear warmup (10 steps) to a constant 1e-6.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # () int32
    m: Any                 # pytree like params (f32)
    v: Any                 # pytree like params (f32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def warmup_constant_schedule(base_lr: float,
                             warmup_steps: int) -> Callable[[jnp.ndarray],
                                                            jnp.ndarray]:
    def lr_at(step):
        frac = jnp.minimum(
            (step.astype(jnp.float32) + 1.0) / max(warmup_steps, 1), 1.0)
        return base_lr * frac
    return lr_at


def adamw_update(params, grads, state: AdamWState, *,
                 lr, beta1: float = 0.9, beta2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.0
                 ) -> Tuple[Any, AdamWState]:
    """One AdamW step.  ``lr`` may be a scalar or a schedule value."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = beta1 * m + (1.0 - beta1) * gf
        v_new = beta2 * v + (1.0 - beta2) * gf * gf
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
