from repro.checkpoint.store import (
    latest_step,
    list_steps,
    load_checkpoint,
    prune_checkpoints,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "list_steps", "prune_checkpoints"]
