"""Step-indexed pytree checkpoints: msgpack + zstd.

Arrays are serialized as (dtype, shape, raw bytes); the pytree structure is
round-tripped via a nested (dict/list/tuple/scalar) skeleton.  Writes are
atomic (tmp + rename) so an interrupted save never corrupts the latest
checkpoint.  Save interval per the paper: every 50 steps.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dep: gate so importing repro.checkpoint never hard-fails
    import zstandard
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None


def _require_zstd():
    if zstandard is None:
        raise ImportError(
            "checkpoint save/load needs the 'zstandard' package "
            "(not installed in this environment)")
    return zstandard


_ARR_KEY = "__nd__"
_TUP_KEY = "__tuple__"


def _pack(obj: Any) -> Any:
    if isinstance(obj, (jnp.ndarray, np.ndarray)) or hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        return {_ARR_KEY: True, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "data": arr.tobytes()}
    if isinstance(obj, dict):
        return {k: _pack(v) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUP_KEY: [_pack(v) for v in obj]}
    if isinstance(obj, list):
        return [_pack(v) for v in obj]
    return obj


def _unpack(obj: Any) -> Any:
    if isinstance(obj, dict):
        if obj.get(_ARR_KEY):
            arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"]))
            return jnp.asarray(arr.reshape(obj["shape"]))
        if _TUP_KEY in obj:
            return tuple(_unpack(v) for v in obj[_TUP_KEY])
        return {k: _unpack(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unpack(v) for v in obj]
    return obj


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tree = jax.device_get(tree)
    payload = msgpack.packb(_pack(tree), use_bin_type=True)
    compressed = _require_zstd().ZstdCompressor(level=3).compress(payload)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(compressed)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.ckpt", fn))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None) -> Any:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    with open(path, "rb") as f:
        compressed = f.read()
    payload = _require_zstd().ZstdDecompressor().decompress(compressed)
    return _unpack(msgpack.unpackb(payload, raw=False))
