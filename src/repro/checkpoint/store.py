"""Step-indexed pytree checkpoints: msgpack + zstd/zlib.

Arrays are serialized as (dtype, shape, raw bytes); the pytree structure
is round-tripped via a nested (dict/list/tuple/scalar) skeleton.  Writes
are crash-safe (tmp + fsync + rename + dir fsync) so an interruption at
ANY point — mid-write, pre-rename, post-rename — leaves the latest
*complete* checkpoint loadable (``latest_step`` only matches final
``step_NNNNNNNN.ckpt`` names, never ``.tmp`` leftovers).  Save interval
per the paper: every 50 steps.

File format: 4-byte magic ``RPCK`` + 1 codec byte (``Z`` = zstd, ``z`` =
zlib) + compressed msgpack payload.  zlib is the stdlib fallback used
when the optional ``zstandard`` package is absent; a headerless file is
a legacy zstd checkpoint from before the header existed.

Low-precision dtypes (bfloat16, float8_*) resolve through ``ml_dtypes``
— ``np.dtype("bfloat16")`` alone raises, so a bf16 checkpoint written on
one host must not become unreadable on another (satellite fix, PR 7).
"""
from __future__ import annotations

import os
import re
import zlib
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dep: gate so importing repro.checkpoint never hard-fails
    import zstandard
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None

try:  # jax ships it, but keep the store importable without
    import ml_dtypes
except ImportError:  # pragma: no cover - environment-dependent
    ml_dtypes = None

_MAGIC = b"RPCK"
_CODEC_ZSTD = b"Z"
_CODEC_ZLIB = b"z"

_ARR_KEY = "__nd__"
_TUP_KEY = "__tuple__"


def _resolve_dtype(name: str) -> np.dtype:
    """``np.dtype`` with an ``ml_dtypes`` fallback: numpy alone rejects
    'bfloat16' / 'float8_e4m3fn' / ... even though the arrays themselves
    round-trip fine as raw bytes."""
    try:
        return np.dtype(name)
    except TypeError:
        if ml_dtypes is not None:
            t = getattr(ml_dtypes, name, None)
            if t is not None:
                return np.dtype(t)
        raise


def _pack(obj: Any) -> Any:
    if isinstance(obj, (jnp.ndarray, np.ndarray)) or hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        return {_ARR_KEY: True, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "data": arr.tobytes()}
    if isinstance(obj, dict):
        return {k: _pack(v) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUP_KEY: [_pack(v) for v in obj]}
    if isinstance(obj, list):
        return [_pack(v) for v in obj]
    return obj


def _unpack(obj: Any) -> Any:
    if isinstance(obj, dict):
        if obj.get(_ARR_KEY):
            arr = np.frombuffer(obj["data"],
                                dtype=_resolve_dtype(obj["dtype"]))
            return jnp.asarray(arr.reshape(obj["shape"]))
        if _TUP_KEY in obj:
            return tuple(_unpack(v) for v in obj[_TUP_KEY])
        return {k: _unpack(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unpack(v) for v in obj]
    return obj


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        body = zstandard.ZstdCompressor(level=3).compress(payload)
        return _MAGIC + _CODEC_ZSTD + body
    return _MAGIC + _CODEC_ZLIB + zlib.compress(payload, 3)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _MAGIC:
        codec, body = blob[4:5], blob[5:]
        if codec == _CODEC_ZLIB:
            return zlib.decompress(body)
        if codec == _CODEC_ZSTD:
            if zstandard is None:
                raise ImportError(
                    "zstd-compressed checkpoint needs the 'zstandard' "
                    "package (not installed in this environment)")
            return zstandard.ZstdDecompressor().decompress(body)
        raise ValueError(f"unknown checkpoint codec byte {codec!r}")
    # legacy headerless format: always zstd
    if zstandard is None:
        raise ImportError(
            "legacy checkpoint needs the 'zstandard' package "
            "(not installed in this environment)")
    return zstandard.ZstdDecompressor().decompress(blob)


def _ckpt_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    keep_last: Optional[int] = None) -> str:
    """Atomically write ``step_NNNNNNNN.ckpt``; with ``keep_last=N``,
    prune older checkpoints (and any stale ``.tmp`` from a past crash)
    down to the newest N after the rename lands."""
    from repro.core import faults  # lazy: kill-point hooks, no-op inert

    os.makedirs(ckpt_dir, exist_ok=True)
    tree = jax.device_get(tree)
    payload = msgpack.packb(_pack(tree), use_bin_type=True)
    blob = _compress(payload)
    path = _ckpt_path(ckpt_dir, step)
    tmp = path + ".tmp"
    faults.kill_point("ckpt.pre_write")
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    faults.kill_point("ckpt.pre_rename")
    os.replace(tmp, path)
    _fsync_dir(ckpt_dir)
    faults.kill_point("ckpt.post_rename")
    if keep_last is not None:
        prune_checkpoints(ckpt_dir, keep_last)
    return path


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(m.group(1)) for fn in os.listdir(ckpt_dir)
                  if (m := re.fullmatch(r"step_(\d+)\.ckpt", fn)))


def prune_checkpoints(ckpt_dir: str, keep_last: int) -> None:
    """Delete all but the newest ``keep_last`` checkpoints, plus any
    orphaned ``.tmp`` files left by an interrupted save."""
    keep = set(list_steps(ckpt_dir)[-max(keep_last, 1):])
    for fn in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, fn)
        if fn.endswith(".ckpt.tmp"):
            os.remove(full)
        elif (m := re.fullmatch(r"step_(\d+)\.ckpt", fn)) \
                and int(m.group(1)) not in keep:
            os.remove(full)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None) -> Any:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with open(_ckpt_path(ckpt_dir, step), "rb") as f:
        blob = f.read()
    return _unpack(msgpack.unpackb(_decompress(blob), raw=False))
