"""Cross-request radix cache over the paged KV pool.

TreePO's in-tree forks amortize KV *within* one query tree; serving real
traffic repeats prefixes *across* requests too — system prompts, few-shot
templates, the same benchmark question asked twice.  This module keeps a
radix tree keyed by page-sized token blocks whose nodes own refcounted
pages of ``PagePool`` (the SGL-JAX radix-cache design, SNIPPETS.md §1),
so a new request's prompt prefix can point its block table at KV pages
some earlier request already computed — the exact COW refcounting
discipline in-tree forks use, extended across requests.

Ownership protocol (what keeps ``lifecycle_guard`` conservation exact):

* every page stored in the tree carries exactly ONE cache-owned refcount
  (taken at :meth:`insert`); live paths referencing the same page hold
  their own refs on top;
* :meth:`match_prefix` retains every page it hands out — the caller puts
  them straight into an ``EnginePath`` table and releases them through
  the normal path lifecycle;
* :meth:`evict` drops whole least-recently-used leaves, releasing the
  cache's ref per page.  A page a live path still references therefore
  stays allocated (its refcount just drops by one) — eviction can never
  free KV out from under a running request.

Matches are page-granular and capped one token short of the prompt
(``(len(tokens) - 1) // page_size`` blocks): the serve loop must re-feed
at least the final prompt token to obtain the boundary logits it samples
the first generated token from.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.kv.cache import PagePool

__all__ = ["RadixCache", "RadixNode"]

Block = Tuple[int, ...]


class RadixNode:
    """One edge of the radix tree: a run of page-sized token blocks and
    the pages holding their KV, compressed path-style (Patricia trie)."""

    __slots__ = ("blocks", "pages", "children", "parent", "last_access")

    def __init__(self, blocks: List[Block], pages: List[int],
                 parent: Optional["RadixNode"], last_access: int):
        self.blocks = blocks
        self.pages = pages
        self.children: Dict[Block, "RadixNode"] = {}
        self.parent = parent
        self.last_access = last_access

    def is_leaf(self) -> bool:
        return not self.children


class RadixCache:
    """Radix tree of cached prompt-prefix KV pages over one ``PagePool``."""

    def __init__(self, pool: PagePool, page_size: int):
        self.pool = pool
        self.page_size = page_size
        self.root = RadixNode([], [], None, 0)
        self._clock = 0
        self.cached_pages = 0      # pages currently owned by the cache
        self.hit_tokens = 0        # prompt tokens served from cache
        self.evicted_pages = 0     # cache-owned refs dropped by eviction
        self.insertions = 0
        self.lookups = 0

    # -- internals ----------------------------------------------------------

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _blocks(self, tokens: Sequence[int], n: int) -> List[Block]:
        ps = self.page_size
        return [tuple(tokens[i * ps:(i + 1) * ps]) for i in range(n)]

    # -- lookup -------------------------------------------------------------

    def match_prefix(self, tokens: Sequence[int]
                     ) -> Tuple[List[int], int]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(pages, matched_tokens)``; every returned page has been
        retained for the caller.  The match is capped one token short of
        the sequence so the caller always recomputes the boundary token.
        """
        self.lookups += 1
        limit = max(0, (len(tokens) - 1) // self.page_size)
        blocks = self._blocks(tokens, limit)
        pages: List[int] = []
        node = self.root
        stamp = self._tick()
        i = 0
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                break
            n = 0
            while (n < len(child.blocks) and i + n < len(blocks)
                   and child.blocks[n] == blocks[i + n]):
                n += 1
            pages.extend(child.pages[:n])
            child.last_access = stamp
            i += n
            if n < len(child.blocks):
                break           # partial-edge hit: take the page prefix
            node = child
        for pid in pages:
            self.pool.retain(pid)
        self.hit_tokens += i * self.page_size
        return pages, i * self.page_size

    # -- insert -------------------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Cache ``pages`` as the KV of the page-aligned token prefix.

        Walks the tree deduplicating against what is already cached (an
        identical block run keeps the incumbent pages — the caller's
        duplicates are simply not cached) and retains one cache-owned ref
        on every page of the new suffix.  Returns how many pages the
        cache newly took ownership of.
        """
        n = len(pages)
        assert len(tokens) >= n * self.page_size, \
            "insert needs page-aligned tokens covering every page"
        blocks = self._blocks(tokens, n)
        node = self.root
        stamp = self._tick()
        i = 0
        while i < n:
            child = node.children.get(blocks[i])
            if child is None:
                new = RadixNode(blocks[i:], list(pages[i:]), node, stamp)
                node.children[blocks[i]] = new
                for pid in new.pages:
                    self.pool.retain(pid)
                self.cached_pages += len(new.pages)
                self.insertions += 1
                return n - i
            m = 0
            while (m < len(child.blocks) and i + m < n
                   and child.blocks[m] == blocks[i + m]):
                m += 1
            child.last_access = stamp
            if m == len(child.blocks):
                node = child
                i += m
                continue
            # split the edge at the divergence point: a mid node keeps the
            # shared block prefix (and its pages), the incumbent child
            # re-parents under it with the suffix
            mid = RadixNode(child.blocks[:m], child.pages[:m], node, stamp)
            node.children[blocks[i]] = mid
            child.blocks = child.blocks[m:]
            child.pages = child.pages[m:]
            child.parent = mid
            mid.children[child.blocks[0]] = child
            node = mid
            i += m
        return 0

    # -- eviction -----------------------------------------------------------

    def _lru_leaf(self) -> Optional[RadixNode]:
        best: Optional[RadixNode] = None
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if nd.is_leaf():
                if best is None or nd.last_access < best.last_access:
                    best = nd
            else:
                stack.extend(nd.children.values())
        return best

    def evict(self, need: int) -> int:
        """Drop least-recently-used whole leaves until at least ``need``
        pages actually returned to the pool's free list (pages live paths
        still reference stay allocated and don't count), or the cache is
        empty.  Returns the number of pages freed to the pool."""
        freed = 0
        while freed < need:
            leaf = self._lru_leaf()
            if leaf is None:
                break
            for pid in leaf.pages:
                if int(self.pool.refcount[pid]) == 1:
                    freed += 1
                self.pool.release(pid)
            self.cached_pages -= len(leaf.pages)
            self.evicted_pages += len(leaf.pages)
            parent = leaf.parent
            del parent.children[leaf.blocks[0]]
            # collapse a now-childless interior run into nothing extra:
            # its pages remain cached and it is itself a leaf candidate
        return freed

    # -- introspection ------------------------------------------------------

    def _walk_pages(self) -> List[int]:
        out: List[int] = []
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            out.extend(nd.pages)
            stack.extend(nd.children.values())
        return out

    @property
    def evictable_pages(self) -> int:
        """Cached pages whose only ref is the cache's — reclaimable
        immediately without touching any live path."""
        return sum(1 for pid in self._walk_pages()
                   if int(self.pool.refcount[pid]) == 1)
