"""Paged KV cache with tree-structured prefix sharing.

The device side is a set of fixed-size pools — by default one **fused**
array per attention layer with K/V head-interleaved on the head axis
(``(num_pages, page_size, 2*n_kv, head_dim)``, layout contract in
``repro.kv.layout``) so one page DMA ships both halves; with
``fused_kv=False`` the legacy split K / V pools (the parity oracle) —
plus recurrent-state slot arrays for SSM/hybrid layers.  The host side is a page
allocator with **refcounts**: forking a search path at a segment boundary
copies the child's *block table* (a Python list of page ids) and bumps the
refcount of every shared page — KV data of full pages is never copied (the
paper's prefix amortization).  A branch at a non-page-aligned boundary
copies-on-write at most the one partial tail page.

Recurrent state (Mamba conv/ssm, RWKV wkv/shift) *is* copied on fork — it is
a running reduction, not a prefix (DESIGN.md §4).  Both kinds of fork copy
(COW page rows, slot rows) are collected per branching round and applied by
:meth:`PagedKVState.apply_forks` in a single jitted multi-layer dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class OutOfPages(RuntimeError):
    """KV page / slot pool exhaustion, carrying allocator diagnostics.

    The allocator fills in pool occupancy; the engine/sampler *annotate*
    the in-flight exception with live-path and per-query page counts so a
    real exhaustion is debuggable from the exception text alone (under
    the pressure protocol — `docs/robustness.md` — one of these escaping
    a rollout is itself a bug report)."""

    def __init__(self, msg: str, *, pages_in_use: Optional[int] = None,
                 num_pages: Optional[int] = None):
        super().__init__(msg)
        self.base_msg = msg
        self.pages_in_use = pages_in_use
        self.num_pages = num_pages
        self.live_paths: Optional[int] = None
        self.per_query_pages: Optional[Dict[int, int]] = None
        self.radix_pages: Optional[int] = None
        self.radix_evictable: Optional[int] = None

    def annotate(self, *, live_paths: Optional[int] = None,
                 per_query_pages: Optional[Dict[int, int]] = None,
                 radix_pages: Optional[int] = None,
                 radix_evictable: Optional[int] = None
                 ) -> "OutOfPages":
        if live_paths is not None:
            self.live_paths = live_paths
        if per_query_pages is not None:
            self.per_query_pages = dict(per_query_pages)
        if radix_pages is not None:
            self.radix_pages = radix_pages
        if radix_evictable is not None:
            self.radix_evictable = radix_evictable
        return self

    def __str__(self) -> str:
        parts = [self.base_msg]
        if self.pages_in_use is not None and self.num_pages is not None:
            parts.append(f"pages_in_use={self.pages_in_use}"
                         f"/{self.num_pages}")
        if self.live_paths is not None:
            parts.append(f"live_paths={self.live_paths}")
        if self.per_query_pages:
            per_q = ", ".join(f"q{q}:{n}" for q, n in
                              sorted(self.per_query_pages.items()))
            parts.append(f"per_query_pages={{{per_q}}}")
        if self.radix_pages is not None:
            ev = 0 if self.radix_evictable is None else self.radix_evictable
            parts.append(f"radix_pages={self.radix_pages}(evictable {ev})")
        return " | ".join(parts)


# Fault-injection hook (see repro.core.faults).  FaultInjector installs
# its `fires` callable here on arm — a module global rather than an
# import, because repro.core.engine imports this module at package init.
fault_hook = None


def bucket_pow2(n: int, minimum: int = 1) -> int:
    """Round up to the next power of two — THE jit-shape bucketing policy
    (engine batch/seq buckets and apply_forks pad buckets share it)."""
    return max(minimum, 1 << (max(n, 1) - 1).bit_length())


@dataclasses.dataclass
class PagePool:
    """Host-side page allocator with refcounts."""

    num_pages: int

    def __post_init__(self):
        self.refcount = np.zeros(self.num_pages, dtype=np.int32)
        self.free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self._in_use = 0          # incremental |{p: refcount[p] > 0}|
        self.peak_in_use = 0      # high-water mark (pool-sizing signal)

    def alloc(self) -> int:
        if fault_hook is not None and fault_hook("page_pool.alloc"):
            raise OutOfPages("injected page exhaustion",
                             pages_in_use=self._in_use,
                             num_pages=self.num_pages)
        if not self.free:
            raise OutOfPages("pool exhausted",
                             pages_in_use=self._in_use,
                             num_pages=self.num_pages)
        pid = self.free.pop()
        assert self.refcount[pid] == 0
        self.refcount[pid] = 1
        self._in_use += 1
        if self._in_use > self.peak_in_use:
            self.peak_in_use = self._in_use
        return pid

    def retain(self, pid: int) -> None:
        assert self.refcount[pid] > 0
        self.refcount[pid] += 1

    def release(self, pid: int) -> None:
        assert self.refcount[pid] > 0
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self.free.append(pid)
            self._in_use -= 1

    @property
    def pages_in_use(self) -> int:
        # maintained incrementally: alloc/release are on the per-token hot
        # path and an O(num_pages) refcount scan here dominated them.
        return self._in_use

    @property
    def num_free(self) -> int:
        return len(self.free)

    @property
    def watermark(self) -> float:
        """Pool occupancy in [0, 1] — the pressure signal the engine and
        branching heuristic consult (`docs/robustness.md`)."""
        return self._in_use / max(self.num_pages, 1)


class SlotAllocator:
    """Fixed pool of per-path slots (recurrent state / scratch rows)."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.free: List[int] = list(range(num_slots - 1, -1, -1))

    def alloc(self) -> int:
        if not self.free:
            raise OutOfPages(
                f"slots exhausted ({self.in_use}/{self.num_slots} slots)")
        return self.free.pop()

    def release(self, slot: int) -> None:
        self.free.append(slot)

    @property
    def in_use(self) -> int:
        return self.num_slots - len(self.free)

    @property
    def watermark(self) -> float:
        return self.in_use / max(self.num_slots, 1)


class PagedKVState:
    """Device arrays + host bookkeeping for the tree engine.

    Layout (``fused_kv=True``, the default — ``repro.kv.layout``):
      kv_pools: per attn layer {"kv": (P, page, 2*n_kv, hd)} with heads
                ``[K0,V0,K1,V1,...]`` (MLA: {"kv": (P, page, r + rd)} with
                ``[ckv | k_rope]`` on the feature axis) — one array per
                layer, so a page is one DMA and a fork COW copy can never
                split K from V.
    Legacy layout (``fused_kv=False``, parity oracle):
      kv_pools: per attn layer {"k": (P, page, n_kv, hd), "v": ...}
                (MLA layers: {"ckv": (P, page, r), "k_rope": (P, page, rd)})
    Either way:
      rec_state: per recurrent layer, slot-indexed state arrays
                 (S_max, ...) — slot dim first.
    """

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 max_slots: int, dtype=jnp.float32, fused_kv: bool = True):
        self.cfg = cfg
        self.page_size = page_size
        self.pool = PagePool(num_pages)
        self.slots = SlotAllocator(max_slots)
        self.dtype = dtype
        self.fused_kv = fused_kv
        hd = cfg.resolved_head_dim
        self.kv_pools: Dict[int, Dict[str, jnp.ndarray]] = {}
        self.rec_state: Dict[int, Dict[str, jnp.ndarray]] = {}
        for i in range(cfg.num_layers):
            kind = cfg.layer_kind(i)
            if kind == "attn":
                if cfg.attention_kind == "mla":
                    m = cfg.mla
                    if fused_kv:
                        self.kv_pools[i] = {
                            "kv": jnp.zeros(
                                (num_pages, page_size,
                                 m.kv_lora_rank + m.qk_rope_head_dim),
                                dtype),
                        }
                    else:
                        self.kv_pools[i] = {
                            "ckv": jnp.zeros((num_pages, page_size,
                                              m.kv_lora_rank), dtype),
                            "k_rope": jnp.zeros((num_pages, page_size,
                                                 m.qk_rope_head_dim),
                                                dtype),
                        }
                elif fused_kv:
                    self.kv_pools[i] = {
                        "kv": jnp.zeros((num_pages, page_size,
                                         2 * cfg.num_kv_heads, hd), dtype),
                    }
                else:
                    self.kv_pools[i] = {
                        "k": jnp.zeros((num_pages, page_size,
                                        cfg.num_kv_heads, hd), dtype),
                        "v": jnp.zeros((num_pages, page_size,
                                        cfg.num_kv_heads, hd), dtype),
                    }
            elif kind == "mamba":
                mc = cfg.mamba
                d_in = mc.expand * cfg.d_model
                self.rec_state[i] = {
                    "conv": jnp.zeros((max_slots, mc.d_conv - 1, d_in), dtype),
                    "ssm": jnp.zeros((max_slots, d_in, mc.d_state),
                                     jnp.float32),
                }
            elif kind == "rwkv":
                rc = cfg.rwkv
                H = cfg.d_model // rc.head_dim
                self.rec_state[i] = {
                    "wkv": jnp.zeros((max_slots, H, rc.head_dim, rc.head_dim),
                                     jnp.float32),
                    "shift": jnp.zeros((max_slots, cfg.d_model), dtype),
                    "shift_ffn": jnp.zeros((max_slots, cfg.d_model), dtype),
                }
        # whisper cross-attention KV: per request, shared by every branch
        self.cross_kv: Optional[tuple] = None
        # jitted fork-copy dispatches, cached per (page-, slot-count) bucket
        self._fork_fns: Dict[tuple, object] = {}

    # -- host bookkeeping ---------------------------------------------------

    def fork_table(self, table: List[int]) -> List[int]:
        """Child block table sharing every page of the parent prefix."""
        for pid in table:
            self.pool.retain(pid)
        return list(table)

    def release_table(self, table: List[int]) -> None:
        for pid in table:
            self.pool.release(pid)

    # -- batched fork application -------------------------------------------

    @staticmethod
    def _pad_pairs(src: List[int], dst: List[int]) -> tuple:
        """Pad (src, dst) to a power-of-two bucket so jit caches a few
        shapes, not one per round.  Padding repeats the first real pair:
        duplicate scatter updates to one index are order-unspecified in
        JAX, but duplicates of the *same* (src, dst) write identical bytes,
        so the result stays deterministic whatever rows the caller uses."""
        # imported late: repro.core's __init__ pulls in engine, which
        # imports this module — at call time the cycle has resolved
        from repro.core.guard import annotated_transfer

        n = len(src)
        if n == 0:
            return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
                    0)
        nb = bucket_pow2(n)
        pad = nb - n
        src_d, dst_d = annotated_transfer(
            (np.asarray(list(src) + [src[0]] * pad, np.int32),
             np.asarray(list(dst) + [dst[0]] * pad, np.int32)),
            to="device", reason="fork-tables")
        return (src_d, dst_d, nb)

    def _get_fork_fn(self, n_pages: int, n_slots: int):
        """Jitted multi-layer copy, shaped by which state kinds fork this
        round.  The function only takes (and returns) the pytrees it
        mutates — an untouched pool routed through jit would come back as
        a fresh output buffer, i.e. a full pool copy per round."""
        key = (n_pages, n_slots)
        if key not in self._fork_fns:
            def copy_rows(tree, src, dst):
                return {i: {k: v.at[dst].set(v[src]) for k, v in st.items()}
                        for i, st in tree.items()}

            if n_pages and n_slots:
                def fork_fn(pools, rec, psrc, pdst, ssrc, sdst):
                    return (copy_rows(pools, psrc, pdst),
                            copy_rows(rec, ssrc, sdst))
                donate = (0, 1)
            elif n_pages:
                def fork_fn(pools, psrc, pdst):
                    return copy_rows(pools, psrc, pdst)
                donate = (0,)
            else:
                def fork_fn(rec, ssrc, sdst):
                    return copy_rows(rec, ssrc, sdst)
                donate = (0,)
            # donate the pools/rec buffers (the caller rebinds them to the
            # result) so XLA scatters the few forked rows in place instead
            # of copying whole (num_pages, ...) arrays each round; CPU has
            # no donation support and would warn per dispatch.
            if jax.default_backend() == "cpu":
                donate = ()
            self._fork_fns[key] = jax.jit(fork_fn, donate_argnums=donate)
        return self._fork_fns[key]

    def apply_forks(self, page_src: List[int], page_dst: List[int],
                    slot_src: List[int] = (), slot_dst: List[int] = ()
                    ) -> None:
        """Apply a whole branching round's fork copies in ONE jitted
        dispatch: COW page rows in every attention layer's pool and
        recurrent-state rows in every SSM/RWKV layer's slot arrays.

        The sources must still hold their pre-fork contents when this runs
        (the engine allocates fresh dst pages/slots, so a round's copies
        never alias), which is what lets dozens of per-fork-per-layer
        ``v.at[dst].set(v[src])`` dispatches collapse into one call.

        Atomicity: the pools/rec trees are rebound only after the jitted
        copy returns, so a failure here (pool OOM inside the dispatch, or
        the ``kv.apply_forks`` injection site below) leaves device state
        untouched — no fork can observe copied K with stale V, on either
        layout.  The caller still owns the *host* rollback: the freshly
        allocated dst pages/slots must go back via ``release_partial``
        (``TreeEngine.fork_paths`` does).
        """
        if fault_hook is not None and fault_hook("kv.apply_forks"):
            raise OutOfPages("injected apply_forks failure",
                             pages_in_use=self.pool.pages_in_use,
                             num_pages=self.pool.num_pages)
        if not self.rec_state:
            slot_src, slot_dst = [], []
        if not self.kv_pools:
            page_src, page_dst = [], []
        if not page_src and not slot_src:
            return
        psrc, pdst, npg = self._pad_pairs(list(page_src), list(page_dst))
        ssrc, sdst, nsl = self._pad_pairs(list(slot_src), list(slot_dst))
        fn = self._get_fork_fn(npg, nsl)
        if npg and nsl:
            self.kv_pools, self.rec_state = fn(self.kv_pools, self.rec_state,
                                               psrc, pdst, ssrc, sdst)
        elif npg:
            self.kv_pools = fn(self.kv_pools, psrc, pdst)
        else:
            self.rec_state = fn(self.rec_state, ssrc, sdst)

    # -- stats ---------------------------------------------------------------

    def kv_bytes_per_token(self) -> int:
        """Bytes of KV written per generated token (all attn layers)."""
        total = 0
        for pools in self.kv_pools.values():
            for arr in pools.values():
                per_tok = int(np.prod(arr.shape[2:])) * arr.dtype.itemsize
                total += per_tok
        return total
