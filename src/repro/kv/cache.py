"""Paged KV cache with tree-structured prefix sharing.

The device side is a set of fixed-size pools (one K and one V array per
attention layer, shape ``(num_pages, page_size, n_kv, head_dim)``) plus
recurrent-state slot arrays for SSM/hybrid layers.  The host side is a page
allocator with **refcounts**: forking a search path at a segment boundary
copies the child's *block table* (a Python list of page ids) and bumps the
refcount of every shared page — KV data is never copied (the paper's prefix
amortization).  Branches only ever happen at page-aligned segment
boundaries (DESIGN.md deviation #1 — the paper's own §4.2 shows misaligned
fallback is harmful), so copy-on-write is never needed.

Recurrent state (Mamba conv/ssm, RWKV wkv/shift) *is* copied on fork — it is
a running reduction, not a prefix (DESIGN.md §4) — via slot-to-slot device
copies batched per fork generation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class OutOfPages(RuntimeError):
    pass


@dataclasses.dataclass
class PagePool:
    """Host-side page allocator with refcounts."""

    num_pages: int

    def __post_init__(self):
        self.refcount = np.zeros(self.num_pages, dtype=np.int32)
        self.free: List[int] = list(range(self.num_pages - 1, -1, -1))

    def alloc(self) -> int:
        if not self.free:
            raise OutOfPages(f"pool exhausted ({self.num_pages} pages)")
        pid = self.free.pop()
        assert self.refcount[pid] == 0
        self.refcount[pid] = 1
        return pid

    def retain(self, pid: int) -> None:
        assert self.refcount[pid] > 0
        self.refcount[pid] += 1

    def release(self, pid: int) -> None:
        assert self.refcount[pid] > 0
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self.free.append(pid)

    @property
    def pages_in_use(self) -> int:
        return int((self.refcount > 0).sum())


class SlotAllocator:
    """Fixed pool of per-path slots (recurrent state / scratch rows)."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.free: List[int] = list(range(num_slots - 1, -1, -1))

    def alloc(self) -> int:
        if not self.free:
            raise OutOfPages(f"slots exhausted ({self.num_slots})")
        return self.free.pop()

    def release(self, slot: int) -> None:
        self.free.append(slot)

    @property
    def in_use(self) -> int:
        return self.num_slots - len(self.free)


class PagedKVState:
    """Device arrays + host bookkeeping for the tree engine.

    Layout:
      kv_pools: per attn layer {"k": (P, page, n_kv, hd), "v": ...}
                (MLA layers: {"ckv": (P, page, r), "k_rope": (P, page, rd)})
      rec_state: per recurrent layer, slot-indexed state arrays
                 (S_max, ...) — slot dim first.
    """

    def __init__(self, cfg: ModelConfig, num_pages: int, page_size: int,
                 max_slots: int, dtype=jnp.float32):
        self.cfg = cfg
        self.page_size = page_size
        self.pool = PagePool(num_pages)
        self.slots = SlotAllocator(max_slots)
        self.dtype = dtype
        hd = cfg.resolved_head_dim
        self.kv_pools: Dict[int, Dict[str, jnp.ndarray]] = {}
        self.rec_state: Dict[int, Dict[str, jnp.ndarray]] = {}
        for i in range(cfg.num_layers):
            kind = cfg.layer_kind(i)
            if kind == "attn":
                if cfg.attention_kind == "mla":
                    m = cfg.mla
                    self.kv_pools[i] = {
                        "ckv": jnp.zeros((num_pages, page_size,
                                          m.kv_lora_rank), dtype),
                        "k_rope": jnp.zeros((num_pages, page_size,
                                             m.qk_rope_head_dim), dtype),
                    }
                else:
                    self.kv_pools[i] = {
                        "k": jnp.zeros((num_pages, page_size,
                                        cfg.num_kv_heads, hd), dtype),
                        "v": jnp.zeros((num_pages, page_size,
                                        cfg.num_kv_heads, hd), dtype),
                    }
            elif kind == "mamba":
                mc = cfg.mamba
                d_in = mc.expand * cfg.d_model
                self.rec_state[i] = {
                    "conv": jnp.zeros((max_slots, mc.d_conv - 1, d_in), dtype),
                    "ssm": jnp.zeros((max_slots, d_in, mc.d_state),
                                     jnp.float32),
                }
            elif kind == "rwkv":
                rc = cfg.rwkv
                H = cfg.d_model // rc.head_dim
                self.rec_state[i] = {
                    "wkv": jnp.zeros((max_slots, H, rc.head_dim, rc.head_dim),
                                     jnp.float32),
                    "shift": jnp.zeros((max_slots, cfg.d_model), dtype),
                    "shift_ffn": jnp.zeros((max_slots, cfg.d_model), dtype),
                }
        # whisper cross-attention KV: per request, shared by every branch
        self.cross_kv: Optional[tuple] = None

    # -- host bookkeeping ---------------------------------------------------

    def fork_table(self, table: List[int]) -> List[int]:
        """Child block table sharing every page of the parent prefix."""
        for pid in table:
            self.pool.retain(pid)
        return list(table)

    def release_table(self, table: List[int]) -> None:
        for pid in table:
            self.pool.release(pid)

    def copy_slots(self, src_slots: List[int], dst_slots: List[int]) -> None:
        """Batched device copy of recurrent state rows (fork of SSM state)."""
        if not src_slots or not self.rec_state:
            return
        src = jnp.asarray(src_slots, jnp.int32)
        dst = jnp.asarray(dst_slots, jnp.int32)
        for i, st in self.rec_state.items():
            self.rec_state[i] = {
                k: v.at[dst].set(v[src]) for k, v in st.items()
            }

    # -- stats ---------------------------------------------------------------

    def kv_bytes_per_token(self) -> int:
        """Bytes of KV written per generated token (all attn layers)."""
        total = 0
        for pools in self.kv_pools.values():
            for arr in pools.values():
                per_tok = int(np.prod(arr.shape[2:])) * arr.dtype.itemsize
                total += per_tok
        return total
