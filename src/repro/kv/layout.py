"""Fused KV page-pool layout transforms.

THE definitions of the fused layouts (``docs/architecture.md`` §Paged KV):

* GQA/MHA: K and V are **head-interleaved** on the head axis —
  ``[K0, V0, K1, V1, ...]`` — so a page tile is ``(page, 2*Hkv, hd)`` and
  one page DMA ships both halves (the split layout costs two).
* MLA: the compressed latent and the decoupled-rope key are concatenated
  on the feature axis — ``[ckv | k_rope]`` — so a page tile is
  ``(page, r + rd)``.

Every producer/consumer of the fused layout (pool construction in
``repro.kv.cache``, the KV writes in ``repro.core.engine``, the reference
oracles in ``repro.kernels.ref``, the parity tests) goes through these
four functions, so the interleaving convention has exactly one home.
All are shape-polymorphic over leading axes: they accept per-token
``(..., Hkv, hd)`` writes and whole pools ``(P, page, Hkv, hd)`` alike.
"""
from __future__ import annotations

import jax.numpy as jnp


def interleave_kv(k, v):
    """(..., Hkv, D) x2 -> (..., 2*Hkv, D) with heads ``[K0,V0,K1,V1,..]``."""
    assert k.shape == v.shape, (k.shape, v.shape)
    kv = jnp.stack([k, v], axis=-2)               # (..., Hkv, 2, D)
    return kv.reshape(kv.shape[:-3] + (kv.shape[-3] * 2, kv.shape[-1]))


def deinterleave_kv(kv):
    """(..., 2*Hkv, D) -> ((..., Hkv, D) k, (..., Hkv, D) v)."""
    h2, d = kv.shape[-2], kv.shape[-1]
    assert h2 % 2 == 0, kv.shape
    kv4 = kv.reshape(kv.shape[:-2] + (h2 // 2, 2, d))
    return kv4[..., 0, :], kv4[..., 1, :]


def fuse_mla(ckv, k_rope):
    """(..., r) + (..., rd) -> (..., r + rd) feature-concat latent page."""
    return jnp.concatenate([ckv, k_rope], axis=-1)


def split_mla(kv, rank: int):
    """(..., r + rd) -> ((..., r) ckv, (..., rd) k_rope)."""
    return kv[..., :rank], kv[..., rank:]
