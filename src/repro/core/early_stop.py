"""Early-stop criteria for search paths (paper §2.2 "Heuristic Sampling").

A freshly generated segment stops its path as:
  LEAF   — contains [EOS] or a legal ``\\boxed{}`` answer (footnote 1), or the
           path hit the depth budget (complete-but-unanswered trajectory);
  FAILED — contains a repetitive substring pattern ("mumbling" of weakly
           aligned base models): some n-gram tail repeated >= `count` times
           consecutively.  Pruned; budget transfers to surviving paths.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.data.reward import extract_boxed
from repro.data.tokenizer import ByteTokenizer

_TOK = ByteTokenizer()


def has_repetition(tokens: Sequence[int], max_ngram: int = 16,
                   count: int = 4) -> bool:
    """True if the tail of ``tokens`` is some n-gram (1 <= n <= max_ngram)
    repeated >= ``count`` times consecutively."""
    toks = list(tokens)
    L = len(toks)
    for n in range(1, max_ngram + 1):
        if n * count > L:
            break
        tail = toks[L - n:]
        reps = 1
        while reps < count and toks[L - (reps + 1) * n: L - reps * n] == tail:
            reps += 1
        if reps >= count:
            return True
    return False


def segment_stop_reason(segment_tokens: Sequence[int],
                        full_tokens: Sequence[int],
                        *, eos_id: int = ByteTokenizer.EOS,
                        max_ngram: int = 16, count: int = 4
                        ) -> Optional[str]:
    """Returns None (continue), or 'eos' | 'boxed' | 'repetition'."""
    if eos_id in segment_tokens:
        return "eos"
    # answer detection on the decoded *full* suffix (a box may straddle a
    # segment boundary)
    text = _TOK.decode(full_tokens)
    if extract_boxed(text) is not None:
        return "boxed"
    if has_repetition(segment_tokens, max_ngram, count):
        return "repetition"
    return None


def truncate_at_eos(tokens: List[int], logprobs: List[float],
                    eos_id: int = ByteTokenizer.EOS
                    ) -> Tuple[List[int], List[float]]:
    """Keep tokens up to and including the first EOS."""
    if eos_id in tokens:
        idx = tokens.index(eos_id) + 1
        return tokens[:idx], logprobs[:idx]
    return tokens, logprobs
