"""Host-side tree bookkeeping for TreePO sampling (paper §2.2).

A *node* is one generated segment; a *path* is the chain root→node.  The
tree for query q tracks every path's status, its per-depth node-id chain
(which feeds the tree-based advantage, ``repro.core.advantage``), and its
device-side identity (``EnginePath``: block table / recurrent slot).

The training hot path consumes trees as padded tensors: every finished
path records its (J,)-padded ancestor row *at finish time*
(:meth:`QueryTree.add_finished`), so batch assembly
(:func:`batch_group_tensors`) is a stack of precomputed rows — no
per-tree ``ancestor_matrix`` reconstruction in the trainer loop.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional, Tuple


class Status(enum.Enum):
    ACTIVE = "active"
    LEAF = "leaf"          # finished with EOS / boxed answer / length cap
    FAILED = "failed"      # early-stopped (repetition / budget pruned)


_NODE_COUNTER = [0]


def _next_node_id() -> int:
    _NODE_COUNTER[0] += 1
    return _NODE_COUNTER[0]


@dataclasses.dataclass
class Path:
    """One active/finished search path (the chain up to its last node)."""

    query_idx: int
    depth: int                        # segments generated so far
    node_ids: List[int]               # ancestor node id per depth (root first)
    tokens: List[int]                 # generated tokens (suffix after prompt)
    logprobs: List[float]             # per generated token
    ep: Optional[Any] = None          # EnginePath (device-side identity)
    status: Status = Status.ACTIVE
    seg_logprob: float = 0.0          # mean logprob of the last segment
    finish_reason: str = ""
    # segment boundaries in `tokens` (starts with 0; token-aligned fallback)
    seg_bounds: List[int] = dataclasses.field(
        default_factory=lambda: [0])
    # mean logprob of segment k = seg_logprobs[k-1] (tokens
    # seg_bounds[k-1]:seg_bounds[k]) — the branching heuristic's signal,
    # kept per segment so fallback forks inherit the *prefix* segment's
    # value, not the source leaf's final one
    seg_logprobs: List[float] = dataclasses.field(default_factory=list)
    # terminal reward, memoized so each trajectory is scored exactly once
    # (None = not scored yet; FAILED paths are pinned to 0.0 at finish)
    reward: Optional[float] = None

    def clone_for_branch(self, ep: Optional[Any] = None) -> "Path":
        """Fork at the current segment boundary."""
        return Path(
            query_idx=self.query_idx,
            depth=self.depth,
            node_ids=list(self.node_ids),
            tokens=list(self.tokens),
            logprobs=list(self.logprobs),
            ep=ep,
            status=Status.ACTIVE,
            seg_logprob=self.seg_logprob,
            seg_bounds=list(self.seg_bounds),
            seg_logprobs=list(self.seg_logprobs),
        )


@dataclasses.dataclass
class QueryTree:
    """All paths for one query."""

    query_idx: int
    prompt_tokens: List[int]
    target: str                       # ground-truth answer (reward check)
    root_id: int = dataclasses.field(default_factory=_next_node_id)
    active: List[Path] = dataclasses.field(default_factory=list)
    finished: List[Path] = dataclasses.field(default_factory=list)
    # paths retracted under KV pressure (ep released, tokens kept);
    # regenerated via TreeEngine.restore_path when the pool recovers, or
    # finished FAILED("preempted") at end of rollout (docs/robustness.md)
    preempted: List[Path] = dataclasses.field(default_factory=list)
    init_div: int = 1
    total_segments: int = 0
    # J - 1 of the padded ancestor rows recorded by add_finished (set by
    # the sampler from tree_cfg.max_depth; 0 = rows not being recorded)
    max_depth: int = 0
    # one (J,) int64 row per finished path, built incrementally
    anc_rows: List[Any] = dataclasses.field(default_factory=list)

    @property
    def num_leaves(self) -> int:
        return sum(1 for p in self.finished if p.status == Status.LEAF)

    @property
    def num_trajectories(self) -> int:
        return len(self.finished)

    def add_finished(self, path: Path) -> None:
        """Record a finished path + its padded ancestor row (the (G, J)
        tensor grows one row at a time instead of being rebuilt per tree
        at pack time)."""
        self.finished.append(path)
        if self.max_depth > 0:
            self.anc_rows.append(
                _ancestor_row(path.node_ids, self.max_depth))

    def ancestors(self, max_depth: Optional[int] = None):
        """(G, J) ancestor matrix from the incrementally recorded rows
        (falls back to a full rebuild for trees populated directly by
        tests / legacy callers)."""
        import numpy as np

        J = (max_depth if max_depth is not None else self.max_depth) + 1
        if len(self.anc_rows) == len(self.finished) and self.finished \
                and self.anc_rows[0].shape[0] == J:
            return np.stack(self.anc_rows)
        return ancestor_matrix(self.finished, J - 1)

    def rewards(self):
        """(G,) memoized terminal rewards (every entry must have been
        scored — see ``Path.reward`` / the sampler's ``score_fn``)."""
        import numpy as np

        return np.asarray([0.0 if p.reward is None else p.reward
                           for p in self.finished], np.float32)

    def fallback_candidates(self) -> List[Path]:
        """Paper §2.2: only paths with a formatted answer or EOS may seed
        fallback (FAILED / length-capped paths may not)."""
        return [p for p in self.finished
                if p.status == Status.LEAF
                and p.finish_reason in ("eos", "boxed")
                and len(p.seg_bounds) > 2
                # a leaf whose retained KV was reclaimed under pool
                # pressure can no longer seed an engine fork
                and not (p.ep is not None
                         and getattr(p.ep, "released", False))]


def new_node_id() -> int:
    return _next_node_id()


def _ancestor_row(node_ids: List[int], max_depth: int):
    """One path's (J,) ancestor row: leaf id repeated below its depth
    (Eq. 4's nesting — a finished path is a singleton chain downward)."""
    import numpy as np

    row = np.empty((max_depth + 1,), dtype=np.int64)
    ids = node_ids[: max_depth + 1]
    row[: len(ids)] = ids
    row[len(ids):] = ids[-1]
    return row


def ancestor_matrix(paths: List[Path], max_depth: int):
    """(G, J) ancestor-node-id matrix for advantage estimation.

    J = max_depth + 1 (row 0 = the shared root).  Shorter trajectories
    repeat their leaf id below their final depth (consistent with Eq. 4's
    subgroup nesting: a finished path is a singleton chain downward).
    """
    import numpy as np

    G = len(paths)
    anc = np.zeros((G, max_depth + 1), dtype=np.int64)
    for i, p in enumerate(paths):
        anc[i] = _ancestor_row(p.node_ids, max_depth)
    return anc


def batch_group_tensors(trees: List["QueryTree"], max_depth: int,
                        group_pad: Optional[int] = None,
                        query_pad: Optional[int] = None
                        ) -> Tuple[Any, Any, Any]:
    """Stack Q trees into padded (Q, G, J) ancestors / (Q, G) rewards /
    (Q, G) validity mask for the one-dispatch batched advantage.

    ``group_pad`` fixes G and ``query_pad`` fixes Q (defaults: the
    actual sizes) — callers pass bucketed values so the jitted dispatch
    compiles once per bucket, not once per (Q, G) combination.  Padded
    slots (and whole padded query rows) get a unique negative ancestor
    id per (row, slot) so they can never collide with a real subgroup
    even if a masked kernel ignores the mask; their reward is 0 and
    mask is 0.
    """
    import numpy as np

    J = max_depth + 1
    Q = max(query_pad or len(trees), len(trees), 1)
    G = group_pad or max((t.num_trajectories for t in trees), default=1)
    G = max(G, max((t.num_trajectories for t in trees), default=1), 1)
    anc = np.zeros((Q, G, J), np.int64)
    rew = np.zeros((Q, G), np.float32)
    mask = np.zeros((Q, G), np.float32)
    for qi in range(Q):
        g = trees[qi].num_trajectories if qi < len(trees) else 0
        if g:
            anc[qi, :g] = trees[qi].ancestors(max_depth)
            rew[qi, :g] = trees[qi].rewards()
            mask[qi, :g] = 1.0
        for slot in range(g, G):
            anc[qi, slot] = -(qi * G + slot + 1)
    return anc, rew, mask
