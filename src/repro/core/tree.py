"""Host-side tree bookkeeping for TreePO sampling (paper §2.2).

A *node* is one generated segment; a *path* is the chain root→node.  The
tree for query q tracks every path's status, its per-depth node-id chain
(which feeds the tree-based advantage, ``repro.core.advantage``), and its
device-side identity (``EnginePath``: block table / recurrent slot).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional


class Status(enum.Enum):
    ACTIVE = "active"
    LEAF = "leaf"          # finished with EOS / boxed answer / length cap
    FAILED = "failed"      # early-stopped (repetition / budget pruned)


_NODE_COUNTER = [0]


def _next_node_id() -> int:
    _NODE_COUNTER[0] += 1
    return _NODE_COUNTER[0]


@dataclasses.dataclass
class Path:
    """One active/finished search path (the chain up to its last node)."""

    query_idx: int
    depth: int                        # segments generated so far
    node_ids: List[int]               # ancestor node id per depth (root first)
    tokens: List[int]                 # generated tokens (suffix after prompt)
    logprobs: List[float]             # per generated token
    ep: Optional[Any] = None          # EnginePath (device-side identity)
    status: Status = Status.ACTIVE
    seg_logprob: float = 0.0          # mean logprob of the last segment
    finish_reason: str = ""
    # segment boundaries in `tokens` (starts with 0; token-aligned fallback)
    seg_bounds: List[int] = dataclasses.field(
        default_factory=lambda: [0])

    def clone_for_branch(self, ep: Optional[Any] = None) -> "Path":
        """Fork at the current segment boundary."""
        return Path(
            query_idx=self.query_idx,
            depth=self.depth,
            node_ids=list(self.node_ids),
            tokens=list(self.tokens),
            logprobs=list(self.logprobs),
            ep=ep,
            status=Status.ACTIVE,
            seg_logprob=self.seg_logprob,
            seg_bounds=list(self.seg_bounds),
        )


@dataclasses.dataclass
class QueryTree:
    """All paths for one query."""

    query_idx: int
    prompt_tokens: List[int]
    target: str                       # ground-truth answer (reward check)
    root_id: int = dataclasses.field(default_factory=_next_node_id)
    active: List[Path] = dataclasses.field(default_factory=list)
    finished: List[Path] = dataclasses.field(default_factory=list)
    init_div: int = 1
    total_segments: int = 0

    @property
    def num_leaves(self) -> int:
        return sum(1 for p in self.finished if p.status == Status.LEAF)

    @property
    def num_trajectories(self) -> int:
        return len(self.finished)

    def fallback_candidates(self) -> List[Path]:
        """Paper §2.2: only paths with a formatted answer or EOS may seed
        fallback (FAILED / length-capped paths may not)."""
        return [p for p in self.finished
                if p.status == Status.LEAF
                and p.finish_reason in ("eos", "boxed")
                and len(p.seg_bounds) > 2]


def new_node_id() -> int:
    return _next_node_id()


def ancestor_matrix(paths: List[Path], max_depth: int):
    """(G, J) ancestor-node-id matrix for advantage estimation.

    J = max_depth + 1 (row 0 = the shared root).  Shorter trajectories
    repeat their leaf id below their final depth (consistent with Eq. 4's
    subgroup nesting: a finished path is a singleton chain downward).
    """
    import numpy as np

    G = len(paths)
    anc = np.zeros((G, max_depth + 1), dtype=np.int64)
    for i, p in enumerate(paths):
        ids = p.node_ids[: max_depth + 1]
        anc[i, : len(ids)] = ids
        if len(ids) < max_depth + 1:
            anc[i, len(ids):] = ids[-1]
    return anc
