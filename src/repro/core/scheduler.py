"""Continuous-batching tree-serving scheduler (SGL-JAX-style loop).

The synchronous driver prefilled a fixed batch, ran it to completion,
then started the next — the accelerator idled between batches and every
prompt was recomputed from scratch.  This scheduler admits requests
continuously from an arrival trace and dispatches ONE jitted serve
segment per round in which prompt-prefill chunks and steady-state decode
mix freely: a row's first rounds *force* its prompt tokens through the
decode scan (chunked prefill as forced decode), later rounds sample.

Determinism contract (proven in tests/test_scheduler.py): the serve
function samples row ``i`` with a key derived from (request key,
absolute position) and every per-row computation is row-independent, so
a request's token/logprob stream is bitwise identical whatever arrival
interleaving, batch composition, preemption or admission order it
experienced — continuous and synchronous serving agree per request.

KV economics: a new request's prompt prefix is first looked up in the
cross-request :class:`~repro.kv.radix.RadixCache`; matched pages are
shared COW-style (no recompute, no copy).  Under pool pressure the
degradation order is radix-evict LRU leaves FIRST, preempt newest
request second (``docs/robustness.md`` composition) — cache contents are
recomputable, a live request's working set costs a full replay.

Scheduling is FCFS with preempted requests re-queued at the *front*, so
no request can starve: the head of the queue is always the next admitted
(bounded-admission-wait test).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import faults
from repro.core.early_stop import truncate_at_eos
from repro.core.engine import EnginePath, TreeEngine
from repro.core.guard import annotated_transfer
from repro.data.tokenizer import ByteTokenizer
from repro.kv.cache import OutOfPages, bucket_pow2
from repro.kv.radix import RadixCache

__all__ = ["Request", "ServeReport", "Scheduler", "poisson_trace"]


# ---------------------------------------------------------------------------
# request / report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request and its full lifecycle state."""

    rid: int                          # also the per-request sampling key
    prompt: List[int]
    max_new_tokens: int = 64
    arrival: float = 0.0              # trace time the request appears
    state: str = "waiting"            # waiting -> running -> finished
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    out_logprobs: List[float] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    ep: Optional[EnginePath] = None
    consumed: int = 0                 # tokens fed to the model (KV built)
    cached_len: int = 0               # prompt tokens served by the radix
    inserted: bool = False            # prompt prefix offered to the cache
    visible_round: int = -1           # round the request entered the queue
    admit_round: int = -1             # round of FIRST admission
    preemptions: int = 0

    def history(self) -> List[int]:
        """Every token whose KV the model must hold: prompt + generated.
        Replay after preemption forces exactly this sequence."""
        return self.prompt + self.out_tokens


@dataclasses.dataclass
class ServeReport:
    rounds: int = 0
    admitted: int = 0
    finished: int = 0
    preemptions: int = 0
    prompt_tokens: int = 0            # across admitted requests
    radix_hit_tokens: int = 0         # prompt tokens served from cache
    forced_tokens: int = 0            # prompt/replay tokens fed as forced
    gen_tokens: int = 0               # sampled tokens fed (the output)
    model_tokens: int = 0             # R*l per round over real rows
    evicted_pages: int = 0            # radix pages dropped under pressure
    max_admission_wait: int = 0       # rounds from visible to admitted
    virtual_time: float = 0.0

    @property
    def reuse_ratio(self) -> float:
        """Fraction of admitted prompt tokens whose KV came from the
        cross-request radix cache instead of being recomputed."""
        return self.radix_hit_tokens / max(self.prompt_tokens, 1)

    @property
    def gen_token_ps(self) -> float:
        return self.gen_tokens / max(self.virtual_time, 1e-9)

    @property
    def traj_ps(self) -> float:
        return self.finished / max(self.virtual_time, 1e-9)


def poisson_trace(rng, n: int, *, rate: float,
                  start: float = 0.0) -> List[float]:
    """``n`` Poisson arrival times (exponential inter-arrival gaps of
    mean ``1/rate``) from an externally-owned ``random.Random`` — the
    caller owns seeding and any checkpoint capture of the generator."""
    out: List[float] = []
    t = start
    for _ in range(n):
        t += rng.expovariate(rate)
        out.append(t)
    return out


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    """Continuous-batching frontend over a ``TreeEngine``/``ModelRunner``.

    mode="continuous" admits whenever a slot frees up; mode="sync"
    reproduces the old batch driver (admit a full batch only when the
    previous one drained) — same serve function, same per-request
    streams, used as the throughput baseline and parity oracle.
    clock="round" advances virtual time by 1 per dispatch round
    (deterministic tests); clock="wall" accumulates measured wall
    seconds (benchmarks).
    """

    def __init__(self, engine: TreeEngine, *, mode: str = "continuous",
                 max_running: int = 8, seg_len: Optional[int] = None,
                 radix: bool = True, base_seed: int = 0,
                 eos_id: int = ByteTokenizer.EOS, clock: str = "round"):
        assert mode in ("continuous", "sync")
        assert clock in ("round", "wall")
        assert engine.can_restore, \
            "serving needs token-complete contexts (no cross-KV / " \
            "modality prefix)"
        self.engine = engine
        self.mode = mode
        self.max_running = max_running
        # ONE compiled batch bucket for the whole serve lifetime: padded
        # to the pow2 bucket of max_running, so warm serving recompiles
        # exactly never (hot_path_guard regression test)
        self.Rb = bucket_pow2(max_running)
        self.seg_len = seg_len or engine.tree_cfg.segment_len
        self.base_seed = base_seed
        self.eos_id = eos_id
        self.clock = clock
        self.radix: Optional[RadixCache] = None
        if radix and not engine.has_rec:
            # recurrent archs carry slot state the cache cannot restore;
            # attention-only KV is fully page-addressed
            self.radix = RadixCache(engine.kv.pool, engine.page_size)
        # always (re)register: radix=False must detach any cache a
        # previous scheduler left on a reused engine
        engine.attach_radix(self.radix)
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.round = 0
        self.now = 0.0
        self.report = ServeReport()

    # -- admission ----------------------------------------------------------

    def submit(self, r: Request) -> None:
        r.state = "waiting"
        if r.visible_round < 0:
            r.visible_round = self.round
        self.waiting.append(r)

    def _build_path(self, r: Request) -> Tuple[EnginePath, int]:
        """Admission-time path construction: radix-match the history,
        point the table at the shared pages, grow capacity for the first
        segment.  Mirrors ``TreeEngine.restore_path``'s error discipline:
        a mid-build ``OutOfPages`` releases everything acquired so far
        (matched pages included) before propagating."""
        hist = r.history()
        pages: List[int] = []
        cached = 0
        if self.radix is not None:
            pages, cached = self.radix.match_prefix(hist)
        path = EnginePath(table=pages, slot=-1, qslot=-1, position=cached,
                          pending_token=0, pending_logprob=0.0)
        try:
            self.engine._ensure_capacity(path, cached + self.seg_len)
            if self.engine.has_rec:
                path.slot = self.engine._alloc_slot()
        except Exception:
            self.engine.release_partial([path])
            raise
        return path, cached

    def _admit(self) -> None:
        if self.mode == "sync" and self.running:
            return
        while self.waiting and len(self.running) < self.max_running:
            r = self.waiting[0]
            try:
                path, cached = self._build_path(r)
            except OutOfPages:
                if not self.running:
                    raise    # nothing preemptible left: genuine exhaustion
                break        # wait for pages; FCFS head keeps its turn
            self.waiting.popleft()
            r.ep = path
            r.consumed = cached
            r.state = "running"
            if r.admit_round < 0:
                r.admit_round = self.round
                self.report.admitted += 1
                self.report.prompt_tokens += len(r.prompt)
                self.report.radix_hit_tokens += min(cached, len(r.prompt))
                self.report.max_admission_wait = max(
                    self.report.max_admission_wait,
                    self.round - r.visible_round)
                r.cached_len = cached
            self.running.append(r)

    # -- pressure -----------------------------------------------------------

    def _page_demand(self) -> int:
        ps = self.engine.page_size
        demand = 0
        for r in self.running:
            need = -(-(r.ep.position + self.seg_len) // ps)
            demand += max(0, need - len(r.ep.table))
        return demand

    def _make_room(self) -> None:
        """Guarantee the round's page demand: evict radix leaves first,
        preempt the NEWEST running request second (FCFS fairness: the
        oldest admitted work is protected)."""
        deficit = self._page_demand() - self.engine.pages_free()
        if deficit <= 0:
            return
        if self.radix is not None:
            self.radix.evict(deficit)
        while (self._page_demand() > self.engine.pages_free()
               and len(self.running) > 1):
            self._preempt_victim(self.running[-1])

    def _preempt_victim(self, r: Request) -> None:
        """Retract ``r`` to the FRONT of the waiting queue.  Its pages are
        freed; its generated tokens are kept and will be force-replayed on
        re-admission, where position-keyed sampling regenerates the
        dropped pending draw bitwise."""
        self.running.remove(r)
        self.engine.preempt_path(r.ep)
        r.ep = None
        r.consumed = 0
        r.state = "waiting"
        r.preemptions += 1
        self.report.preemptions += 1
        self.waiting.appendleft(r)

    # -- dispatch -----------------------------------------------------------

    def _dispatch(self) -> None:
        l = self.seg_len
        eng = self.engine
        for r in list(self.running):
            try:
                eng._ensure_capacity(r.ep, r.ep.position + l)
            except OutOfPages:
                if len(self.running) == 1:
                    raise    # one request can't fit: pool too small
                self._preempt_victim(r)
        if not self.running:
            return
        # snapshot the row order: _finish_request mutates self.running
        # during the unpack loop, and row i of the packed batch must keep
        # naming the same request end to end
        rows = list(self.running)
        R = len(rows)
        Rb = self.Rb
        tok0 = np.zeros((Rb,), np.int32)
        lp0 = np.zeros((Rb,), np.float32)
        pos0 = np.zeros((Rb,), np.int32)
        tables = np.full((Rb, eng.MP), -1, np.int32)
        slots = np.full((Rb,), max(eng.scratch_slot, 0), np.int32)
        forced_tok = np.zeros((Rb, l), np.int32)
        forced_on = np.zeros((Rb, l), bool)
        row_keys = np.zeros((Rb, 2), np.uint32)
        n_forced: List[int] = []
        for i, r in enumerate(rows):
            ep = r.ep
            tok0[i] = ep.pending_token
            lp0[i] = ep.pending_logprob
            pos0[i] = ep.position
            tables[i, : len(ep.table)] = ep.table
            if ep.slot >= 0:
                slots[i] = ep.slot
            hist = r.history()
            nf = min(l, max(0, len(hist) - r.consumed))
            n_forced.append(nf)
            if nf:
                forced_tok[i, :nf] = hist[r.consumed:r.consumed + nf]
                forced_on[i, :nf] = True
            row_keys[i] = (np.uint32(self.base_seed), np.uint32(r.rid))
        tables[R:, 0] = eng.garbage_page

        fn = eng.runner.get_serve_fn(Rb, l)
        (tok0, lp0, pos0, tables, slots, forced_tok, forced_on,
         row_keys) = annotated_transfer(
            (tok0, lp0, pos0, tables, slots, forced_tok, forced_on,
             row_keys), to="device", reason="serve-pack")
        pools, rec, toks, lps, pend_tok, pend_lp = fn(
            eng.params, eng.kv.kv_pools, eng.kv.rec_state,
            tok0, lp0, pos0, tables, slots, forced_tok, forced_on,
            row_keys)
        eng.kv.kv_pools = pools
        eng.kv.rec_state = rec
        toks, lps, pend_tok, pend_lp = annotated_transfer(
            (toks, lps, pend_tok, pend_lp), reason="serve-segment")
        eng.stats.host_bytes += (toks.nbytes + lps.nbytes
                                 + pend_tok.nbytes + pend_lp.nbytes)
        lps = faults.corrupt_array("engine.decode_logprobs", lps)

        total_forced = sum(n_forced)
        eng.stats.prefill_tokens += total_forced
        eng.stats.decode_tokens += R * l - total_forced
        eng.stats.segments += R
        self.report.forced_tokens += total_forced
        self.report.gen_tokens += R * l - total_forced
        self.report.model_tokens += R * l
        for i, r in enumerate(rows):
            nf = n_forced[i]
            r.ep.position += l
            r.ep.pending_token = int(pend_tok[i])
            r.ep.pending_logprob = float(pend_lp[i])
            r.consumed += l
            r.out_tokens.extend(int(t) for t in toks[i, nf:])
            r.out_logprobs.extend(float(v) for v in lps[i, nf:])
            if (self.radix is not None and not r.inserted
                    and r.consumed >= len(r.prompt)):
                n_ins = len(r.prompt) // eng.page_size
                if n_ins > 0:
                    self.radix.insert(r.prompt[: n_ins * eng.page_size],
                                      r.ep.table[:n_ins])
                r.inserted = True
            if not (np.isfinite(lps[i, nf:]).all()
                    and np.isfinite(float(pend_lp[i]))):
                eng.stats.quarantined_paths += 1
                self._finish_request(r, "nonfinite")
                continue
            cut_t, cut_l = truncate_at_eos(r.out_tokens, r.out_logprobs,
                                           self.eos_id)
            if len(cut_t) < len(r.out_tokens):
                r.out_tokens, r.out_logprobs = cut_t, cut_l
                self._finish_request(r, "eos")
            elif len(r.out_tokens) >= r.max_new_tokens:
                r.out_tokens = r.out_tokens[: r.max_new_tokens]
                r.out_logprobs = r.out_logprobs[: r.max_new_tokens]
                self._finish_request(r, "length")

    def _finish_request(self, r: Request, reason: str) -> None:
        self.engine.release_path(r.ep)
        self.running.remove(r)
        r.state = "finished"
        r.finish_reason = reason
        self.report.finished += 1

    # -- serve loop ---------------------------------------------------------

    def step(self) -> None:
        """One scheduling round: admit, make room, dispatch one mixed
        prefill/decode serve segment."""
        self._admit()
        self._make_room()
        self._dispatch()
        self.round += 1

    def run(self, requests: Sequence[Request], *,
            max_rounds: int = 100_000) -> ServeReport:
        """Serve a whole arrival trace to completion."""
        trace = sorted(requests, key=lambda r: (r.arrival, r.rid))
        idx = 0
        while self.round < max_rounds:
            while idx < len(trace) and trace[idx].arrival <= self.now:
                self.submit(trace[idx])
                idx += 1
            if not self.waiting and not self.running:
                if idx >= len(trace):
                    break
                self.now = trace[idx].arrival   # idle: jump to next arrival
                continue
            t0 = time.perf_counter()
            self.step()
            if self.clock == "wall":
                self.now += time.perf_counter() - t0
            else:
                self.now += 1.0
        self.report.rounds = self.round
        self.report.virtual_time = self.now
        if self.radix is not None:
            self.report.evicted_pages = self.radix.evicted_pages
        return self.report
