"""Tree-based sampling — Algorithm 1 of the paper, host-side orchestration.

One call to :func:`sample_trees` turns a batch of queries into ``w``
trajectories each by driving the :class:`~repro.core.engine.TreeEngine`
through segment-synchronous rounds:

  1. prefill every query once (the shared tree root),
  2. init divergence (fixed or randomized 2..8 root forks),
  3. loop: batched segment decode over *all* queries' active paths →
     early-stop / leaf classification → branching-budget assignment
     (with budget transfer + heuristics) → DFS fallback for starved
     queries,
  4. finish when every query has ``w`` trajectories (or budgets exhaust).

Sequential (non-tree) sampling — the paper's baseline — is the same
machinery with ``branch_factor=1`` and ``init_divergence == w``: ``w``
independent rollouts that share only the prompt KV.

Training-side hooks: an optional ``score_fn`` scores each trajectory the
moment it finishes (memoized on ``Path.reward`` — one reward evaluation
per trajectory, ever), and every finished path records its padded
ancestor row incrementally on the tree, so the trainer packs the batched
(Q, G, J) advantage inputs without per-tree reconstruction.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import TreeConfig
from repro.core import branching as br
from repro.core.early_stop import segment_stop_reason, truncate_at_eos
from repro.core.engine import TreeEngine
from repro.core.fallback import pick_fallback
from repro.core.tree import Path, QueryTree, Status, new_node_id

# scores a finished LEAF trajectory (FAILED paths are pinned to 0.0)
ScoreFn = Callable[[QueryTree, Path], float]


@dataclasses.dataclass
class SamplerReport:
    num_queries: int = 0
    num_trajectories: int = 0
    num_leaves: int = 0
    num_failed: int = 0
    num_fallbacks: int = 0
    decode_rounds: int = 0


def _finish_path(tree: QueryTree, path: Path, status: Status,
                 reason: str, engine: TreeEngine,
                 score_fn: Optional[ScoreFn] = None) -> None:
    path.status = status
    path.finish_reason = reason
    if status == Status.FAILED:
        path.reward = 0.0             # failed trajectories earn nothing
    elif score_fn is not None:
        path.reward = float(score_fn(tree, path))
    tree.add_finished(path)
    if path.ep is not None:
        # finished paths never sample again (fallback forks read only their
        # KV pages), so drop the boundary-logits reference now rather than
        # pinning the round's (Rb, V) device buffer until end of rollout
        path.ep.logits_buf = None
    if status == Status.FAILED and path.ep is not None:
        # failed paths are never fallback sources: free their pages now
        engine.release_path(path.ep)


def _process_segment(tree: QueryTree, path: Path, seg_tokens: List[int],
                     seg_logprobs: List[float], seg_logprob: float,
                     tree_cfg: TreeConfig, engine: TreeEngine,
                     score_fn: Optional[ScoreFn] = None) -> None:
    seg_tokens, seg_logprobs = truncate_at_eos(seg_tokens, seg_logprobs)
    path.tokens.extend(seg_tokens)
    path.logprobs.extend(seg_logprobs)
    path.depth += 1
    path.node_ids.append(new_node_id())
    path.seg_bounds.append(len(path.tokens))
    path.seg_logprob = seg_logprob
    path.seg_logprobs.append(seg_logprob)
    tree.total_segments += 1

    reason = segment_stop_reason(
        seg_tokens, path.tokens,
        max_ngram=tree_cfg.repetition_ngram,
        count=tree_cfg.repetition_count)
    if reason in ("eos", "boxed"):
        _finish_path(tree, path, Status.LEAF, reason, engine, score_fn)
    elif reason == "repetition":
        _finish_path(tree, path, Status.FAILED, reason, engine, score_fn)
    elif path.depth >= tree_cfg.max_depth:
        _finish_path(tree, path, Status.LEAF, "length", engine, score_fn)
    else:
        tree.active.append(path)


def _branch_tree(tree: QueryTree, tree_cfg: TreeConfig, engine: TreeEngine,
                 rng: random.Random, progress: float,
                 score_fn: Optional[ScoreFn] = None) -> None:
    """Apply the depth budget to this tree's active paths (paper §2.2:
    budget transfer evens dead paths' allowance over the survivors).

    After a DFS fallback round the active list can be *mixed-depth*
    (fallback children restart at their fork depth), so the budget is
    computed per depth group — one global ``active[0].depth`` budget
    would over- or under-allocate every other depth.
    """
    if not tree.active:
        return
    budgets = br.mixed_depth_budgets(
        tree_cfg, [p.depth for p in tree.active], tree.init_div,
        tree.num_trajectories)
    # collect the round's forks, then branch them in ONE engine call:
    # one jitted page/slot-copy dispatch + one on-device fork_sample.
    survivors: List[Tuple[Path, int]] = []
    parents = []
    for depth in sorted(budgets, reverse=True):
        group = [p for p in tree.active if p.depth == depth]
        forks = br.assign_branches(
            tree_cfg, [p.seg_logprob for p in group], budgets[depth], rng,
            progress)
        for path, k in zip(group, forks):
            if k <= 0:
                # width budget exhausted: prune (counts as failed, no reward)
                _finish_path(tree, path, Status.FAILED, "budget", engine,
                             score_fn)
                continue
            survivors.append((path, k))
            parents.extend([path.ep] * (k - 1))
    children = engine.fork_paths(parents)
    new_active: List[Path] = []
    ci = 0
    for path, k in survivors:
        new_active.append(path)
        for _ in range(k - 1):
            new_active.append(path.clone_for_branch(children[ci]))
            ci += 1
    tree.active = new_active


def _fallback_tree(tree: QueryTree, tree_cfg: TreeConfig,
                   engine: TreeEngine, rng: random.Random,
                   guard: int, n_prefix: int,
                   report: SamplerReport) -> None:
    """DFS fallback: refill a starved query from its finished leaves."""
    if tree.active or not tree_cfg.fallback:
        return
    needed = tree_cfg.max_width - tree.num_trajectories
    while needed > 0 and tree.total_segments < guard:
        picked = pick_fallback(tree, rng)
        if picked is None:
            return
        src, j = picked
        prefix_count = src.seg_bounds[j]
        prefix_position = n_prefix + len(tree.prompt_tokens) + prefix_count
        replay = list(tree.prompt_tokens) + src.tokens[:prefix_count]
        child_ep = engine.fork_from_prefix(src.ep, prefix_position, replay)
        # the child's last segment is the *prefix* segment j, so the next
        # branching round's uncertainty heuristic must see that segment's
        # mean logprob — not the source leaf's final-segment value
        child = Path(
            query_idx=tree.query_idx,
            depth=j,
            node_ids=src.node_ids[: j + 1],
            tokens=src.tokens[:prefix_count],
            logprobs=src.logprobs[:prefix_count],
            ep=child_ep,
            seg_bounds=src.seg_bounds[: j + 1],
            seg_logprob=(src.seg_logprobs[j - 1]
                         if len(src.seg_logprobs) >= j >= 1
                         else src.seg_logprob),
            seg_logprobs=src.seg_logprobs[:j],
        )
        tree.active.append(child)
        report.num_fallbacks += 1
        needed -= 1


def sample_trees(engine: TreeEngine, prompts: List[List[int]],
                 targets: List[str], tree_cfg: Optional[TreeConfig] = None,
                 *, rng: Optional[random.Random] = None,
                 progress: float = 0.0,
                 prefix_embeds=None, enc_frames=None,
                 guard_factor: int = 4,
                 score_fn: Optional[ScoreFn] = None,
                 ) -> Tuple[List[QueryTree], SamplerReport]:
    """Run Algorithm 1 for a batch of queries.  Returns the query trees
    (finished paths = trajectories) and a sampling report."""
    tree_cfg = tree_cfg or engine.tree_cfg
    rng = rng or random.Random(0)
    report = SamplerReport(num_queries=len(prompts))
    guard = tree_cfg.max_width * tree_cfg.max_depth * guard_factor

    trees = [QueryTree(query_idx=i, prompt_tokens=list(p), target=t,
                       max_depth=tree_cfg.max_depth)
             for i, (p, t) in enumerate(zip(prompts, targets))]

    # 1-2. prefill + init divergence --------------------------------------
    roots = engine.prefill_queries(prompts, prefix_embeds=prefix_embeds,
                                   enc_frames=enc_frames)
    for tree, root_ep in zip(trees, roots):
        n_init = min(br.init_divergence(tree_cfg, rng), tree_cfg.max_width)
        tree.init_div = n_init
        eps = [root_ep] + engine.fork_paths([root_ep] * (n_init - 1))
        tree.active = [
            Path(query_idx=tree.query_idx, depth=0,
                 node_ids=[tree.root_id], tokens=[], logprobs=[], ep=ep)
            for ep in eps
        ]

    # 3. segment-synchronous search loop ----------------------------------
    while True:
        batch = [(tree, p) for tree in trees for p in tree.active]
        if not batch:
            break
        paths = [p for _, p in batch]
        for tree in trees:
            tree.active = []
        results = engine.decode_segments([p.ep for p in paths])
        report.decode_rounds += 1
        for (tree, path), res in zip(batch, results):
            _process_segment(tree, path, res.tokens, res.logprobs,
                             res.seg_logprob, tree_cfg, engine, score_fn)
        for tree in trees:
            _branch_tree(tree, tree_cfg, engine, rng, progress, score_fn)
            _fallback_tree(tree, tree_cfg, engine, rng, guard,
                           engine.n_prefix, report)

    # 4. release device resources ------------------------------------------
    for tree in trees:
        for p in tree.finished:
            if p.ep is not None:
                engine.release_path(p.ep)
        if tree.finished and tree.finished[0].ep is not None:
            engine.release_qslot(tree.finished[0].ep.qslot)
        report.num_trajectories += tree.num_trajectories
        report.num_leaves += tree.num_leaves
        report.num_failed += sum(1 for p in tree.finished
                                 if p.status == Status.FAILED)
    return trees, report


def sequential_tree_cfg(tree_cfg: TreeConfig) -> TreeConfig:
    """The paper's sequential baseline expressed in tree terms: ``w``
    independent rollouts, no branching, no fallback, no early stop
    transfer (repetition stop retained — both samplers use it)."""
    return dataclasses.replace(
        tree_cfg,
        branch_factor=1,
        init_divergence_low=tree_cfg.max_width,
        init_divergence_high=tree_cfg.max_width,
        fallback=False,
        budget_transfer=False,
    )


def sample_sequential(engine: TreeEngine, prompts: List[List[int]],
                      targets: List[str],
                      tree_cfg: Optional[TreeConfig] = None, **kw
                      ) -> Tuple[List[QueryTree], SamplerReport]:
    """Vanilla i.i.d. rollout baseline driven through the same engine."""
    tree_cfg = sequential_tree_cfg(tree_cfg or engine.tree_cfg)
    return sample_trees(engine, prompts, targets, tree_cfg, **kw)
