"""Tree-based sampling — Algorithm 1 of the paper, host-side orchestration.

One call to :func:`sample_trees` turns a batch of queries into ``w``
trajectories each by driving the :class:`~repro.core.engine.TreeEngine`
through segment-synchronous rounds:

  1. prefill every query once (the shared tree root),
  2. init divergence (fixed or randomized 2..8 root forks),
  3. loop: batched segment decode over *all* queries' active paths →
     early-stop / leaf classification → branching-budget assignment
     (with budget transfer + heuristics) → DFS fallback for starved
     queries,
  4. finish when every query has ``w`` trajectories (or budgets exhaust).

Sequential (non-tree) sampling — the paper's baseline — is the same
machinery with ``branch_factor=1`` and ``init_divergence == w``: ``w``
independent rollouts that share only the prompt KV.

Training-side hooks: an optional ``score_fn`` scores each trajectory the
moment it finishes (memoized on ``Path.reward`` — one reward evaluation
per trajectory, ever), and every finished path records its padded
ancestor row incrementally on the tree, so the trainer packs the batched
(Q, G, J) advantage inputs without per-tree reconstruction.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import TreeConfig
from repro.core import branching as br
from repro.core.early_stop import segment_stop_reason, truncate_at_eos
from repro.core.engine import TreeEngine
from repro.core.fallback import pick_fallback
from repro.core.tree import Path, QueryTree, Status, new_node_id
from repro.kv.cache import OutOfPages

# scores a finished LEAF trajectory (FAILED paths are pinned to 0.0)
ScoreFn = Callable[[QueryTree, Path], float]


@dataclasses.dataclass
class SamplerReport:
    num_queries: int = 0
    num_trajectories: int = 0
    num_leaves: int = 0
    num_failed: int = 0
    num_fallbacks: int = 0
    decode_rounds: int = 0
    # fault-tolerance accounting (docs/robustness.md)
    num_preempted: int = 0      # paths retracted under KV pressure
    num_regenerated: int = 0    # preempted paths replayed back in
    num_quarantined: int = 0    # paths retired on non-finite logits


def _finish_path(tree: QueryTree, path: Path, status: Status,
                 reason: str, engine: TreeEngine,
                 score_fn: Optional[ScoreFn] = None) -> None:
    path.status = status
    path.finish_reason = reason
    if status == Status.FAILED:
        path.reward = 0.0             # failed trajectories earn nothing
    elif score_fn is not None:
        path.reward = float(score_fn(tree, path))
    tree.add_finished(path)
    if path.ep is not None:
        # finished paths never sample again (fallback forks read only their
        # KV pages), so drop the boundary-logits reference now rather than
        # pinning the round's (Rb, V) device buffer until end of rollout
        path.ep.logits_buf = None
    if status == Status.FAILED and path.ep is not None:
        # failed paths are never fallback sources: free their pages now
        engine.release_path(path.ep)


def _process_segment(tree: QueryTree, path: Path, seg_tokens: List[int],
                     seg_logprobs: List[float], seg_logprob: float,
                     tree_cfg: TreeConfig, engine: TreeEngine,
                     score_fn: Optional[ScoreFn] = None, *,
                     finite: bool = True,
                     report: Optional[SamplerReport] = None) -> None:
    if not finite:
        # numeric quarantine: the engine pulled non-finite logprobs for
        # this row — retire the path WITHOUT extending it (the segment's
        # tokens came from poisoned logits); siblings are unaffected
        if report is not None:
            report.num_quarantined += 1
        _finish_path(tree, path, Status.FAILED, "nonfinite", engine,
                     score_fn)
        return
    seg_tokens, seg_logprobs = truncate_at_eos(seg_tokens, seg_logprobs)
    path.tokens.extend(seg_tokens)
    path.logprobs.extend(seg_logprobs)
    path.depth += 1
    path.node_ids.append(new_node_id())
    path.seg_bounds.append(len(path.tokens))
    path.seg_logprob = seg_logprob
    path.seg_logprobs.append(seg_logprob)
    tree.total_segments += 1

    reason = segment_stop_reason(
        seg_tokens, path.tokens,
        max_ngram=tree_cfg.repetition_ngram,
        count=tree_cfg.repetition_count)
    if reason in ("eos", "boxed"):
        _finish_path(tree, path, Status.LEAF, reason, engine, score_fn)
    elif reason == "repetition":
        _finish_path(tree, path, Status.FAILED, reason, engine, score_fn)
    elif path.depth >= tree_cfg.max_depth:
        _finish_path(tree, path, Status.LEAF, "length", engine, score_fn)
    else:
        tree.active.append(path)


def _branch_tree(tree: QueryTree, tree_cfg: TreeConfig, engine: TreeEngine,
                 rng: random.Random, progress: float,
                 score_fn: Optional[ScoreFn] = None,
                 report: Optional[SamplerReport] = None) -> None:
    """Apply the depth budget to this tree's active paths (paper §2.2:
    budget transfer evens dead paths' allowance over the survivors).

    After a DFS fallback round the active list can be *mixed-depth*
    (fallback children restart at their fork depth), so the budget is
    computed per depth group — one global ``active[0].depth`` budget
    would over- or under-allocate every other depth.

    Pressure-aware term (docs/robustness.md): each depth group's budget
    passes through ``branching.throttle_budget`` — above the soft KV
    watermark the extra fan-out shrinks, at the hard watermark only
    continuations survive — and the round's total new forks are hard-
    capped by the pages/slots the pool can actually absorb (a fork costs
    at most one COW page + one recurrent slot).
    """
    if not tree.active:
        return
    budgets = br.mixed_depth_budgets(
        tree_cfg, [p.depth for p in tree.active], tree.init_div,
        tree.num_trajectories)
    pressure_fn = getattr(engine, "pressure", None)
    if pressure_fn is not None:
        pressure = pressure_fn()
        counts: Dict[int, int] = {}
        for p in tree.active:
            counts[p.depth] = counts.get(p.depth, 0) + 1
        budgets = {d: br.throttle_budget(tree_cfg, b, counts[d], pressure)
                   for d, b in budgets.items()}
    fork_cap = _fork_capacity(engine, tree_cfg)
    # collect the round's forks, then branch them in ONE engine call:
    # one jitted page/slot-copy dispatch + one on-device fork_sample.
    survivors: List[Tuple[Path, int]] = []
    parents = []
    for depth in sorted(budgets, reverse=True):
        group = [p for p in tree.active if p.depth == depth]
        forks = br.assign_branches(
            tree_cfg, [p.seg_logprob for p in group], budgets[depth], rng,
            progress)
        for path, k in zip(group, forks):
            if k <= 0:
                # width budget exhausted: prune (counts as failed, no reward)
                _finish_path(tree, path, Status.FAILED, "budget", engine,
                             score_fn)
                continue
            k = min(k, 1 + max(fork_cap - len(parents), 0))
            survivors.append((path, k))
            parents.extend([path.ep] * (k - 1))
    children = engine.fork_paths(parents)
    new_active: List[Path] = []
    ci = 0
    for path, k in survivors:
        new_active.append(path)
        for _ in range(k - 1):
            new_active.append(path.clone_for_branch(children[ci]))
            ci += 1
    tree.active = _quarantine_nonfinite(tree, new_active, engine, score_fn,
                                        report)


def _fork_capacity(engine: TreeEngine, tree_cfg: TreeConfig) -> int:
    """Upper bound on new forks the pool can absorb right now: one COW
    page each, reserving one path's next decode segment, and one slot
    each on recurrent archs.  Engines without allocator surface (host-
    side unit-test fakes) are unconstrained."""
    pages_free_fn = getattr(engine, "pages_free", None)
    if pages_free_fn is None:
        return 1 << 30
    reserve = -(-tree_cfg.segment_len // engine.page_size) + 1
    cap = max(pages_free_fn() - reserve, 0)
    if getattr(engine, "has_rec", False):
        cap = min(cap, len(engine.kv.slots.free))
    return cap


def _quarantine_nonfinite(tree: QueryTree, paths: List[Path],
                          engine: TreeEngine,
                          score_fn: Optional[ScoreFn],
                          report: Optional[SamplerReport]) -> List[Path]:
    """Drop paths whose divergence draw came back non-finite (flagged by
    the engine in ``sample_pending_batch``)."""
    kept: List[Path] = []
    for p in paths:
        if p.ep is not None and getattr(p.ep, "numeric_bad", False):
            if report is not None:
                report.num_quarantined += 1
            _finish_path(tree, p, Status.FAILED, "nonfinite", engine,
                         score_fn)
        else:
            kept.append(p)
    return kept


def _fallback_tree(tree: QueryTree, tree_cfg: TreeConfig,
                   engine: TreeEngine, rng: random.Random,
                   guard: int, n_prefix: int,
                   report: SamplerReport) -> None:
    """DFS fallback: refill a starved query from its finished leaves."""
    if tree.active or not tree_cfg.fallback:
        return
    needed = tree_cfg.max_width - tree.num_trajectories
    while needed > 0 and tree.total_segments < guard:
        picked = pick_fallback(tree, rng)
        if picked is None:
            return
        src, j = picked
        prefix_count = src.seg_bounds[j]
        prefix_position = n_prefix + len(tree.prompt_tokens) + prefix_count
        replay = list(tree.prompt_tokens) + src.tokens[:prefix_count]
        # KV-pressure guard: a fallback fork costs one COW page (attention)
        # or a full prefix replay into fresh pages (recurrent) plus one
        # decode segment — don't start one the pool can't finish
        pages_free_fn = getattr(engine, "pages_free", None)
        if pages_free_fn is not None:
            reserve = -(-tree_cfg.segment_len // engine.page_size) + 1
            prefix_pages = -(-prefix_position // engine.page_size)
            need = (prefix_pages if engine.has_rec else 1) + reserve
            if pages_free_fn() < need or (
                    engine.has_rec and not engine.kv.slots.free):
                return
        child_ep = engine.fork_from_prefix(src.ep, prefix_position, replay)
        # the child's last segment is the *prefix* segment j, so the next
        # branching round's uncertainty heuristic must see that segment's
        # mean logprob — not the source leaf's final-segment value
        child = Path(
            query_idx=tree.query_idx,
            depth=j,
            node_ids=src.node_ids[: j + 1],
            tokens=src.tokens[:prefix_count],
            logprobs=src.logprobs[:prefix_count],
            ep=child_ep,
            seg_bounds=src.seg_bounds[: j + 1],
            seg_logprob=(src.seg_logprobs[j - 1]
                         if len(src.seg_logprobs) >= j >= 1
                         else src.seg_logprob),
            seg_logprobs=src.seg_logprobs[:j],
        )
        tree.active.extend(
            _quarantine_nonfinite(tree, [child], engine, None, report))
        report.num_fallbacks += 1
        needed -= 1


def _release_leaf_kv(trees: List[QueryTree], engine: TreeEngine,
                     needed: int) -> int:
    """Graceful-degradation victim #1: finished leaves retain their KV
    only to seed DFS fallback, so under pool pressure that retention is
    the cheapest thing to give up (the leaf trajectory itself is kept —
    only future fallback quality degrades).  Frees pages until ``needed``
    is met or no retained leaf KV remains; returns pages freed."""
    freed = 0
    for tree in trees:
        for p in tree.finished:
            if freed >= needed:
                return freed
            if p.ep is not None and not p.ep.released:
                before = engine.kv.pool.pages_in_use
                engine.release_path(p.ep)
                freed += before - engine.kv.pool.pages_in_use
    return freed


def _decode_pages_needed(engine: TreeEngine, ep, seg_len: int) -> int:
    pages = -(-(ep.position + seg_len) // engine.page_size)
    return max(pages - len(ep.table), 0)


def _admit_for_decode(trees: List[QueryTree],
                      batch: List[Tuple[QueryTree, Path]],
                      engine: TreeEngine, tree_cfg: TreeConfig,
                      report: SamplerReport,
                      score_fn: Optional[ScoreFn]
                      ) -> List[Tuple[QueryTree, Path]]:
    """Admission control before a decode round: if the round's worst-case
    page demand exceeds the free pool, first reclaim finished leaves'
    retained KV, then retract the lowest-value active paths — deepest
    first, lowest ``seg_logprob`` as tiebreak (the same value ordering
    the paper's heuristics rank by).  Retracted paths keep their host
    tokens and are parked on ``tree.preempted`` for regeneration; on
    archs whose context is not token-reconstructable (modality prefix /
    cross-KV) they are finished FAILED("preempted") instead.  At least
    one path is always admitted so the rollout makes progress."""
    seg = tree_cfg.segment_len
    demand = sum(_decode_pages_needed(engine, p.ep, seg) for _, p in batch)
    free = engine.pages_free()
    if demand > free:
        free += _release_leaf_kv(trees, engine, demand - free)
    if demand <= free:
        return batch
    order = sorted(range(len(batch)),
                   key=lambda i: (-batch[i][1].depth,
                                  batch[i][1].seg_logprob))
    admitted = set(range(len(batch)))
    for i in order:
        if demand <= free or len(admitted) <= 1:
            break
        tree, path = batch[i]
        admitted.discard(i)
        demand -= _decode_pages_needed(engine, path.ep, seg)
        report.num_preempted += 1
        if engine.can_restore:
            free += engine.preempt_path(path.ep)
            path.ep = None
            tree.preempted.append(path)
        else:
            before = engine.kv.pool.pages_in_use
            _finish_path(tree, path, Status.FAILED, "preempted", engine,
                         score_fn)
            free += before - engine.kv.pool.pages_in_use
    return [batch[i] for i in sorted(admitted)]


def _regenerate_tree(tree: QueryTree, engine: TreeEngine,
                     tree_cfg: TreeConfig, guard: int,
                     report: SamplerReport,
                     score_fn: Optional[ScoreFn],
                     force: bool = False) -> int:
    """Re-admit preempted paths once the pool has headroom: replay their
    full token history into fresh pages (``TreeEngine.restore_path``),
    highest-value first (shallowest / best seg_logprob — the reverse of
    the retraction order).  Normally regeneration waits for occupancy to
    come back under the soft watermark; ``force`` (used when a tree
    would otherwise stall with an empty frontier) admits one path as
    long as its replay + one decode segment physically fit."""
    regen = 0
    while tree.preempted and tree.total_segments < guard:
        idx = min(range(len(tree.preempted)),
                  key=lambda i: (tree.preempted[i].depth,
                                 -tree.preempted[i].seg_logprob))
        path = tree.preempted[idx]
        tokens = list(tree.prompt_tokens) + path.tokens
        pages = -(-(engine.n_prefix + len(tokens) + tree_cfg.segment_len)
                  // engine.page_size)
        if engine.has_rec and not engine.kv.slots.free:
            break
        below_soft = (engine.kv.pool.pages_in_use + pages
                      <= tree_cfg.kv_watermark_soft
                      * engine.kv.pool.num_pages)
        if not (below_soft or (force and pages <= engine.pages_free())):
            break
        tree.preempted.pop(idx)
        path.ep = engine.restore_path(tokens)
        report.num_regenerated += 1
        for p in _quarantine_nonfinite(tree, [path], engine, score_fn,
                                       report):
            tree.active.append(p)
            regen += 1
        if force:
            break
    return regen


def sample_trees(engine: TreeEngine, prompts: List[List[int]],
                 targets: List[str], tree_cfg: Optional[TreeConfig] = None,
                 *, rng: Optional[random.Random] = None,
                 progress: float = 0.0,
                 prefix_embeds=None, enc_frames=None,
                 guard_factor: int = 4,
                 score_fn: Optional[ScoreFn] = None,
                 ) -> Tuple[List[QueryTree], SamplerReport]:
    """Run Algorithm 1 for a batch of queries.  Returns the query trees
    (finished paths = trajectories) and a sampling report."""
    tree_cfg = tree_cfg or engine.tree_cfg
    rng = rng or random.Random(0)
    report = SamplerReport(num_queries=len(prompts))
    guard = tree_cfg.max_width * tree_cfg.max_depth * guard_factor

    trees = [QueryTree(query_idx=i, prompt_tokens=list(p), target=t,
                       max_depth=tree_cfg.max_depth)
             for i, (p, t) in enumerate(zip(prompts, targets))]

    # under allocation pressure the engine retries a failed page/slot
    # alloc after this callback reclaims finished leaves' retained KV —
    # never in-flight paths, which only admission control may retract
    engine.set_pressure_cb(
        lambda needed: _release_leaf_kv(trees, engine, needed))
    qslot_of: Dict[int, int] = {}
    try:
        # 1-2. prefill + init divergence ----------------------------------
        roots = engine.prefill_queries(prompts,
                                       prefix_embeds=prefix_embeds,
                                       enc_frames=enc_frames)
        for tree, root_ep in zip(trees, roots):
            qslot_of[tree.query_idx] = root_ep.qslot
            n_init = min(br.init_divergence(tree_cfg, rng),
                         tree_cfg.max_width)
            tree.init_div = n_init
            eps = [root_ep] + engine.fork_paths([root_ep] * (n_init - 1))
            tree.active = _quarantine_nonfinite(
                tree,
                [Path(query_idx=tree.query_idx, depth=0,
                      node_ids=[tree.root_id], tokens=[], logprobs=[],
                      ep=ep)
                 for ep in eps],
                engine, score_fn, report)

        # 3. segment-synchronous search loop ------------------------------
        while True:
            batch = [(tree, p) for tree in trees for p in tree.active]
            if not batch:
                # frontier empty but retracted paths remain: force-revive
                # one per tree so pressure preemption cannot strand work
                if not any(_regenerate_tree(tree, engine, tree_cfg, guard,
                                            report, score_fn, force=True)
                           for tree in trees if tree.preempted):
                    break
                continue
            for tree in trees:
                tree.active = []
            batch = _admit_for_decode(trees, batch, engine, tree_cfg,
                                      report, score_fn)
            results = engine.decode_segments([p.ep for _, p in batch])
            report.decode_rounds += 1
            for (tree, path), res in zip(batch, results):
                _process_segment(tree, path, res.tokens, res.logprobs,
                                 res.seg_logprob, tree_cfg, engine,
                                 score_fn, finite=res.finite,
                                 report=report)
            for tree in trees:
                _branch_tree(tree, tree_cfg, engine, rng, progress,
                             score_fn, report)
                _fallback_tree(tree, tree_cfg, engine, rng, guard,
                               engine.n_prefix, report)
                if tree.preempted:
                    _regenerate_tree(tree, engine, tree_cfg, guard,
                                     report, score_fn)

        # preempted paths the budget never recovered for: graceful
        # degradation means they are dropped as failed trajectories, not
        # an escaped OutOfPages
        for tree in trees:
            for p in tree.preempted:
                _finish_path(tree, p, Status.FAILED, "preempted", engine,
                             score_fn)
            tree.preempted = []
    except OutOfPages as e:
        # annotate the in-flight exhaustion so it is debuggable from the
        # exception alone (this should be unreachable under the pressure
        # protocol — reaching it is itself the bug report)
        per_query = {
            t.query_idx: len({pid for p in (t.active + t.finished)
                              if p.ep is not None
                              for pid in p.ep.table})
            for t in trees}
        raise e.annotate(
            live_paths=sum(len(t.active) for t in trees),
            per_query_pages=per_query)
    finally:
        engine.set_pressure_cb(None)

    # 4. release device resources ------------------------------------------
    for tree in trees:
        for p in tree.finished:
            if p.ep is not None:
                engine.release_path(p.ep)
        report.num_trajectories += tree.num_trajectories
        report.num_leaves += tree.num_leaves
        report.num_failed += sum(1 for p in tree.finished
                                 if p.status == Status.FAILED)
    for qslot in qslot_of.values():
        engine.release_qslot(qslot)
    return trees, report


def sequential_tree_cfg(tree_cfg: TreeConfig) -> TreeConfig:
    """The paper's sequential baseline expressed in tree terms: ``w``
    independent rollouts, no branching, no fallback, no early stop
    transfer (repetition stop retained — both samplers use it)."""
    return dataclasses.replace(
        tree_cfg,
        branch_factor=1,
        init_divergence_low=tree_cfg.max_width,
        init_divergence_high=tree_cfg.max_width,
        fallback=False,
        budget_transfer=False,
    )


def sample_sequential(engine: TreeEngine, prompts: List[List[int]],
                      targets: List[str],
                      tree_cfg: Optional[TreeConfig] = None, **kw
                      ) -> Tuple[List[QueryTree], SamplerReport]:
    """Vanilla i.i.d. rollout baseline driven through the same engine."""
    tree_cfg = sequential_tree_cfg(tree_cfg or engine.tree_cfg)
    return sample_trees(engine, prompts, targets, tree_cfg, **kw)
