"""Branching-budget policies (paper §2.2 Branching, §4.4 heuristics).

The budget contract: at depth ``d`` the tree may hold up to
``init_div * N^d`` concurrent paths, capped by the remaining width
(``w - finished``).  *Budget transfer* re-assigns the allowance of early-
stopped paths to the survivors, keeping the inference batch full.  The
distribution of extra forks over the active paths is the heuristic knob
(``TreeConfig.branch_heuristic``; the ``*_encourage`` aliases are
accepted for the prob-guided pair):

  uniform            — round-robin (the paper's default);
  low_prob           — softmax(-seg_logprob / tau): uncertain paths fork
                       more (paper finds this *harmful* — §4.4);
  high_prob          — softmax(+seg_logprob / tau): confident paths fork
                       more (overly greedy);
  scheduled_low_prob — low_prob with tau annealed across training
                       (5.0 -> 1.0 in the paper's ablation).

The per-path heuristic signal is the mean logprob of the path's LAST
decoded segment — ``Path.seg_logprob``, which since PR 3 is the tail of
the per-segment ``Path.seg_logprobs`` list, so a DFS-fallback child at
fork depth j reads its *prefix* segment's value, not the source leaf's.

Every active path always keeps >= 1 continuation while the budget
permits (the paper's guarantee); after mixed-depth fallback each depth
group is budgeted independently (``mixed_depth_budgets``).
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Sequence

from repro.configs.base import TreeConfig


def init_divergence(tree_cfg: TreeConfig, rng: random.Random) -> int:
    """Number of root forks: fixed, or uniform in [low, high] ("More Init
    Divergence")."""
    lo, hi = tree_cfg.init_divergence_low, tree_cfg.init_divergence_high
    if hi <= lo:
        return max(1, lo)
    return rng.randint(lo, hi)


def depth_budget(tree_cfg: TreeConfig, depth: int, init_div: int,
                 num_finished: int) -> int:
    """Max concurrent paths allowed at this depth (budget transfer makes it
    a *total* across live paths, not per-path)."""
    raw = init_div * (tree_cfg.branch_factor ** depth)
    cap = max(tree_cfg.max_width - num_finished, 0)
    return max(min(raw, cap), 0)


def mixed_depth_budgets(tree_cfg: TreeConfig, depths: Sequence[int],
                        init_div: int, num_finished: int) -> Dict[int, int]:
    """Per-depth total budgets for a mixed-depth active set.

    After DFS fallback the active list can hold paths at several depths
    (each fallback child restarts at its fork depth j), so one
    ``depth_budget(active[0].depth)`` call cannot be applied to all of
    them.  Each unique depth gets its own ``init_div * N^d`` allowance,
    and the shared width cap (``max_width - finished``) is split in two
    phases: first one continuation per path (deepest group first — a
    fresh fallback child is never starved by another depth's fan-out),
    then extra forks up to each group's remaining allowance, again
    deepest first (DFS bias: prefer long-reasoning paths).
    Returns {depth: total budget for that depth's group}.

    With a single depth present this reduces exactly to
    ``{d: depth_budget(tree_cfg, d, init_div, num_finished)}``.
    """
    from collections import Counter

    counts = Counter(depths)
    cap = max(tree_cfg.max_width - num_finished, 0)
    raws = {d: init_div * (tree_cfg.branch_factor ** d) for d in counts}
    order = sorted(counts, reverse=True)
    budgets: Dict[int, int] = {}
    for d in order:                        # phase 1: keep paths alive
        take = max(min(counts[d], raws[d], cap), 0)
        budgets[d] = take
        cap -= take
    for d in order:                        # phase 2: distribute fan-out
        extra = max(min(raws[d] - budgets[d], cap), 0)
        budgets[d] += extra
        cap -= extra
    return budgets


def softmax_weights(seg_logprobs: Sequence[float], tau: float,
                    sign: float) -> List[float]:
    z = [sign * lp / max(tau, 1e-6) for lp in seg_logprobs]
    m = max(z)
    e = [math.exp(v - m) for v in z]
    s = sum(e)
    return [v / s for v in e]


def heuristic_tau(tree_cfg: TreeConfig, progress: float) -> float:
    """progress in [0, 1] over training; schedules tau for the scheduled
    variant, constant otherwise."""
    if tree_cfg.branch_heuristic == "scheduled_low_prob":
        start, end = 5.0, 1.0
        return start + (end - start) * min(max(progress, 0.0), 1.0)
    return tree_cfg.heuristic_temp


def assign_branches(tree_cfg: TreeConfig, seg_logprobs: Sequence[float],
                    total_budget: int, rng: random.Random,
                    progress: float = 0.0) -> List[int]:
    """Split ``total_budget`` continuations over the active paths.

    seg_logprobs: mean logprob of each active path's last segment (the free
    heuristic signal returned by the engine).  Returns forks-per-path
    (each >= 1 while budget permits).
    """
    n = len(seg_logprobs)
    if n == 0:
        return []
    total = max(total_budget, 0)
    if total <= n:
        # not enough budget to even continue everything: keep the first
        # `total` paths (caller decides survivor order; uniform = as-is)
        return [1 if i < total else 0 for i in range(n)]
    extra = total - n
    kind = tree_cfg.branch_heuristic
    if kind == "uniform":
        forks = [1] * n
        order = list(range(n))
        rng.shuffle(order)
        for i in range(extra):
            forks[order[i % n]] += 1
        return forks
    tau = heuristic_tau(tree_cfg, progress)
    sign = +1.0 if kind == "high_prob" or kind == "high_prob_encourage" \
        else -1.0
    w = softmax_weights(seg_logprobs, tau, sign)
    # largest-remainder apportionment of the extra budget
    quotas = [wi * extra for wi in w]
    forks = [1 + int(q) for q in quotas]
    rem = extra - sum(int(q) for q in quotas)
    order = sorted(range(n), key=lambda i: quotas[i] - int(quotas[i]),
                   reverse=True)
    for i in range(rem):
        forks[order[i % n]] += 1
    return forks


def pressure_scale(tree_cfg: TreeConfig, pressure: float) -> float:
    """Fraction of the *extra* (beyond-continuation) branching budget
    kept at the given KV-pool pressure (``PagePool.watermark``).

    1.0 below the soft watermark, linear to 0.0 at the hard watermark:
    the tree stops minting new divergence before the pool exhausts, so
    engine-side preemption is the exception, not the steady state."""
    if not tree_cfg.pressure_aware:
        return 1.0
    soft, hard = tree_cfg.kv_watermark_soft, tree_cfg.kv_watermark_hard
    if pressure <= soft:
        return 1.0
    if pressure >= hard:
        return 0.0
    return (hard - pressure) / max(hard - soft, 1e-9)


def throttle_budget(tree_cfg: TreeConfig, budget: int, n_active: int,
                    pressure: float) -> int:
    """Pressure-aware term of the branching heuristic: every active path
    keeps its continuation (the paper's guarantee is never throttled);
    only the extra fan-out is scaled by :func:`pressure_scale`."""
    keep = min(budget, n_active)
    extra = max(budget - keep, 0)
    return keep + int(extra * pressure_scale(tree_cfg, pressure))
