"""TreePO core: tree-based rollout engine + tree-based advantage.

The paper's primary contribution lives here:
  engine.py    — segment-synchronous paged tree-decoding engine
  sampler.py   — Algorithm 1 (tree-based sampling) host orchestration
  branching.py — budget policies (N-ary, budget transfer, prob heuristics)
  fallback.py  — DFS fallback from finished leaves
  early_stop.py— EOS / boxed / repetition leaf classification
  tree.py      — host tree bookkeeping + ancestor matrices
  advantage.py — Eq. 2/5/6/7 advantage estimators
  loss.py      — Eq. 1 GRPO/DAPO clipped token-level PG objective
"""
from repro.core.advantage import (
    batch_treepo_advantage,
    global_normalize,
    grpo_advantage,
    query_keep_mask,
    treepo_advantage,
)
from repro.core.engine import EnginePath, SegmentResult, TreeEngine
from repro.core.loss import dapo_pg_loss, entropy_from_logits, \
    token_logprobs_from_logits
from repro.core.sampler import (
    SamplerReport,
    sample_sequential,
    sample_trees,
    sequential_tree_cfg,
)
from repro.core.tree import (
    Path,
    QueryTree,
    Status,
    ancestor_matrix,
    batch_group_tensors,
)
