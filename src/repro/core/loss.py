"""GRPO/DAPO policy-gradient objective (paper Eq. 1).

Token-level clipped importance-weighted PG with DAPO's clip-higher
(eps_low != eps_high) and token-level (not sequence-level) normalization:
the sum over all tokens of all trajectories is divided by the total token
count of the batch, as in Eq. 1's 1/Σ|o_i| prefactor.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def token_logprobs_from_logits(logits: jnp.ndarray,
                               tokens: jnp.ndarray) -> jnp.ndarray:
    """logits: (..., S, V); tokens: (..., S) -> log pi(token) (..., S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    tok_logit = jnp.take_along_axis(logits, tokens[..., None],
                                    axis=-1)[..., 0]
    return tok_logit - logz


def dapo_pg_loss(
    logprobs_new: jnp.ndarray,
    logprobs_old: jnp.ndarray,
    advantages: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    clip_eps_low: float = 0.2,
    clip_eps_high: float = 0.28,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Eq. 1. All inputs (..., S) token-level; advantages broadcastable.

    Returns (scalar loss, metrics).
    """
    ratio = jnp.exp(logprobs_new - logprobs_old)
    clipped = jnp.clip(ratio, 1.0 - clip_eps_low, 1.0 + clip_eps_high)
    obj = jnp.minimum(ratio * advantages, clipped * advantages)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = -(obj * mask).sum() / denom
    clip_frac = ((jnp.abs(ratio - 1.0) >
                  jnp.where(advantages > 0, clip_eps_high, clip_eps_low))
                 * mask).sum() / denom
    metrics = {
        "pg_loss": loss,
        "ratio_mean": (ratio * mask).sum() / denom,
        "clip_frac": clip_frac,
        "adv_mean": (advantages * mask).sum() / denom,
    }
    return loss, metrics


def entropy_from_logits(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean per-token entropy (reported in the paper's 'entropy loss' plots)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ent = -(jnp.exp(logp) * logp).sum(axis=-1)
    return (ent * mask).sum() / jnp.maximum(mask.sum(), 1.0)
