"""Deterministic fault injection for the rollout/training stack.

Every robustness behavior in this repo (KV-pressure degradation, numeric
quarantine, crash-safe resume) is provable in tests because faults are
*injected*, not hoped for.  A :class:`FaultInjector` is a context manager
armed with specs that fire at the *n*-th occurrence of a named site:

    with FaultInjector(seed=0) as fi:
        fi.page_exhaustion(at_alloc=5)            # 5th page alloc raises
        fi.nan_logits(at_round=2, rows=(0,))      # NaN decode row, round 2
        fi.nan_grads(at_step=1)                   # poison one update batch
        fi.kill("ckpt.pre_rename")                # simulate kill -9
        ...  # run the system under test

Sites are plain strings checked by cheap module-level helpers (`fires`,
`corrupt_array`, `kill_point`) that are no-ops when no injector is
active, so production paths pay one global read.  Counters are per-site
and deterministic: the k-th event of a site fires iff a spec covers k,
independent of timing.  The seeded RNG backs optional probabilistic
specs (``prob=``), keeping even randomized campaigns reproducible.

Instrumented sites:

==========================  ================================================
``page_pool.alloc``         :meth:`repro.kv.cache.PagePool.alloc` raises
                            ``OutOfPages`` (installed via the module-global
                            ``fault_hook`` to avoid an import cycle)
``engine.decode_logprobs``  per-round (R, l) segment logprobs pulled by
                            ``TreeEngine.decode_segments``
``engine.fork_logprobs``    per-call (F,) divergence draws pulled by
                            ``TreeEngine.sample_pending_batch``
``trainer.batch_logprobs``  the (N, L) rollout-logprobs plane fed to the
                            jitted update (NaN here poisons loss *and*
                            grads inside jit)
``kill:<point>``            process-interrupt points — ``ckpt.pre_write``,
                            ``ckpt.pre_rename``, ``ckpt.post_rename``
                            (checkpoint store) and ``train.step`` (launch
                            driver) raise :class:`InjectedCrash`
==========================  ================================================

Only one injector may be active at a time (no nesting); arming installs
the KV-cache hook and disarming removes it, even on exceptions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """Base class for all injector-raised failures."""


class InjectedCrash(InjectedFault):
    """Simulated process interrupt (``kill -9``) at a named kill point."""


@dataclasses.dataclass
class _Spec:
    site: str
    at: int                       # 1-based event index that fires
    times: int = 1                # consecutive events that fire
    rows: Tuple[int, ...] = (0,)  # rows to corrupt (corrupt_array sites)
    value: float = float("nan")
    prob: float = 0.0             # extra per-event probability (seeded)

    def covers(self, n: int) -> bool:
        return self.at <= n < self.at + self.times


class FaultInjector:
    """Seeded, deterministic fault-injection harness (context manager)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self._specs: Dict[str, List[_Spec]] = {}
        self.counters: Dict[str, int] = {}
        self.fired: List[Tuple[str, int]] = []   # (site, event#) log

    # -- spec builders (chainable) ----------------------------------------

    def on(self, site: str, at: int, *, times: int = 1,
           rows: Tuple[int, ...] = (0,), value: float = float("nan"),
           prob: float = 0.0) -> "FaultInjector":
        self._specs.setdefault(site, []).append(
            _Spec(site, at, times, tuple(rows), value, prob))
        return self

    def page_exhaustion(self, at_alloc: int,
                        times: int = 1) -> "FaultInjector":
        return self.on("page_pool.alloc", at_alloc, times=times)

    def nan_logits(self, at_round: int,
                   rows: Tuple[int, ...] = (0,)) -> "FaultInjector":
        return self.on("engine.decode_logprobs", at_round, rows=rows)

    def nan_fork_logits(self, at_call: int,
                        rows: Tuple[int, ...] = (0,)) -> "FaultInjector":
        return self.on("engine.fork_logprobs", at_call, rows=rows)

    def nan_grads(self, at_step: int) -> "FaultInjector":
        return self.on("trainer.batch_logprobs", at_step)

    def kill(self, point: str, at: int = 1) -> "FaultInjector":
        return self.on("kill:" + point, at)

    # -- firing ------------------------------------------------------------

    def _match(self, site: str) -> Optional[_Spec]:
        n = self.counters.get(site, 0) + 1
        self.counters[site] = n
        for spec in self._specs.get(site, ()):
            if spec.covers(n) or (spec.prob > 0.0
                                  and self.rng.random() < spec.prob):
                self.fired.append((site, n))
                return spec
        return None

    def fires(self, site: str) -> bool:
        return self._match(site) is not None

    def corrupt_array(self, site: str, arr: np.ndarray,
                      col: int = 0) -> np.ndarray:
        spec = self._match(site)
        if spec is None:
            return arr
        self.fired.pop()              # re-log below with row detail
        out = np.array(arr, copy=True)
        flat = out.reshape(out.shape[0], -1) if out.ndim > 1 \
            else out.reshape(-1, 1)
        for r in spec.rows:
            r = r % flat.shape[0]
            flat[r, col % flat.shape[1]] = spec.value
            self.fired.append((site, self.counters[site]))
        return out

    def kill_point(self, point: str) -> None:
        if self.fires("kill:" + point):
            raise InjectedCrash(point)

    # -- arming ------------------------------------------------------------

    def __enter__(self) -> "FaultInjector":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("FaultInjector does not nest")
        _ACTIVE = self
        import repro.kv.cache as kvc   # lazy: avoids core<->kv cycle
        self._prev_hook = kvc.fault_hook
        kvc.fault_hook = self.fires
        return self

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        _ACTIVE = None
        import repro.kv.cache as kvc
        kvc.fault_hook = self._prev_hook
        return None


# -- module-level helpers (cheap no-ops when disarmed) ----------------------

_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


def fires(site: str) -> bool:
    a = _ACTIVE
    return False if a is None else a.fires(site)


def corrupt_array(site: str, arr, col: int = 0):
    a = _ACTIVE
    return arr if a is None else a.corrupt_array(site, arr, col)


def kill_point(point: str) -> None:
    a = _ACTIVE
    if a is not None:
        a.kill_point(point)
