"""Runtime hot-path guards: device residency as a *checked* invariant.

Every efficiency property the tree engine and trainer earn — amortized
prefix compute, device-resident boundary logits, one jitted K-epoch
update per bucket — survives only while the hot paths stay on device
and each (shape) bucket compiles exactly once.  This module is the
runtime half of the enforcement layer (the static half is
``tools/analyze``, see ``docs/static_analysis.md``):

* :func:`annotated_transfer` — the ONE sanctioned door between host and
  device on a hot path.  Takes an arbitrary pytree and moves it in a
  single batched call (``jax.device_get`` / ``jax.device_put``), so a
  round's pulls coalesce into one transfer instead of one per array,
  and tags the transfer with a ``reason`` an armed guard records.

* :func:`hot_path_guard` — a context manager that arms
  ``jax.transfer_guard("disallow")`` (authoritative on real
  accelerators) plus a Python-level interception of the repo's transfer
  entry points (``np.asarray`` / ``np.array`` / ``jax.device_get`` on
  device arrays, ``jnp.asarray`` / ``jnp.array`` / ``jax.device_put``
  on host ndarrays outside a trace) — the CPU container performs those
  zero-copy, so the XLA guard alone cannot see them.  Un-annotated
  transfers raise :class:`HotPathViolation` at exit, listing every
  offending call site; annotated ones are tallied per reason.

* :func:`compile_count` / :func:`compile_delta` — a process-wide
  compilation counter fed by ``jax.monitoring`` backend-compile events,
  and :func:`compile_cache_size` for per-jitted-function trace-cache
  sizes — together they turn "one compilation per bucket" into an
  assertable number (``tests/test_guard.py``,
  ``benchmarks/train_hotpath.py``'s ``recompiles`` field).

Known limits (documented, not silent): dunder conversions
(``float(x)`` / ``int(x)`` on a device array) cannot be intercepted
from Python and are only caught by the XLA transfer guard on non-CPU
backends — the static analyzer's R1 rule covers them at review time;
implicit h2d at jit dispatch (passing a raw ``np.ndarray`` into a
jitted function) is likewise only visible to the XLA guard.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "HotPathViolation",
    "GuardReport",
    "annotated_transfer",
    "hot_path_guard",
    "compile_count",
    "compile_delta",
    "compile_cache_size",
]


class HotPathViolation(RuntimeError):
    """An un-annotated host<->device transfer happened under
    :func:`hot_path_guard`."""


# ---------------------------------------------------------------------------
# compile counter (jax.monitoring backend-compile events)
# ---------------------------------------------------------------------------

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_compile_lock = threading.Lock()
_compile_state = {"count": 0, "registered": False}


def _on_event_duration(event: str, duration: float, **_kw) -> None:
    if event == _COMPILE_EVENT:
        with _compile_lock:
            _compile_state["count"] += 1


def _ensure_listener() -> None:
    if not _compile_state["registered"]:
        with _compile_lock:
            if not _compile_state["registered"]:
                jax.monitoring.register_event_duration_secs_listener(
                    _on_event_duration)
                _compile_state["registered"] = True


def compile_count() -> int:
    """Total XLA backend compilations observed since the listener was
    first armed (any call to this module arms it).  Use deltas — the
    absolute value depends on what compiled before arming."""
    _ensure_listener()
    return _compile_state["count"]


@contextlib.contextmanager
def compile_delta():
    """``with compile_delta() as d: ...; d()`` — number of backend
    compilations inside the block (0 on a warm steady-state path)."""
    start = compile_count()
    yield lambda: compile_count() - start


def compile_cache_size(jitted_fn) -> int:
    """Number of traced specializations cached on a ``jax.jit`` function
    (-1 if this jax version doesn't expose it).  A per-bucket cached jit
    holding exactly 1 entry is the "compiled exactly once per bucket"
    invariant."""
    getter = getattr(jitted_fn, "_cache_size", None)
    if getter is None:
        return -1
    try:
        return int(getter())
    except Exception:
        return -1


# ---------------------------------------------------------------------------
# transfer interception
# ---------------------------------------------------------------------------

_tls = threading.local()


def _state() -> dict:
    st = getattr(_tls, "state", None)
    if st is None:
        st = {"guard": None, "annotating": 0, "intercepting": 0}
        _tls.state = st
    return st


def _tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += int(getattr(leaf, "nbytes", 0) or 0)
    return total


def _call_site(skip_prefixes: Tuple[str, ...] = ("guard.py",)) -> str:
    """repo-facing ``file:line`` of the frame that initiated a transfer
    (first frame outside this module and outside numpy/jax internals)."""
    for frame in reversed(traceback.extract_stack()[:-2]):
        fn = frame.filename
        if any(fn.endswith(p) for p in skip_prefixes):
            continue
        if "/numpy/" in fn or "/jax/" in fn or "/jaxlib/" in fn:
            continue
        return f"{fn}:{frame.lineno}"
    return "<unknown>"


@dataclasses.dataclass
class GuardReport:
    """What happened inside one :func:`hot_path_guard` block."""

    violations: List[str] = dataclasses.field(default_factory=list)
    annotated: List[Tuple[str, str, int]] = dataclasses.field(
        default_factory=list)          # (reason, direction, bytes)
    compiles_at_enter: int = 0

    @property
    def compiles(self) -> int:
        """Backend compilations since the guard was entered."""
        return compile_count() - self.compiles_at_enter

    @property
    def annotated_bytes(self) -> int:
        return sum(b for _, _, b in self.annotated)

    @property
    def annotated_reasons(self) -> Dict[str, int]:
        """reason -> number of annotated transfers under that label."""
        out: Dict[str, int] = {}
        for reason, _, _b in self.annotated:
            out[reason] = out.get(reason, 0) + 1
        return out


def _record_violation(direction: str, obj: Any) -> None:
    st = _state()
    guard: Optional[GuardReport] = st["guard"]
    # "intercepting" > 1: a patched entry point called another patched
    # entry point (jnp.asarray lowers to device_put) — one transfer,
    # recorded at the outermost wrapper only
    if guard is None or st["annotating"] or st["intercepting"] > 1:
        return
    desc = getattr(obj, "shape", None)
    dt = getattr(obj, "dtype", None)
    guard.violations.append(
        f"{direction} transfer of {dt}{list(desc) if desc is not None else ''}"
        f" at {_call_site()}")


def _is_device_array(x: Any) -> bool:
    return isinstance(x, jax.Array)


def _is_host_array(x: Any) -> bool:
    return isinstance(x, np.ndarray)


class _PatchSet:
    """Reversible monkeypatches of the transfer entry points.  Installed
    only while a guard is active (reference-counted for nesting)."""

    def __init__(self) -> None:
        self.depth = 0
        self._saved: List[Tuple[Any, str, Any]] = []

    def _patch(self, owner: Any, name: str, wrapper) -> None:
        self._saved.append((owner, name, getattr(owner, name)))
        setattr(owner, name, wrapper)

    def install(self) -> None:
        self.depth += 1
        if self.depth > 1:
            return
        import jax.numpy as jnp

        orig_np_asarray = np.asarray
        orig_np_array = np.array
        orig_device_get = jax.device_get
        orig_device_put = jax.device_put
        orig_jnp_asarray = jnp.asarray
        orig_jnp_array = jnp.array

        def _outermost(fn):
            # track wrapper nesting so a patched entry point that calls
            # another patched one (jnp.asarray lowers through
            # device_put) records ONE transfer, not two
            def wrapped(*a, **kw):
                st = _state()
                st["intercepting"] += 1
                try:
                    return fn(*a, **kw)
                finally:
                    st["intercepting"] -= 1
            return wrapped

        @_outermost
        def np_asarray(a, *args, **kwargs):
            if _is_device_array(a):
                _record_violation("device->host", a)
            return orig_np_asarray(a, *args, **kwargs)

        @_outermost
        def np_array(a, *args, **kwargs):
            if _is_device_array(a):
                _record_violation("device->host", a)
            return orig_np_array(a, *args, **kwargs)

        @_outermost
        def device_get(x):
            if any(_is_device_array(l)
                   for l in jax.tree_util.tree_leaves(x)):
                _record_violation("device->host", x)
            return orig_device_get(x)

        def _h2d_check(x):
            # constants materialized during tracing are baked into the
            # compiled program, not per-dispatch transfers — skip them
            if _is_host_array(x) and jax.core.trace_state_clean():
                _record_violation("host->device", x)

        @_outermost
        def device_put(x, *args, **kwargs):
            for leaf in jax.tree_util.tree_leaves(x):
                _h2d_check(leaf)
            return orig_device_put(x, *args, **kwargs)

        @_outermost
        def jnp_asarray(a, *args, **kwargs):
            _h2d_check(a)
            return orig_jnp_asarray(a, *args, **kwargs)

        @_outermost
        def jnp_array(a, *args, **kwargs):
            _h2d_check(a)
            return orig_jnp_array(a, *args, **kwargs)

        self._patch(np, "asarray", np_asarray)
        self._patch(np, "array", np_array)
        self._patch(jax, "device_get", device_get)
        self._patch(jax, "device_put", device_put)
        self._patch(jnp, "asarray", jnp_asarray)
        self._patch(jnp, "array", jnp_array)

    def remove(self) -> None:
        self.depth -= 1
        if self.depth > 0:
            return
        for owner, name, orig in reversed(self._saved):
            setattr(owner, name, orig)
        self._saved.clear()


_patches = _PatchSet()


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def annotated_transfer(tree: Any, *, to: str = "host",
                       reason: str = "unlabeled") -> Any:
    """Move a pytree across the host/device boundary in ONE batched call.

    ``to="host"``: one ``jax.device_get`` over the whole tree (returns
    numpy arrays); ``to="device"``: one ``jax.device_put``.  Inside an
    armed :func:`hot_path_guard` the transfer is allowlisted and tallied
    under ``reason``; outside a guard it is just the transfer.  This is
    the single door intended hot-path transfers go through — raw
    ``np.asarray`` / ``jnp.asarray`` on the hot path is a guard
    violation and a ``tools/analyze`` R1 finding.
    """
    if to not in ("host", "device"):
        raise ValueError(f"annotated_transfer: to={to!r} "
                         "(expected 'host' or 'device')")
    st = _state()
    st["annotating"] += 1
    try:
        with jax.transfer_guard("allow"):
            if to == "host":
                out = jax.device_get(tree)
            else:
                out = jax.device_put(tree)
    finally:
        st["annotating"] -= 1
    guard: Optional[GuardReport] = st["guard"]
    if guard is not None:
        guard.annotated.append(
            (reason, "d2h" if to == "host" else "h2d",
             _tree_bytes(out if to == "host" else tree)))
    return out


@contextlib.contextmanager
def hot_path_guard(*, use_transfer_guard: Optional[bool] = None,
                   raise_on_violation: bool = True):
    """Assert device residency over a block of hot-path host code.

    Yields a :class:`GuardReport`.  While active:

    * ``jax.transfer_guard("disallow")`` is armed (XLA-level; the
      authoritative check on TPU/GPU where transfers are real copies —
      ``use_transfer_guard`` defaults to backend != cpu, because on CPU
      the XLA guard also trips on weak scalar constants of un-jitted
      glue ops whose "transfers" are zero-copy there);
    * the Python entry points are intercepted so un-annotated transfers
      are caught on this CPU container too;
    * backend compilations are counted (``report.compiles`` — a warm
      steady-state block must report 0).

    On exit, any recorded violation raises :class:`HotPathViolation`
    listing every offending call site (set ``raise_on_violation=False``
    to inspect the report instead — used by the tests of the guard
    itself).  Guards nest; the innermost report records the block's
    transfers and each active guard sees its own compile delta.
    """
    _ensure_listener()
    if use_transfer_guard is None:
        use_transfer_guard = jax.default_backend() != "cpu"
    st = _state()
    report = GuardReport(compiles_at_enter=compile_count())
    prev = st["guard"]
    st["guard"] = report
    _patches.install()
    ctx = (jax.transfer_guard("disallow") if use_transfer_guard
           else contextlib.nullcontext())
    try:
        with ctx:
            yield report
    finally:
        _patches.remove()
        st["guard"] = prev
        if prev is not None:
            # surface the inner block's traffic to the enclosing guard
            prev.violations.extend(report.violations)
            prev.annotated.extend(report.annotated)
    if report.violations and raise_on_violation:
        raise HotPathViolation(
            "un-annotated host transfer(s) on a guarded hot path "
            "(route intended transfers through "
            "repro.core.guard.annotated_transfer):\n  " +
            "\n  ".join(report.violations))
