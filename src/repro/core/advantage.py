"""TreePO advantage estimation (paper §2.3, Eq. 2/5/6/7).

A rollout group for one query is ``G`` trajectories (tree leaves).  The tree
structure is encoded as an *ancestor matrix* ``anc`` of shape (G, J): the
node id of trajectory i's ancestor at depth j (depth 0 = the root query, so
``anc[:, 0]`` is constant).  Trajectories shorter than J repeat their leaf id
(a singleton chain below the leaf — consistent with Eq. 4's nesting).

Variants (paper names in quotes):
  grpo                  Eq. 2  — classic group-mean/std baseline
  treepo                Eq. 5  — plain mean over depth subgroups ("averaging"),
                                 the adopted method
  treepo_size_weighted  Eq. 6  — |G_j|-weighted aggregation (ablation: worse)
  treepo_subgroup_reject Eq. 7 — zero out degenerate subgroups
                                 (std == 0) ("naive rejection": harmful)
  treepo_no_root                — drop the j=0 root-group term (ablation:
                                 comparable)

All return a per-trajectory advantage (G,); token-level = broadcast over
the trajectory's tokens (Eq. 1 applies it at every t).

The paper's "global and local" mixing decomposes as: *local* = the
per-depth subgroup baselines above (each trajectory is centered against
the mean reward of every subtree it belongs to), *global* = the
REINFORCE++ variance normalization across all response tokens of the
whole batch (``global_normalize``).  Since PR 3 the global half runs
on device inside the jitted update — the trainer broadcasts the (N,)
per-trajectory advantages over the derived response mask and normalizes
there (``repro.rl.update``; the sequence-packed layout derives the
broadcast from its per-segment tables first).

Batched dispatch: :func:`batch_treepo_advantage` is ONE jitted call over
the whole (Q, G) batch.  Ragged groups are handled by a validity ``mask``
plus sentinel ancestor ids on padded slots (each padded trajectory is a
singleton subgroup with a unique negative id — see
``repro.core.tree.batch_group_tensors`` — so it cannot contaminate any
real subgroup's mean/std); masked entries are zeroed on output and
excluded from the global normalization.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def grpo_advantage(rewards: jnp.ndarray, eps: float = 1e-6,
                   mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Eq. 2: (R - mean) / std within the group.  rewards: (G,).

    ``mask`` (G,) restricts the group statistics to valid entries (ragged
    batched groups); masked entries return 0.
    """
    if mask is None:
        mean = rewards.mean()
        std = rewards.std()
        return (rewards - mean) / (std + eps)
    n = jnp.maximum(mask.sum(), 1.0)
    mean = (rewards * mask).sum() / n
    var = (((rewards - mean) ** 2) * mask).sum() / n
    return (rewards - mean) / (jnp.sqrt(var) + eps) * mask


def _subgroup_means(rewards: jnp.ndarray, anc: jnp.ndarray) -> jnp.ndarray:
    """Per-(trajectory, depth) mean reward of the trajectory's subgroup.

    rewards: (G,); anc: (G, J) int ancestor ids (unique per node within the
    tree).  Returns (G, J): mean reward over {i' : anc[i', j] == anc[i, j]}.
    """
    G, J = anc.shape

    def per_depth(ids):
        # ids: (G,) node ids at one depth.  segment-sum by dense relabeling.
        same = ids[:, None] == ids[None, :]          # (G, G)
        cnt = same.sum(axis=1).astype(jnp.float32)
        s = (same * rewards[None, :]).sum(axis=1)
        return s / jnp.maximum(cnt, 1.0)

    return jax.vmap(per_depth, in_axes=1, out_axes=1)(anc)


def _subgroup_stds(rewards: jnp.ndarray, anc: jnp.ndarray) -> jnp.ndarray:
    """Per-(trajectory, depth) std of rewards within the subgroup."""
    def per_depth(ids):
        same = ids[:, None] == ids[None, :]
        cnt = jnp.maximum(same.sum(axis=1).astype(jnp.float32), 1.0)
        mean = (same * rewards[None, :]).sum(axis=1) / cnt
        var = (same * (rewards[None, :] - mean[:, None]) ** 2).sum(axis=1) / cnt
        return jnp.sqrt(var)

    return jax.vmap(per_depth, in_axes=1, out_axes=1)(anc)


def subgroup_sizes(anc: jnp.ndarray) -> jnp.ndarray:
    """|G_j| for each (trajectory, depth): (G, J) float."""
    def per_depth(ids):
        return (ids[:, None] == ids[None, :]).sum(axis=1).astype(jnp.float32)

    return jax.vmap(per_depth, in_axes=1, out_axes=1)(anc)


def treepo_advantage(
    rewards: jnp.ndarray,
    anc: jnp.ndarray,
    *,
    variant: str = "treepo",
    eps: float = 1e-6,
) -> jnp.ndarray:
    """Tree-based advantage for one query group.

    rewards: (G,) terminal rewards; anc: (G, J) ancestor ids.
    Returns (G,) advantages.  Eq. 5 (variant="treepo"):
        Â_i = (1/J) Σ_j Â_{i,j} / std({Â_{i,j}}_j)
    with Â_{i,j} = R_i − mean(R over G_j); the denominator std runs over
    trajectory i's own per-depth terms.  Eq. 7
    (variant="treepo_subgroup_reject") zeroes degenerate subgroups
    (std(G_j) == 0) out of BOTH the numerator aggregation and that
    denominator std — the rejection removes the depth term from the
    whole estimator, not just the average (PR 3 regression fix).

    Batched path: :func:`batch_treepo_advantage` vmaps this over (Q, G)
    with sentinel ancestor ids on padded slots; prefer it in hot paths —
    no per-tree dispatches.
    """
    G, J = anc.shape
    means = _subgroup_means(rewards, anc)        # (G, J)
    adv_j = rewards[:, None] - means             # (G, J) = Â_{i,·,j}

    std_weights = None
    if variant == "treepo_no_root":
        adv_j = adv_j[:, 1:]
        weights = jnp.ones_like(adv_j)
    elif variant == "treepo_size_weighted":
        weights = subgroup_sizes(anc)            # Eq. 6: |G_j| weights
    elif variant == "treepo_subgroup_reject":
        stds = _subgroup_stds(rewards, anc)      # Eq. 7: drop degenerate G_j
        weights = (stds > eps).astype(jnp.float32)
        # Eq. 7 rejects a degenerate subgroup from the whole estimator:
        # the std in the denominator runs over the KEPT per-depth terms
        # only, matching the paper's ablation definition
        std_weights = weights
    elif variant == "treepo":
        weights = jnp.ones_like(adv_j)           # Eq. 5: plain averaging
    else:
        raise ValueError(f"unknown variant {variant!r}")

    wsum = jnp.maximum(weights.sum(axis=1), eps)
    agg = (weights * adv_j).sum(axis=1) / wsum
    # normalize by std over the per-depth advantages of this trajectory
    # (the paper's std({Â_{i,t,j}}^{J-1}) denominator term)
    if std_weights is None:
        std_weights = jnp.ones_like(adv_j)
    n = jnp.maximum(std_weights.sum(axis=1), 1.0)
    m = (std_weights * adv_j).sum(axis=1) / n
    var = (std_weights * (adv_j - m[:, None]) ** 2).sum(axis=1) / n
    per_traj_std = jnp.sqrt(var)
    return agg / (per_traj_std + eps)


def global_normalize(adv: jnp.ndarray, mask: jnp.ndarray,
                     eps: float = 1e-6) -> jnp.ndarray:
    """REINFORCE++ global variance normalization over the whole batch.

    adv: any shape; mask: same shape (1 = valid token).  Normalizes by
    masked batch std (mean is *not* re-subtracted: subgroup baselines
    already centered the estimate).
    """
    denom = jnp.maximum(mask.sum(), 1.0)
    mean = (adv * mask).sum() / denom
    var = (((adv - mean) ** 2) * mask).sum() / denom
    return adv * jax.lax.rsqrt(var + eps)


def query_keep_mask(rewards: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """DAPO dynamic-sampling constraint (Eq. 1 s.t. / Eq. 5 s.t.):
    keep a query only if its group rewards are not all-equal.

    rewards: (Q, G) -> (Q,) bool.
    """
    return rewards.std(axis=1) > eps


@functools.partial(jax.jit, static_argnames=("variant", "use_global_norm"))
def _batch_advantage_jit(rewards: jnp.ndarray, anc: jnp.ndarray,
                         mask: jnp.ndarray, variant: str,
                         use_global_norm: bool, eps: float) -> jnp.ndarray:
    if variant == "grpo":
        adv = jax.vmap(
            lambda r, m: grpo_advantage(r, eps=eps, mask=m))(rewards, mask)
    else:
        adv = jax.vmap(
            lambda r, a: treepo_advantage(r, a, variant=variant, eps=eps)
        )(rewards, anc)
        adv = adv * mask
    if use_global_norm and variant != "grpo":
        adv = global_normalize(adv, mask, eps)
    return adv


def batch_treepo_advantage(rewards: jnp.ndarray, anc: jnp.ndarray,
                           mask: Optional[jnp.ndarray] = None,
                           *, variant: str = "treepo",
                           use_global_norm: bool = True,
                           eps: float = 1e-6) -> jnp.ndarray:
    """One jitted dispatch over the whole batch of queries.

    rewards (Q, G), anc (Q, G, J), mask (Q, G) validity -> (Q, G).
    mask=None means every slot is a real trajectory.  Padded slots must
    carry unique sentinel ancestor ids (``batch_group_tensors``) so the
    dense equality kernels see them as singleton subgroups.
    """
    if mask is None:
        mask = jnp.ones(rewards.shape, jnp.float32)
    return _batch_advantage_jit(jnp.asarray(rewards), jnp.asarray(anc),
                                jnp.asarray(mask, jnp.float32), variant,
                                use_global_norm, eps)
