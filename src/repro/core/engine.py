"""Segment-synchronous tree-decoding engine (the paper's inference engine,
TPU-native).

vLLM's continuous batching schedules per token; XLA wants fixed shapes, so
TreePO's own *fixed-length segment* abstraction becomes the scheduling
quantum (DESIGN.md §2): the host re-batches paths only at segment
boundaries, and one jitted ``segment_decode`` call generates ``l`` tokens
for a power-of-two bucket of active paths against the shared paged KV pool.

Branch = block-table copy (+ copy-on-write of at most one partial page);
KV data of shared prefixes is stored once (the paper's KV amortization).
Recurrent state (Mamba conv/ssm, RWKV wkv/shift) is slot-indexed and copied
on fork — it is a running reduction, not a prefix.

The per-segment inner loop is device-resident end to end:

* **Attention decode** runs through the paged Pallas kernels — by default
  the pipelined fused-pool generation (GQA: ``kops.fused_paged_attention``
  over a head-interleaved ``[K0,V0,...]`` pool; MLA:
  ``kops.mla_fused_paged_attention`` over ``[ckv|k_rope]`` latent pages),
  which multi-buffers its own page DMAs so the copy of page i+1 overlaps
  the scoring of page i; ``fused_kv=False`` selects the legacy split-pool
  kernels (``kops.paged_attention`` / ``kops.mla_paged_attention``) as the
  parity oracle.  Block-table indirection is resolved in scalar prefetch
  either way, never as a dense HBM gather.
* **Fork divergence is sampled on device**: full-vocab boundary logits stay
  in a device buffer keyed by (buffer, row) on each path, and a branching
  round draws all of its divergence tokens in one jitted ``fork_sample``
  dispatch.  Steady-state host transfer per decode round is the (R, l)
  segment token/logprob matrices plus (R,) pending scalars — never (R, V)
  logits.  ``EnginePath.last_logits`` remains as an opt-in debug fetch.
* **Fork application is batched**: a round's COW page copies and recurrent
  slot copies go through ``PagedKVState.apply_forks`` as one jitted
  multi-layer dispatch.

Device functions are cached per static shape bucket:
  prefill  (Q, Sp)      — flash-attention forward, paged KV write-out,
                          returns last-position logits (kept on device).
  decode   (R, l)       — lax.scan over l tokens; paged attention per attn
                          layer; on-device temperature/top-p sampling.
  serve    (R, l)       — decode variant for the continuous-batching
                          scheduler: per-step forced tokens (chunked
                          prompt prefill mixed into the decode dispatch)
                          and per-row position-derived sampling keys, so
                          a request's stream is bitwise independent of
                          how arrivals were batched around it.

The device half (params, KV pools, jitted-fn caches) lives on
:class:`ModelRunner`; :class:`TreeEngine` layers path scheduling policy
(allocation, forks, preemption, pressure) on top — the SGL-JAX-style
Scheduler / ModelRunner split.  ``repro.core.scheduler`` drives the
runner's serve functions directly for continuous batching.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TreeConfig
from repro.core import faults
from repro.core.guard import annotated_transfer
from repro.kernels import ops as kops
from repro.kv.cache import OutOfPages, PagedKVState, bucket_pow2
from repro.kv.layout import fuse_mla, interleave_kv
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    embed,
    mlp,
    rmsnorm,
    sinusoidal_positions,
    unembed,
)


# ---------------------------------------------------------------------------
# path handle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EnginePath:
    """Device-side identity of one search path."""

    table: List[int]                  # page ids (prefix-shared, refcounted)
    slot: int                         # recurrent-state slot (-1 if none)
    qslot: int                        # cross-KV slot (-1 if none)
    position: int                     # tokens whose KV is materialized
    pending_token: int                # sampled, not yet fed
    pending_logprob: float
    logits_buf: Optional[jnp.ndarray] = None  # (Rb, V) device boundary
    logits_row: int = 0                       # logits, shared per round
    released: bool = False
    numeric_bad: bool = False         # non-finite divergence draw detected
                                      # (quarantined by the sampler)

    @property
    def last_logits(self) -> Optional[np.ndarray]:
        """Opt-in DEBUG fetch of this path's (V,) boundary logits.

        The decode/fork hot path never calls this — divergence tokens are
        sampled on device from ``logits_buf`` — but tests and external
        tooling can still pull the full distribution to the host.  This
        transfer is outside the engine's ``EngineStats.host_bytes``
        accounting (a path has no engine reference to report to).
        """
        if self.logits_buf is None:
            return None
        return np.asarray(self.logits_buf[self.logits_row],
                          dtype=np.float32)


@dataclasses.dataclass
class SegmentResult:
    tokens: List[int]
    logprobs: List[float]
    seg_logprob: float                # mean logprob (heuristic signal)
    finite: bool = True               # False -> non-finite logprobs pulled
                                      # for this row; quarantine the path


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0           # model-processed prompt tokens
    decode_tokens: int = 0            # model-processed generated tokens
    segments: int = 0
    forks: int = 0
    cow_pages: int = 0
    replay_tokens: int = 0            # fallback re-prefill cost
    peak_pages: int = 0
    host_bytes: int = 0               # device->host transfer in the
                                      # decode/fork loop (tokens, logprobs,
                                      # pending scalars); debug
                                      # last_logits fetches are NOT counted
    fork_dispatches: int = 0          # jitted fork-sample/apply calls
    # fault-tolerance counters (docs/robustness.md)
    preempted_paths: int = 0          # active paths retracted under pressure
    regenerated_paths: int = 0        # preempted paths replayed back in
    quarantined_paths: int = 0        # paths with non-finite logits/logprobs
    pressure_events: int = 0          # alloc failures absorbed by the
                                      # preemption callback + retry

    @property
    def model_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens + self.replay_tokens


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def _top_p_mask(logits: jnp.ndarray, top_p: float) -> jnp.ndarray:
    """Mask logits outside the top-p nucleus. logits: (..., V)."""
    sort_idx = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, sort_idx, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep_sorted = (cum - probs) < top_p       # always keeps the argmax
    inv = jnp.argsort(sort_idx, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, -1e30)


def sample_tokens(key, logits, temperature: float, top_p: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits: (R, V) -> (tokens (R,), logprobs (R,)) under the sampling
    distribution (temperature-scaled, pre-top-p renormalized)."""
    lg = logits.astype(jnp.float32) / max(temperature, 1e-6)
    lg_samp = _top_p_mask(lg, top_p) if top_p < 1.0 else lg
    tok = jax.random.categorical(key, lg_samp, axis=-1)
    logp = jax.nn.log_softmax(lg, axis=-1)
    lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), lp


@functools.partial(jax.jit, static_argnames=("temperature", "top_p"))
def fork_sample(logits_rows: jnp.ndarray, rows: jnp.ndarray, key, *,
                temperature: float, top_p: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched on-device fork divergence sampling.

    Gathers the requested boundary-logit rows from a round's (Rb, V) device
    buffer and draws one token (+ its logprob) per fork in a single
    dispatch — replacing the old one-numpy-sample-per-fork host loop.
    logits_rows: (Rb, V) f32; rows: (F,) int32 row indices (padded rows are
    sampled and discarded by the caller).
    """
    return sample_tokens(key, logits_rows[rows], temperature, top_p)


def sample_rows(keys: jnp.ndarray, logits: jnp.ndarray,
                temperature: float, top_p: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row keyed variant of :func:`sample_tokens` for the serve loop.

    keys: (R, 2) raw uint32 PRNG keys, one per row — each derived from
    (request key, absolute position), so row i's draw depends only on its
    own request identity, position and logits, never on which other
    requests happened to share the batch.  logits: (R, V).
    """
    lg = logits.astype(jnp.float32) / max(temperature, 1e-6)
    lg_samp = _top_p_mask(lg, top_p) if top_p < 1.0 else lg
    tok = jax.vmap(jax.random.categorical)(keys, lg_samp)
    logp = jax.nn.log_softmax(lg, axis=-1)
    lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), lp


def sample_token_host(rng: np.random.Generator, logits: np.ndarray,
                      temperature: float, top_p: float
                      ) -> Tuple[int, float]:
    """Host-side mirror of ``sample_tokens`` — kept as a distribution
    oracle for tests/debugging; the engine itself samples on device."""
    lg = logits.astype(np.float64) / max(temperature, 1e-6)
    lg = lg - lg.max()
    if top_p < 1.0:
        order = np.argsort(-lg)
        p = np.exp(lg[order])
        p /= p.sum()
        cum = np.cumsum(p)
        cut = np.searchsorted(cum, top_p) + 1
        mask = np.full_like(lg, -np.inf)
        mask[order[:cut]] = lg[order[:cut]]
        lg_samp = mask
    else:
        lg_samp = lg
    p = np.exp(lg_samp - lg_samp.max())
    p /= p.sum()
    tok = int(rng.choice(len(p), p=p))
    logp_all = lg - np.log(np.exp(lg).sum())
    return tok, float(logp_all[tok])


# the single jit-shape bucketing policy, shared with kv.cache's pad buckets
_bucket = bucket_pow2


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class TreeEngine:
    """Paged tree-decoding engine for one model replica.

    Scheduling-policy half of the Scheduler / ModelRunner split: owns path
    lifecycle (alloc/fork/preempt/release), pressure handling and host
    packing; every device concern (params, KV pools, jitted prefill /
    decode / serve functions) is delegated to ``self.runner``.
    """

    def __init__(self, params, cfg: ModelConfig, tree_cfg: TreeConfig, *,
                 num_pages: int = 4096, page_size: Optional[int] = None,
                 max_slots: int = 256, max_queries: int = 64,
                 max_prompt_len: int = 512, enc_len: int = 64,
                 dtype=jnp.float32, seed: int = 0, fused_kv: bool = True,
                 paged_num_buffers: int = 2):
        self.runner = ModelRunner(
            params, cfg, tree_cfg, num_pages=num_pages,
            page_size=page_size, max_slots=max_slots,
            max_queries=max_queries, max_prompt_len=max_prompt_len,
            enc_len=enc_len, dtype=dtype, seed=seed, fused_kv=fused_kv,
            paged_num_buffers=paged_num_buffers)
        self.stats = EngineStats()
        # pressure callback: called with the page deficit when an alloc
        # fails; frees pages (retracting retained/active KV) and the
        # allocation is retried once (docs/robustness.md)
        self._pressure_cb: Optional[Any] = None
        # optional cross-request radix cache (repro.kv.radix): its LRU
        # leaves are evicted before the preemption callback is consulted
        self._radix: Optional[Any] = None

    # -- runner delegation ----------------------------------------------------
    # Device state lives on the ModelRunner; these keep the engine's public
    # surface (and the sampler/trainer/tests that use it) unchanged.

    @property
    def params(self):
        return self.runner.params

    @params.setter
    def params(self, value) -> None:
        self.runner.params = value

    @property
    def cfg(self) -> ModelConfig:
        return self.runner.cfg

    @property
    def tree_cfg(self) -> TreeConfig:
        return self.runner.tree_cfg

    @property
    def kv(self) -> PagedKVState:
        return self.runner.kv

    @property
    def cross_pool(self):
        return self.runner.cross_pool

    @cross_pool.setter
    def cross_pool(self, value) -> None:
        self.runner.cross_pool = value

    @property
    def qslot_alloc(self) -> List[int]:
        return self.runner.qslot_alloc

    @property
    def page_size(self) -> int:
        return self.runner.page_size

    @property
    def max_prompt_len(self) -> int:
        return self.runner.max_prompt_len

    @property
    def dtype(self):
        return self.runner.dtype

    @property
    def MP(self) -> int:
        return self.runner.MP

    @property
    def fused_kv(self) -> bool:
        return self.runner.fused_kv

    @property
    def garbage_page(self) -> int:
        return self.runner.garbage_page

    @property
    def scratch_slot(self) -> int:
        return self.runner.scratch_slot

    @property
    def has_rec(self) -> bool:
        return self.runner.has_rec

    @property
    def has_cross(self) -> bool:
        return self.runner.has_cross

    @property
    def enc_len(self) -> int:
        return self.runner.enc_len

    @property
    def n_prefix(self) -> int:
        return self.runner.n_prefix

    @property
    def _decode_fns(self):
        return self.runner._decode_fns

    @property
    def _prefill_fns(self):
        return self.runner._prefill_fns

    # -- misc -----------------------------------------------------------------

    def _next_key(self):
        return self.runner.next_key()

    def _track_pages(self):
        self.stats.peak_pages = max(self.stats.peak_pages,
                                    self.kv.pool.pages_in_use)

    # -- pressure / preemption ----------------------------------------------

    def attach_radix(self, radix) -> None:
        """Register a cross-request radix cache (``repro.kv.radix``).

        Under pressure the engine evicts the cache's LRU leaves before
        consulting the preemption callback, and :meth:`pressure` discounts
        cache-held pages that could be reclaimed on demand — a pool kept
        warm by the cache must not throttle branching or trigger
        preemption while eviction can still satisfy the demand."""
        self._radix = radix

    def pressure(self) -> float:
        """Effective KV pool occupancy in [0, 1] — the branching throttle
        signal.  Evictable radix-cache pages count as free."""
        pool = self.kv.pool
        if self._radix is None:
            return pool.watermark
        held = pool.pages_in_use - self._radix.evictable_pages
        return max(held, 0) / max(pool.num_pages, 1)

    def pages_free(self) -> int:
        return self.kv.pool.num_free

    @property
    def can_restore(self) -> bool:
        """True when a preempted path is exactly reconstructable from its
        token history alone — no retained modality prefix (VLM) and no
        cross-KV conditioning (enc-dec), both of which live outside the
        path's tokens."""
        return self.n_prefix == 0 and not self.has_cross

    def set_pressure_cb(self, cb) -> None:
        """Install ``cb(page_deficit) -> pages_freed``, consulted when a
        page/slot alloc fails before the allocation is retried once."""
        self._pressure_cb = cb

    def _alloc_page(self) -> int:
        try:
            return self.kv.pool.alloc()
        except OutOfPages:
            self.stats.pressure_events += 1
            # eviction before preemption: cache-held prefix KV is
            # recomputable, a live path's working set is not — reclaim
            # LRU radix leaves first and only then consult the
            # preemption callback
            if self._radix is not None and self._radix.evict(1) > 0:
                try:
                    return self.kv.pool.alloc()
                except OutOfPages:
                    pass
            if self._pressure_cb is not None:
                self._pressure_cb(1)
            # retry once: an injected fault's spec is consumed and a real
            # exhaustion either recovered via the callback or re-raises
            # with full allocator diagnostics
            try:
                return self.kv.pool.alloc()
            except OutOfPages as exc:
                if self._radix is not None:
                    exc.annotate(radix_pages=self._radix.cached_pages,
                                 radix_evictable=self._radix.evictable_pages)
                raise

    def _alloc_slot(self) -> int:
        try:
            return self.kv.slots.alloc()
        except OutOfPages:
            self.stats.pressure_events += 1
            if self._pressure_cb is not None:
                self._pressure_cb(1)
            return self.kv.slots.alloc()

    def preempt_path(self, path: EnginePath) -> int:
        """Retract an active path under KV pressure: free its pages/slot
        and report how many pages actually returned to the pool (shared
        prefix pages stay refcounted by siblings).  The caller keeps the
        host-side tokens and re-admits via :meth:`restore_path` when the
        budget recovers."""
        before = self.kv.pool.pages_in_use
        self.release_path(path)
        self.stats.preempted_paths += 1
        return before - self.kv.pool.pages_in_use

    def restore_path(self, tokens: List[int]) -> EnginePath:
        """Regenerate a preempted path by replaying its full token history
        (prompt + generated) into fresh pages — the `_replay_prefix`
        machinery DFS fallback already uses.  Returns a path with boundary
        logits and a freshly drawn pending token (the preempted pending
        draw is not retained; the continuation re-samples)."""
        assert self.can_restore, \
            "restore_path needs a token-complete context (no modality " \
            "prefix / cross-KV)"
        position = self.n_prefix + len(tokens)
        child = EnginePath(table=[], slot=-1, qslot=-1, position=position,
                           pending_token=0, pending_logprob=0.0)
        try:
            self._ensure_capacity(child, position)
            if self.has_rec:
                child.slot = self._alloc_slot()
            self._replay_prefix(child, list(tokens))
        except Exception:
            # an OutOfPages mid-restore must not leak the pages already
            # replayed into the half-built path (R5 kv-lifecycle)
            self.release_partial([child])
            raise
        self.sample_pending_batch([child])
        self.stats.regenerated_paths += 1
        return child

    # -- page / slot lifecycle --------------------------------------------------

    def _ensure_capacity(self, path: EnginePath, new_len: int) -> None:
        needed = -(-new_len // self.page_size)
        while len(path.table) < needed:
            path.table.append(self._alloc_page())
        self._track_pages()

    def _cow_pages(self, path: EnginePath, page_idxs
                   ) -> Tuple[List[int], List[int]]:
        """Host bookkeeping for COW of ``path.table[idx]`` for each idx:
        allocate private pages and retarget the table, returning the
        (src, dst) copy pairs for a later batched ``kv.apply_forks``.
        Sources stay refcounted by their other owners, so deferring the
        device copy to the end of the round is safe."""
        src_pages: List[int] = []
        dst_pages: List[int] = []
        for page_idx in page_idxs:
            src = path.table[page_idx]
            if self.kv.pool.refcount[src] == 1:
                continue  # already private
            dst = self._alloc_page()
            self.kv.pool.release(src)
            path.table[page_idx] = dst
            src_pages.append(src)
            dst_pages.append(dst)
            self.stats.cow_pages += 1
        self._track_pages()
        return src_pages, dst_pages

    # -- on-device fork sampling ------------------------------------------------

    def sample_pending_batch(self, paths: Sequence[EnginePath]) -> None:
        """Resample every path's pending token from its device-side
        boundary logits — one ``fork_sample`` dispatch per distinct logits
        buffer (a branching round shares a single buffer, so normally one).
        Only (F,) tokens + logprobs cross to the host."""
        groups: Dict[int, Tuple[jnp.ndarray, List[EnginePath]]] = {}
        for p in paths:
            assert p.logits_buf is not None, \
                "path has no boundary logits to sample from"
            groups.setdefault(id(p.logits_buf),
                              (p.logits_buf, []))[1].append(p)
        tc = self.tree_cfg
        for buf, ps in groups.values():
            F = len(ps)
            Fb = _bucket(F)
            rows = annotated_transfer(
                np.asarray([p.logits_row for p in ps] + [0] * (Fb - F),
                           np.int32),
                to="device", reason="fork-rows")
            tok, lp = fork_sample(buf, rows, self._next_key(),
                                  temperature=tc.temperature,
                                  top_p=tc.top_p)
            # one batched pull for the round's divergence draws
            tok, lp = annotated_transfer((tok, lp), reason="fork-draws")
            self.stats.host_bytes += tok.nbytes + lp.nbytes
            self.stats.fork_dispatches += 1
            lp = faults.corrupt_array("engine.fork_logprobs", lp)
            for j, p in enumerate(ps):
                p.pending_token = int(tok[j])
                p.pending_logprob = float(lp[j])
                # non-finite divergence draw: the boundary logits are
                # poisoned — mark for quarantine instead of decoding on
                if not np.isfinite(lp[j]):
                    p.numeric_bad = True
                    self.stats.quarantined_paths += 1

    def release_path(self, path: EnginePath) -> None:
        if path.released:
            return
        self.kv.release_table(path.table)
        path.table = []
        if path.slot >= 0:
            self.kv.slots.release(path.slot)
            path.slot = -1
        # drop the boundary-logits reference: a released path must not pin
        # its round's (Rb, V) device buffer for the rollout's lifetime
        path.logits_buf = None
        path.released = True

    def release_qslot(self, qslot: int) -> None:
        if qslot >= 0:
            self.qslot_alloc.append(qslot)

    def release_partial(self, paths: Sequence[EnginePath]) -> None:
        """Error-path cleanup for a partially constructed batch: when an
        ``OutOfPages`` (or a fault-injection kill point) unwinds mid-
        construction, every page/slot the batch acquired so far goes
        back to the pool.  Safe on half-built paths — empty tables,
        unset slots and already-released paths are all no-ops."""
        for path in paths:
            self.release_path(path)
        self._track_pages()

    # -- prefill ------------------------------------------------------------------

    def prefill_queries(self, prompts: List[List[int]],
                        prefix_embeds: Optional[np.ndarray] = None,
                        enc_frames: Optional[np.ndarray] = None
                        ) -> List[EnginePath]:
        """Prefill each query once (the tree root's shared KV).

        prompts: per-query token lists.  prefix_embeds: (Q, P, d) VLM stub;
        enc_frames: (Q, S_enc, d_enc) audio stub.  Returns one root
        EnginePath per query with ``pending_token`` already sampled.
        """
        Q = len(prompts)
        n_pre = self.n_prefix
        max_sp = max(len(p) for p in prompts)
        Sp = _bucket(max_sp, 8)
        Qb = _bucket(Q)
        tokens = np.zeros((Qb, Sp), np.int32)
        lengths = np.zeros((Qb,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, : len(p)] = p
            lengths[i] = len(p) + n_pre
        lengths[Q:] = 1  # dummies

        paths: List[EnginePath] = []
        tables = np.zeros((Qb, self.MP), np.int32)
        slots = np.zeros((Qb,), np.int32)
        qslots = np.zeros((Qb,), np.int32)
        try:
            for i in range(Qb):
                if i < Q:
                    pth = EnginePath(table=[], slot=-1, qslot=-1,
                                     position=int(lengths[i]),
                                     pending_token=0, pending_logprob=0.0)
                    # appended before the allocs so the error path below
                    # can clean up the half-built root too
                    paths.append(pth)
                    self._ensure_capacity(pth, int(lengths[i]))
                    if self.has_rec:
                        pth.slot = self._alloc_slot()
                    if self.has_cross or n_pre:
                        pth.qslot = self.qslot_alloc.pop() \
                            if self.has_cross else -1
                    row = pth.table + [-1] * (self.MP - len(pth.table))
                    tables[i] = row
                    slots[i] = pth.slot if pth.slot >= 0 \
                        else self.scratch_slot
                    qslots[i] = max(pth.qslot, 0)
                else:
                    tables[i, 0] = self.garbage_page
                    tables[i, 1:] = -1
                    slots[i] = max(self.scratch_slot, 0)
        except Exception:
            # OutOfPages mid-batch: return the roots built so far (pages,
            # slots *and* popped query slots) before propagating
            for pth in paths:
                if pth.qslot >= 0:
                    self.release_qslot(pth.qslot)
            self.release_partial(paths)
            raise

        if prefix_embeds is not None:
            pe = np.zeros((Qb,) + prefix_embeds.shape[1:],
                          prefix_embeds.dtype)
            pe[:Q] = prefix_embeds
            prefix_embeds = pe
        if enc_frames is not None:
            ef = np.zeros((Qb,) + enc_frames.shape[1:], enc_frames.dtype)
            ef[:Q] = enc_frames
            enc_frames = ef

        fn = self.runner.get_prefill_fn(Qb, Sp, prefix_embeds is not None,
                                        enc_frames is not None)
        # one batched h2d push for the whole prefill pack
        (tokens, lengths, tables, slots, qslots, prefix_embeds,
         enc_frames) = annotated_transfer(
            (tokens, lengths, tables, slots, qslots, prefix_embeds,
             enc_frames), to="device", reason="prefill-pack")
        pools, rec, cross, logits = fn(
            self.params, self.kv.kv_pools, self.kv.rec_state,
            self.cross_pool, tokens, lengths,
            tables, slots, qslots,
            prefix_embeds, enc_frames)
        self.kv.kv_pools = pools
        self.kv.rec_state = rec
        self.cross_pool = cross
        logits = logits.astype(jnp.float32)   # stays on device
        for i, pth in enumerate(paths):
            pth.logits_buf = logits
            pth.logits_row = i
        self.sample_pending_batch(paths)
        self.stats.prefill_tokens += sum(len(p) + n_pre for p in prompts)
        return paths

    # -- fork ----------------------------------------------------------------------

    def fork_paths(self, parents: Sequence[EnginePath], *,
                   resample: bool = True) -> List[EnginePath]:
        """Batched branch of a whole round: for every parent (repeat a
        parent to fork it several times) share every full page, COW the
        partial tail page, and copy recurrent state — all fork copies land
        in ONE jitted ``kv.apply_forks`` dispatch — then draw every child's
        divergence token in one on-device ``fork_sample`` dispatch."""
        children: List[EnginePath] = []
        page_src: List[int] = []
        page_dst: List[int] = []
        slot_src: List[int] = []
        slot_dst: List[int] = []
        try:
            for parent in parents:
                child = EnginePath(
                    table=self.kv.fork_table(parent.table),
                    slot=-1, qslot=parent.qslot, position=parent.position,
                    pending_token=parent.pending_token,
                    pending_logprob=parent.pending_logprob,
                    logits_buf=parent.logits_buf,
                    logits_row=parent.logits_row)
                # appended before the COW/slot allocs so the error path
                # below also releases the partially built child
                children.append(child)
                if parent.position % self.page_size != 0:
                    ps, pd = self._cow_pages(
                        child, [parent.position // self.page_size])
                    page_src += ps
                    page_dst += pd
                if parent.slot >= 0:
                    child.slot = self._alloc_slot()
                    slot_src.append(parent.slot)
                    slot_dst.append(child.slot)
        except Exception:
            # OutOfPages mid-round: drop every fork_table retain / COW
            # page / slot the round acquired so far, then propagate —
            # the parents stay intact (their refcounts were only added to)
            self.release_partial(children)
            raise
        if page_src or slot_src:
            try:
                self.kv.apply_forks(page_src, page_dst, slot_src, slot_dst)
            except Exception:
                # a failure inside the fork-copy dispatch (injected kill
                # point, device OOM) leaves the pools unrebound — no child
                # can hold copied K with stale V — but the round's fresh
                # COW pages / slots / table retains must go back, or the
                # half-applied fork leaks them for the rollout's lifetime
                self.release_partial(children)
                raise
            self.stats.fork_dispatches += 1
        self.stats.forks += len(children)
        self._track_pages()
        if resample:
            self.sample_pending_batch(
                [c for c in children if c.logits_buf is not None])
        return children

    def fork_path(self, parent: EnginePath, *, resample: bool = True
                  ) -> EnginePath:
        """Single-parent convenience wrapper over :meth:`fork_paths`."""
        return self.fork_paths([parent], resample=resample)[0]

    def fork_from_prefix(self, src: EnginePath, prefix_position: int,
                         replay_tokens: Optional[List[int]] = None
                         ) -> EnginePath:
        """Fallback fork: a new path whose context is the first
        ``prefix_position`` tokens of ``src``.

        Attention-only archs: share the prefix pages and run one re-feed
        decode step to recover boundary logits.  Recurrent archs: replay
        the prefix through prefill into COW'd pages (state cannot be
        recovered from the KV pool) — ``replay_tokens`` must then hold the
        full token sequence (prompt + generated prefix).
        """
        n_pages = -(-prefix_position // self.page_size)
        child = EnginePath(
            table=self.kv.fork_table(src.table[:n_pages]),
            slot=-1, qslot=src.qslot, position=prefix_position,
            pending_token=0, pending_logprob=0.0)
        try:
            self._fork_from_prefix_arm(child, prefix_position,
                                       replay_tokens)
        except Exception:
            # OutOfPages mid-fallback-fork: the shared-prefix retains and
            # any COW pages / slot must go back before propagating
            self.release_partial([child])
            raise
        self.sample_pending_batch([child])
        self.stats.forks += 1
        return child

    def _fork_from_prefix_arm(self, child: EnginePath,
                              prefix_position: int,
                              replay_tokens: Optional[List[int]]) -> None:
        if self.has_rec:
            assert replay_tokens is not None and \
                len(replay_tokens) >= prefix_position - self.n_prefix, \
                "fork_from_prefix on a recurrent arch needs the full " \
                "prompt+prefix token sequence in replay_tokens"
            child.slot = self._alloc_slot()
            # replay rewrites every position it will ever read, so COW here
            # is bookkeeping only: retarget the table to fresh pages and
            # skip the device copy of bytes the prefill immediately clobbers
            self._cow_pages(child, range(len(child.table)))
            self._replay_prefix(child, replay_tokens[: prefix_position
                                                     - self.n_prefix])
        else:
            assert replay_tokens is not None and \
                len(replay_tokens) >= prefix_position - self.n_prefix, \
                "fork_from_prefix on an attention arch needs replay_tokens" \
                " to re-feed the boundary token (got None / too short)"
            # COW the page holding the boundary token (position-1): _refeed
            # rewrites its KV, and prefill/decode reduction orders differ at
            # the ULP level — writing into a still-shared page would perturb
            # the source path's siblings.  Covers both the misaligned tail
            # and the page-aligned case (where the boundary token is the
            # last row of the final shared page).
            ps, pd = self._cow_pages(
                child, [(prefix_position - 1) // self.page_size])
            if ps:
                self.kv.apply_forks(ps, pd)
                self.stats.fork_dispatches += 1
            self._refeed(child, replay_tokens[prefix_position
                                              - self.n_prefix - 1])

    def _replay_prefix(self, child: EnginePath, tokens: List[int]) -> None:
        """Recurrent-arch fallback: prefill the prefix into the child's
        (COW'd) pages + slot; leaves boundary logits on the child."""
        Sp = _bucket(len(tokens), 8)
        toks = np.zeros((1, Sp), np.int32)
        toks[0, : len(tokens)] = tokens
        lengths = np.asarray([len(tokens) + self.n_prefix], np.int32)
        tables = np.full((1, self.MP), -1, np.int32)
        tables[0, : len(child.table)] = child.table
        slots = np.asarray([child.slot if child.slot >= 0
                            else self.scratch_slot], np.int32)
        qslots = np.asarray([max(child.qslot, 0)], np.int32)
        fn = self.runner.get_prefill_fn(1, Sp, False, False)
        toks, lengths, tables, slots, qslots = annotated_transfer(
            (toks, lengths, tables, slots, qslots), to="device",
            reason="replay-pack")
        pools, rec, cross, logits = fn(
            self.params, self.kv.kv_pools, self.kv.rec_state,
            self.cross_pool, toks, lengths, tables, slots, qslots,
            None, None)
        self.kv.kv_pools, self.kv.rec_state = pools, rec
        child.logits_buf = logits.astype(jnp.float32)   # stays on device
        child.logits_row = 0
        self.stats.replay_tokens += len(tokens)

    def _refeed(self, child: EnginePath, last_token: int) -> None:
        """Attention-arch fallback: one decode step re-feeding the final
        prefix token (identical KV values — benign write) to recover the
        boundary logits."""
        child.position -= 1
        child.pending_token = int(last_token)
        child.pending_logprob = 0.0
        # decode_segments(seg_len=1) rewrites the (identical) KV of the
        # re-fed token and leaves the boundary logits on the child.
        self.decode_segments([child], seg_len=1)
        self.stats.replay_tokens += 1

    # -- segment decode ----------------------------------------------------------

    def decode_segments(self, paths: List[EnginePath],
                        seg_len: Optional[int] = None
                        ) -> List[SegmentResult]:
        """Generate one ``l``-token segment for every path (batched)."""
        l = seg_len or self.tree_cfg.segment_len
        R = len(paths)
        if R == 0:
            return []
        Rb = _bucket(R)
        tok0 = np.zeros((Rb,), np.int32)
        lp0 = np.zeros((Rb,), np.float32)
        pos0 = np.zeros((Rb,), np.int32)
        tables = np.full((Rb, self.MP), -1, np.int32)
        slots = np.full((Rb,), max(self.scratch_slot, 0), np.int32)
        qslots = np.zeros((Rb,), np.int32)
        for i, p in enumerate(paths):
            self._ensure_capacity(p, p.position + l)
            tok0[i] = p.pending_token
            lp0[i] = p.pending_logprob
            pos0[i] = p.position
            tables[i, : len(p.table)] = p.table
            if p.slot >= 0:
                slots[i] = p.slot
            qslots[i] = max(p.qslot, 0)
        tables[R:, 0] = self.garbage_page

        fn = self.runner.get_decode_fn(Rb, l)
        tok0, lp0, pos0, tables, slots, qslots = annotated_transfer(
            (tok0, lp0, pos0, tables, slots, qslots), to="device",
            reason="decode-pack")
        pools, rec, toks, lps, pend_tok, pend_lp, last_logits = fn(
            self.params, self.kv.kv_pools, self.kv.rec_state,
            self.cross_pool, tok0, lp0, pos0, tables, slots,
            qslots, self._next_key())
        self.kv.kv_pools = pools
        self.kv.rec_state = rec
        # steady-state host transfer: O(R*l) tokens/logprobs + O(R) pending
        # scalars, pulled in ONE batched device_get.  The (Rb, V) boundary
        # logits stay on device — forks sample from them via fork_sample.
        toks, lps, pend_tok, pend_lp = annotated_transfer(
            (toks, lps, pend_tok, pend_lp), reason="decode-segment")
        self.stats.host_bytes += (toks.nbytes + lps.nbytes
                                  + pend_tok.nbytes + pend_lp.nbytes)
        lps = faults.corrupt_array("engine.decode_logprobs", lps)

        results = []
        for i, p in enumerate(paths):
            p.position += l
            p.pending_token = int(pend_tok[i])
            p.pending_logprob = float(pend_lp[i])
            p.logits_buf = last_logits
            p.logits_row = i
            seg_t = [int(t) for t in toks[i]]
            seg_l = [float(v) for v in lps[i]]
            # numeric quarantine: a non-finite logprob in this row means
            # the model emitted non-finite logits for this path — flag the
            # segment so the sampler retires the path instead of training
            # on poisoned signal (docs/robustness.md)
            finite = bool(np.isfinite(lps[i]).all()
                          and np.isfinite(pend_lp[i]))
            if not finite:
                self.stats.quarantined_paths += 1
            results.append(SegmentResult(
                tokens=seg_t, logprobs=seg_l,
                seg_logprob=float(np.mean(seg_l)), finite=finite))
        self.stats.decode_tokens += R * l
        self.stats.segments += R
        return results

    # -- cross-kv (whisper) -------------------------------------------------------

    # handled inside prefill via enc_frames; decode gathers by qslot.


# ---------------------------------------------------------------------------
# model runner: device state + jitted device functions
# ---------------------------------------------------------------------------

class ModelRunner:
    """Device-execution half of the Scheduler / ModelRunner split.

    Owns the params, the paged KV state, the cross-attention pools and the
    per-shape caches of jitted prefill / decode / serve functions.  It
    knows nothing about paths, forks or preemption — ``TreeEngine`` (tree
    rollouts) and ``repro.core.scheduler.Scheduler`` (continuous batching)
    are its two scheduling frontends.
    """

    def __init__(self, params, cfg: ModelConfig, tree_cfg: TreeConfig, *,
                 num_pages: int = 4096, page_size: Optional[int] = None,
                 max_slots: int = 256, max_queries: int = 64,
                 max_prompt_len: int = 512, enc_len: int = 64,
                 dtype=jnp.float32, seed: int = 0, fused_kv: bool = True,
                 paged_num_buffers: int = 2):
        self.params = params
        self.cfg = cfg
        self.tree_cfg = tree_cfg
        self.page_size = page_size or min(64, tree_cfg.segment_len)
        self.max_prompt_len = max_prompt_len
        self.dtype = dtype
        self.fused_kv = fused_kv
        # DMA ring depth of the pipelined paged kernels (bitwise-invariant
        # scheduling knob — benchmarks/profile_dma_compute.py sweeps it)
        self.paged_num_buffers = paged_num_buffers
        max_len = max_prompt_len + tree_cfg.max_response_len + enc_len
        self.MP = -(-max_len // self.page_size) + 1
        self.kv = PagedKVState(cfg, num_pages, self.page_size, max_slots,
                               dtype, fused_kv=fused_kv)
        # page 0 = garbage sink for padded-position writes; slot 0 = scratch
        self.garbage_page = self.kv.pool.alloc()
        assert self.garbage_page == 0
        self.scratch_slot = self.kv.slots.alloc() if self.kv.rec_state else -1
        self.has_rec = bool(self.kv.rec_state)
        self.has_cross = cfg.encoder is not None
        self.enc_len = enc_len
        self.cross_pool: Dict[int, Dict[str, jnp.ndarray]] = {}
        self.qslot_alloc: List[int] = list(range(max_queries - 1, -1, -1))
        if self.has_cross:
            hd = cfg.resolved_head_dim
            for i in range(cfg.num_layers):
                self.cross_pool[i] = {
                    "k": jnp.zeros((max_queries, enc_len, cfg.num_kv_heads,
                                    hd), dtype),
                    "v": jnp.zeros((max_queries, enc_len, cfg.num_kv_heads,
                                    hd), dtype),
                }
        self.n_prefix = (cfg.frontend.num_prefix_tokens
                         if cfg.frontend is not None
                         and cfg.frontend.kind == "vision" else 0)
        self._decode_fns: Dict[Tuple[int, int], Any] = {}
        self._prefill_fns: Dict[Tuple[int, int, bool, bool], Any] = {}
        self._serve_fns: Dict[Tuple[int, int], Any] = {}
        self._key = jax.random.PRNGKey(seed)

    def next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _window(self, layer_idx: int) -> int:
        if (self.cfg.sliding_window > 0
                and not self.cfg.is_global_attn_layer(layer_idx)):
            return self.cfg.sliding_window
        return 0

    # =================== jitted device functions =================================

    def get_prefill_fn(self, Q: int, Sp: int, has_prefix: bool,
                       has_frames: bool):
        key = (Q, Sp, has_prefix, has_frames)
        if key not in self._prefill_fns:
            self._prefill_fns[key] = self._build_prefill(Q, Sp)
        return self._prefill_fns[key]

    def _build_prefill(self, Q: int, Sp: int):
        cfg = self.cfg
        page = self.page_size
        n_pre = self.n_prefix
        pool_dtype = self.dtype
        window_of = self._window
        fused = self.fused_kv

        def prefill_fn(params, pools, rec, cross, tokens, lengths, tables,
                       slots, qslots, prefix_embeds, enc_frames):
            B = tokens.shape[0]
            x = embed(params["embed"], tokens)            # (Q,Sp,d)
            if prefix_embeds is not None and cfg.encoder is None:
                x = jnp.concatenate(
                    [prefix_embeds.astype(x.dtype), x], axis=1)
            S = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            mask = positions < lengths[:, None]            # (Q,S)
            pos_flat = jnp.arange(S)
            page_idx = pos_flat // page                    # (S,)
            offs = jnp.broadcast_to(pos_flat % page, (B, S))
            pids = jnp.where(mask, jnp.maximum(
                jnp.take_along_axis(
                    tables, jnp.broadcast_to(page_idx, (B, S)), axis=1), 0),
                0)

            enc_out = None
            if cfg.encoder is not None:
                from repro.models.model import encode
                enc_out = encode(params, cfg, enc_frames)
                x = x + sinusoidal_positions(S, cfg.d_model).astype(
                    x.dtype)[None]

            new_rec = dict(rec)
            new_pools = dict(pools)
            new_cross = dict(cross)
            last = lengths - 1
            for i, lp in enumerate(params["layers"]):
                kind = cfg.layer_kind(i)
                h = rmsnorm(lp["norm1"], x, cfg.norm_eps)
                if kind == "attn":
                    if cfg.attention_kind == "mla":
                        y, (ckv, k_rope) = attn.mla_forward(
                            lp["attn"], cfg, h, positions, i, return_kv=True)
                        if fused:
                            new_pools[i] = {
                                "kv": new_pools[i]["kv"].at[pids, offs].set(
                                    fuse_mla(ckv, k_rope)
                                    .astype(pool_dtype)),
                            }
                        else:
                            new_pools[i] = {
                                "ckv": new_pools[i]["ckv"]
                                .at[pids, offs].set(ckv.astype(pool_dtype)),
                                "k_rope": new_pools[i]["k_rope"]
                                .at[pids, offs].set(
                                    k_rope.astype(pool_dtype)),
                            }
                    else:
                        y, (k, v) = attn.gqa_forward(
                            lp["attn"], cfg, h, positions, i, return_kv=True)
                        if fused:
                            new_pools[i] = {
                                "kv": new_pools[i]["kv"].at[pids, offs].set(
                                    interleave_kv(k, v).astype(pool_dtype)),
                            }
                        else:
                            new_pools[i] = {
                                "k": new_pools[i]["k"].at[pids, offs].set(
                                    k.astype(pool_dtype)),
                                "v": new_pools[i]["v"].at[pids, offs].set(
                                    v.astype(pool_dtype)),
                            }
                elif kind == "mamba":
                    y, st = ssm.mamba_forward(lp["mamba"], cfg, h,
                                              mask=mask, last_idx=last)
                    new_rec[i] = {
                        "conv": new_rec[i]["conv"].at[slots].set(
                            st["conv"].astype(pool_dtype)),
                        "ssm": new_rec[i]["ssm"].at[slots].set(st["ssm"]),
                    }
                elif kind == "rwkv":
                    zero = {
                        "wkv": jnp.zeros(
                            (B, cfg.d_model // cfg.rwkv.head_dim,
                             cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                            jnp.float32),
                        "shift": jnp.zeros((B, cfg.d_model), x.dtype),
                    }
                    y, st = ssm.rwkv6_time_mix(lp["rwkv"], cfg, h, zero,
                                               mask=mask, last_idx=last)
                    new_rec[i] = dict(
                        new_rec[i],
                        wkv=new_rec[i]["wkv"].at[slots].set(st["wkv"]),
                        shift=new_rec[i]["shift"].at[slots].set(
                            st["shift"].astype(pool_dtype)))
                x = x + y
                if cfg.encoder is not None:
                    hc = rmsnorm(lp["norm_cross"], x, cfg.norm_eps)
                    k_c, v_c = attn.cross_attn_kv(lp["cross"], cfg, enc_out)
                    x = x + attn.cross_attn_forward(lp["cross"], cfg, hc,
                                                    k_c, v_c)
                    new_cross[i] = {
                        "k": new_cross[i]["k"].at[qslots].set(
                            k_c.astype(pool_dtype)),
                        "v": new_cross[i]["v"].at[qslots].set(
                            v_c.astype(pool_dtype)),
                    }
                h = rmsnorm(lp["norm2"], x, cfg.norm_eps)
                if kind == "rwkv":
                    y, sh = ssm.rwkv6_channel_mix(
                        lp["ffn"], h, jnp.zeros((B, cfg.d_model), h.dtype),
                        last_idx=last)
                    new_rec[i] = dict(
                        new_rec[i],
                        shift_ffn=new_rec[i]["shift_ffn"].at[slots].set(
                            sh.astype(pool_dtype)))
                elif "ffn_moe" in lp:
                    y, _ = moe_mod.moe_forward(lp["ffn_moe"], cfg, h,
                                               cfg.act)
                else:
                    y = mlp(lp["ffn"], h, cfg.act)
                x = x + y
            x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
            x_last = x[jnp.arange(B), last]
            logits = unembed(params["embed"], x_last, cfg.tie_embeddings)
            return new_pools, new_rec, new_cross, logits

        return jax.jit(prefill_fn)

    def get_decode_fn(self, R: int, l: int):
        key = (R, l)
        if key not in self._decode_fns:
            self._decode_fns[key] = self._build_decode(R, l)
        return self._decode_fns[key]

    def get_serve_fn(self, R: int, l: int):
        key = (R, l)
        if key not in self._serve_fns:
            self._serve_fns[key] = self._build_serve(R, l)
        return self._serve_fns[key]

    def _make_token_forward(self, R: int):
        """One decoding step for a (R,)-row batch, shared by the tree
        decode and the continuous-batching serve scan bodies: embed the
        incoming token, write its KV into the block-table page, run every
        layer through the paged kernels, return the next-token logits."""
        cfg = self.cfg
        page = self.page_size
        pool_dtype = self.dtype
        window_of = self._window
        has_cross = self.has_cross
        fused = self.fused_kv
        nbuf = self.paged_num_buffers

        def mla_paged_attn(lp_attn, q_nope, q_rope, pools_i, tables,
                           lengths):
            """Absorbed MLA decode via the paged Pallas kernel: absorb W_uk
            into the query, attend over the latent pages named by the block
            table (scalar-prefetch indirection — no dense (R, MP*page, r)
            gather), then up-project the latent aggregate with W_uv."""
            m = cfg.mla
            H = cfg.num_heads
            w_uk = lp_attn["w_uk"].reshape(m.kv_lora_rank, H,
                                           m.qk_nope_head_dim)
            q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                               w_uk.astype(jnp.float32))
            if fused:
                o_lat = kops.mla_fused_paged_attention(
                    q_lat, q_rope.astype(jnp.float32), pools_i["kv"],
                    tables, lengths, page_size=page,
                    scale=1.0 / (m.qk_head_dim ** 0.5), num_buffers=nbuf)
            else:
                o_lat = kops.mla_paged_attention(
                    q_lat, q_rope.astype(jnp.float32), pools_i["ckv"],
                    pools_i["k_rope"], tables, lengths, page_size=page,
                    scale=1.0 / (m.qk_head_dim ** 0.5))
            w_uv = lp_attn["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
            o = jnp.einsum("bhr,rhd->bhd", o_lat,
                           w_uv.astype(jnp.float32))
            return o.reshape(o.shape[0], -1)

        def token_forward(params, pools, rec_g, cross_g, tok, pos, tables):
            x = embed(params["embed"], tok)            # (R,d)
            if cfg.encoder is not None:
                pe = sinusoidal_positions(
                    cfg.max_position_embeddings, cfg.d_model)
                x = x + pe[pos].astype(x.dtype)
            lengths = pos + 1
            pids = jnp.take_along_axis(
                jnp.maximum(tables, 0), (pos // page)[:, None],
                axis=1)[:, 0]
            offs = pos % page
            new_rec_g = dict(rec_g)
            new_pools = dict(pools)
            for i, lp_ in enumerate(params["layers"]):
                kind = cfg.layer_kind(i)
                h = rmsnorm(lp_["norm1"], x, cfg.norm_eps)
                if kind == "attn":
                    if cfg.attention_kind == "mla":
                        x1 = h[:, None, :]
                        q_nope, q_rope = attn._mla_q(
                            lp_["attn"], cfg, x1, pos[:, None])
                        q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]
                        ckv_t, kr_t = attn._mla_latents(
                            lp_["attn"], cfg, x1, pos[:, None])
                        pi = new_pools[i]
                        if fused:
                            pi = {
                                "kv": pi["kv"].at[pids, offs].set(
                                    fuse_mla(ckv_t[:, 0], kr_t[:, 0])
                                    .astype(pool_dtype)),
                            }
                        else:
                            pi = {
                                "ckv": pi["ckv"].at[pids, offs].set(
                                    ckv_t[:, 0].astype(pool_dtype)),
                                "k_rope": pi["k_rope"].at[pids, offs].set(
                                    kr_t[:, 0].astype(pool_dtype)),
                            }
                        new_pools[i] = pi
                        o = mla_paged_attn(lp_["attn"], q_nope, q_rope,
                                           pi, tables, lengths)
                        y = o.astype(x.dtype) @ lp_["attn"]["w_o"]
                    else:
                        x1 = h[:, None, :]
                        q, k, v = attn._gqa_qkv(lp_["attn"], cfg, x1,
                                                pos[:, None])
                        q, k, v = q[:, 0], k[:, 0], v[:, 0]
                        pi = new_pools[i]
                        if fused:
                            pi = {
                                "kv": pi["kv"].at[pids, offs].set(
                                    interleave_kv(k, v).astype(pool_dtype)),
                            }
                            new_pools[i] = pi
                            o = kops.fused_paged_attention(
                                q, pi["kv"], tables, lengths,
                                page_size=page, window=window_of(i),
                                num_buffers=nbuf)
                        else:
                            pi = {
                                "k": pi["k"].at[pids, offs].set(
                                    k.astype(pool_dtype)),
                                "v": pi["v"].at[pids, offs].set(
                                    v.astype(pool_dtype)),
                            }
                            new_pools[i] = pi
                            o = kops.paged_attention(
                                q, pi["k"], pi["v"], tables, lengths,
                                page_size=page, window=window_of(i))
                        y = o.reshape(R, -1) @ lp_["attn"]["w_o"]
                elif kind == "mamba":
                    y1, st = ssm.mamba_forward(
                        lp_["mamba"], cfg, h[:, None, :], new_rec_g[i])
                    y = y1[:, 0]
                    new_rec_g[i] = {
                        "conv": st["conv"].astype(pool_dtype),
                        "ssm": st["ssm"]}
                elif kind == "rwkv":
                    st_in = {"wkv": new_rec_g[i]["wkv"],
                             "shift": new_rec_g[i]["shift"]}
                    y1, st = ssm.rwkv6_time_mix(
                        lp_["rwkv"], cfg, h[:, None, :], st_in)
                    y = y1[:, 0]
                    new_rec_g[i] = dict(
                        new_rec_g[i], wkv=st["wkv"],
                        shift=st["shift"].astype(pool_dtype))
                x = x + y
                if has_cross:
                    hc = rmsnorm(lp_["norm_cross"], x, cfg.norm_eps)
                    hd = cfg.resolved_head_dim
                    qc = (hc @ lp_["cross"]["w_q"]).reshape(
                        R, cfg.num_heads, hd)
                    ck, cv = cross_g[i]["k"], cross_g[i]["v"]
                    enc_lengths = jnp.full((R,), ck.shape[1], jnp.int32)
                    oc = kops.decode_attention(qc, ck, cv, enc_lengths)
                    x = x + oc.reshape(R, -1) @ lp_["cross"]["w_o"]
                h = rmsnorm(lp_["norm2"], x, cfg.norm_eps)
                if kind == "rwkv":
                    y1, sh = ssm.rwkv6_channel_mix(
                        lp_["ffn"], h[:, None, :],
                        new_rec_g[i]["shift_ffn"])
                    y = y1[:, 0]
                    new_rec_g[i] = dict(
                        new_rec_g[i],
                        shift_ffn=sh.astype(pool_dtype))
                elif "ffn_moe" in lp_:
                    y, _ = moe_mod.moe_forward(
                        lp_["ffn_moe"], cfg, h[:, None, :], cfg.act)
                    y = y[:, 0]
                else:
                    y = mlp(lp_["ffn"], h, cfg.act)
                x = x + y
            xf = rmsnorm(params["norm_f"], x, cfg.norm_eps)
            logits = unembed(params["embed"], xf, cfg.tie_embeddings)
            return new_pools, new_rec_g, logits

        return token_forward

    def _build_decode(self, R: int, l: int):
        cfg = self.cfg
        tc = self.tree_cfg
        has_cross = self.has_cross
        token_forward = self._make_token_forward(R)

        def decode_fn(params, pools, rec, cross, tok0, lp0, pos0, tables,
                      slots, qslots, key):
            rec_g = {i: {k: v[slots] for k, v in st.items()}
                     for i, st in rec.items()}
            cross_g = None
            if has_cross:
                cross_g = {i: {k: v[qslots] for k, v in st.items()}
                           for i, st in cross.items()}

            def step(carry, key_t):
                pools, rec_g, tok, lp, pos, _ = carry
                new_pools, new_rec_g, logits = token_forward(
                    params, pools, rec_g, cross_g, tok, pos, tables)
                tnext, lpnext = sample_tokens(key_t, logits,
                                              tc.temperature, tc.top_p)
                new_carry = (new_pools, new_rec_g, tnext, lpnext, pos + 1,
                             logits.astype(jnp.float32))
                return new_carry, (tok, lp)

            keys = jax.random.split(key, l)
            V = (params["embed"]["embedding"].shape[0]
                 if cfg.tie_embeddings else
                 params["embed"]["lm_head"].shape[1])
            init = (pools, rec_g, tok0, lp0, pos0,
                    jnp.zeros((R, V), jnp.float32))
            (pools_f, rec_gf, pend_tok, pend_lp, _, last_logits), outs = \
                jax.lax.scan(step, init, keys)
            toks, lps = outs                                # (l, R)
            new_rec = {i: {k: rec[i][k].at[slots].set(rec_gf[i][k])
                           for k in rec[i]}
                       for i in rec}
            return (pools_f, new_rec, toks.T, lps.T, pend_tok, pend_lp,
                    last_logits)

        return jax.jit(decode_fn)

    def _build_serve(self, R: int, l: int):
        """Continuous-batching serve segment: like decode, but each scan
        step can *force* the consumed token (chunked prompt prefill mixed
        into the decode dispatch) and sampling is keyed per row by
        (request key, absolute position) instead of a per-round split —
        a request's token stream is a pure function of its own identity
        and context, bitwise independent of batch composition, arrival
        interleaving and preemption/replay.

        The logprob reported for a *forced* token is its log-probability
        under the previous step's distribution (exact mid-round; at the
        round's first step the carried logits are zeros, but callers only
        consume logprobs of generated tokens, whose values are exact).
        """
        assert not self.has_cross and self.n_prefix == 0, \
            "serve loop needs token-complete contexts (no cross-KV / " \
            "modality prefix)"
        cfg = self.cfg
        tc = self.tree_cfg
        token_forward = self._make_token_forward(R)

        def serve_fn(params, pools, rec, tok0, lp0, pos0, tables, slots,
                     forced_tok, forced_on, row_keys):
            rec_g = {i: {k: v[slots] for k, v in st.items()}
                     for i, st in rec.items()}

            def step(carry, xs):
                pools, rec_g, tok, lp, pos, prev_logits = carry
                f_tok, f_on = xs
                tok = jnp.where(f_on, f_tok, tok)
                prev_lsm = jax.nn.log_softmax(
                    prev_logits / max(tc.temperature, 1e-6), axis=-1)
                lp = jnp.where(
                    f_on,
                    jnp.take_along_axis(prev_lsm, f_tok[:, None],
                                        axis=-1)[:, 0],
                    lp)
                new_pools, new_rec_g, logits = token_forward(
                    params, pools, rec_g, None, tok, pos, tables)
                keys = jax.vmap(jax.random.fold_in)(row_keys, pos + 1)
                tnext, lpnext = sample_rows(keys, logits,
                                            tc.temperature, tc.top_p)
                new_carry = (new_pools, new_rec_g, tnext, lpnext, pos + 1,
                             logits.astype(jnp.float32))
                return new_carry, (tok, lp)

            V = (params["embed"]["embedding"].shape[0]
                 if cfg.tie_embeddings else
                 params["embed"]["lm_head"].shape[1])
            init = (pools, rec_g, tok0, lp0, pos0,
                    jnp.zeros((R, V), jnp.float32))
            (pools_f, rec_gf, pend_tok, pend_lp, _, _), outs = \
                jax.lax.scan(step, init,
                             (forced_tok.T, forced_on.T))
            toks, lps = outs                                # (l, R)
            new_rec = {i: {k: rec[i][k].at[slots].set(rec_gf[i][k])
                           for k in rec[i]}
                       for i in rec}
            return pools_f, new_rec, toks.T, lps.T, pend_tok, pend_lp

        return jax.jit(serve_fn)
