"""DFS fallback (paper §2.2): when a query has no active paths but fewer
than ``w`` trajectories, stem new branches from the *finished* paths.

Selection rule (paper): only stopped paths containing a formatted answer or
ending with [EOS] are candidates; the fork point is a random segment
boundary (token-aligned — §4.2(4) shows misaligned fallback is harmful, so
alignment is an invariant here, not an option).
"""
from __future__ import annotations

import random
from typing import List, Optional, Tuple

from repro.core.tree import Path, QueryTree, Status


def pick_fallback(tree: QueryTree, rng: random.Random
                  ) -> Optional[Tuple[Path, int]]:
    """Returns (source leaf path, fork depth j) or None.

    Fork depth j in [1, depth-1]: the new branch replays the first j
    segments of the source and diverges from there (DFS-style: prefer
    deeper fork points to preserve long-reasoning capability).
    """
    cands = tree.fallback_candidates()
    if not cands:
        return None
    src = rng.choice(cands)
    # seg_bounds includes the leading 0; forking at the final boundary would
    # replay the whole (answered) trajectory, so j stops one short.
    max_j = len(src.seg_bounds) - 2
    if max_j < 1:
        return None
    # DFS bias: sample depth weighted toward the deep end
    depths = list(range(1, max_j + 1))
    weights = [j for j in depths]
    total = sum(weights)
    r = rng.random() * total
    acc = 0.0
    j = depths[-1]
    for d_, w_ in zip(depths, weights):
        acc += w_
        if r <= acc:
            j = d_
            break
    return src, j
