"""Debug-armed runtime twin of the R5/R6 static verification rules.

``tools/analyze/verify.py`` proves page/slot lifecycle and path-FSM
invariants *statically*, per function, over the CFG.  This module
asserts the same invariants *dynamically*, across functions, by
shadowing the allocators under a context manager — the two halves
cross-validate: a static false negative (interprocedural leak, alias
the CFG cannot see) trips the runtime tracker under the fault-injection
suite, and a runtime miss (path never exercised) is exactly what the
static rules cover.  Same pattern as ``repro.core.guard`` is to R1/R2.

Usage (tests; zero overhead when not armed)::

    with lifecycle_guard() as rep:
        ... engine / sampler code ...
    assert rep.violations == []

Tracked invariants:

* **refcount conservation** — the shadow refcount (replayed from
  alloc/retain/release events) must equal the pool's at every step;
  release-at-zero (double release) and retain-after-free are violations
  at the offending call site.
* **free-list integrity** — no duplicates, never a page with a live
  refcount, ``pages_in_use`` consistent with the shadow.
* **slot double-release** — ``SlotAllocator`` keeps no refcounts, so a
  double release silently hands the same slot to two paths; the shadow
  free-set catches it.
* **path FSM** — released paths must not be forked from, decoded, or
  preempted again; ``preempt_path`` must leave the path released.
"""
from __future__ import annotations

import dataclasses
import threading
import traceback
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.kv.cache import PagePool, SlotAllocator

__all__ = ["LifecycleViolation", "LifecycleReport", "lifecycle_guard"]


class LifecycleViolation(RuntimeError):
    """A dynamic refcount / path-FSM invariant was broken."""


_tls = threading.local()


def _state() -> dict:
    if not hasattr(_tls, "state"):
        _tls.state = {"guard": None}
    return _tls.state


def _call_site() -> str:
    """First stack frame outside this module / the allocators."""
    for frame in reversed(traceback.extract_stack()):
        fn = frame.filename
        if "lifecycle.py" in fn or "kv/cache.py" in fn \
                or "traceback" in fn:
            continue
        return f"{fn}:{frame.lineno} in {frame.name}"
    return "<unknown>"


@dataclasses.dataclass
class LifecycleReport:
    violations: List[str] = dataclasses.field(default_factory=list)
    page_allocs: int = 0
    page_retains: int = 0
    page_releases: int = 0
    slot_allocs: int = 0
    slot_releases: int = 0
    forks: int = 0
    preempts: int = 0
    restores: int = 0
    pages_peak: int = 0


class _Tracker:
    def __init__(self, report: LifecycleReport):
        self.report = report
        # per-pool shadow refcounts, snapshotted on first sight (pools
        # created before arming already hold e.g. the garbage page)
        self.pages: Dict[int, Dict[int, int]] = {}
        self.slot_free: Dict[int, Set[int]] = {}
        self.released_paths: Set[int] = set()

    def violate(self, msg: str) -> None:
        self.report.violations.append(f"{msg} at {_call_site()}")

    # -- page pool ----------------------------------------------------------

    def _shadow(self, pool: PagePool) -> Dict[int, int]:
        shadow = self.pages.get(id(pool))
        if shadow is None:
            shadow = {p: int(c) for p, c in enumerate(pool.refcount) if c}
            self.pages[id(pool)] = shadow
        return shadow

    def _check_pool(self, pool: PagePool, shadow: Dict[int, int]) -> None:
        for pid, c in shadow.items():
            actual = int(pool.refcount[pid])
            if actual != c:
                self.violate(f"refcount divergence: page {pid} shadow={c} "
                             f"pool={actual}")
        in_use = sum(1 for c in shadow.values() if c > 0)
        if in_use != pool.pages_in_use:
            self.violate(f"pages_in_use divergence: shadow={in_use} "
                         f"pool={pool.pages_in_use}")
        free = pool.free
        if len(set(free)) != len(free):
            self.violate("free-list contains duplicate pages")
        for pid in free:
            if shadow.get(pid, 0) > 0:
                self.violate(f"page {pid} is on the free list with a "
                             "live refcount")

    def page_alloc(self, pool: PagePool, pid: int) -> None:
        first = id(pool) not in self.pages
        shadow = self._shadow(pool)
        if first:
            # first sight happens *after* orig() ran, so the snapshot
            # already reflects this alloc — nothing to pre-check
            shadow[pid] = int(pool.refcount[pid])
        elif shadow.get(pid, 0) > 0:
            self.violate(f"alloc returned in-use page {pid}")
            shadow[pid] = 1
        else:
            shadow[pid] = 1
        self.report.page_allocs += 1
        self.report.pages_peak = max(self.report.pages_peak,
                                     pool.pages_in_use)
        self._check_pool(pool, shadow)

    # retain/release split into a pre-check (report the bad call before
    # the pool's own assert aborts) and a post-sync (mutate the shadow
    # only after the pool really changed, so a raise leaves it exact)

    def pre_page_retain(self, pool: PagePool, pid: int) -> None:
        shadow = self._shadow(pool)
        if shadow.get(pid, 0) <= 0:
            self.violate(f"retain of page {pid} with no live refcount")

    def post_page_retain(self, pool: PagePool, pid: int) -> None:
        shadow = self._shadow(pool)
        shadow[pid] = shadow.get(pid, 0) + 1
        self.report.page_retains += 1
        self._check_pool(pool, shadow)

    def pre_page_release(self, pool: PagePool, pid: int) -> None:
        shadow = self._shadow(pool)
        if shadow.get(pid, 0) <= 0:
            self.violate(f"release of page {pid} at refcount 0 "
                         "(double release)")

    def post_page_release(self, pool: PagePool, pid: int) -> None:
        shadow = self._shadow(pool)
        shadow[pid] = shadow.get(pid, 0) - 1
        self.report.page_releases += 1
        self._check_pool(pool, shadow)

    # -- slots --------------------------------------------------------------

    def _slot_shadow(self, alloc: SlotAllocator) -> Set[int]:
        shadow = self.slot_free.get(id(alloc))
        if shadow is None:
            shadow = set(alloc.free)
            self.slot_free[id(alloc)] = shadow
        return shadow

    def slot_alloc(self, alloc: SlotAllocator, slot: int) -> None:
        first = id(alloc) not in self.slot_free
        shadow = self._slot_shadow(alloc)
        if first:
            # snapshot taken post-pop: the slot is correctly absent
            pass
        elif slot not in shadow:
            self.violate(f"slot alloc returned in-use slot {slot}")
        shadow.discard(slot)
        self.report.slot_allocs += 1

    def slot_release(self, alloc: SlotAllocator, slot: int) -> None:
        shadow = self._slot_shadow(alloc)
        if slot in shadow:
            self.violate(f"double release of slot {slot} — the free "
                         "list now hands it to two paths")
        shadow.add(slot)
        self.report.slot_releases += 1

    # -- path FSM -----------------------------------------------------------

    def check_live(self, op: str, paths) -> None:
        for p in paths:
            if p is not None and getattr(p, "released", False):
                self.violate(f"{op} on a released path")

    def note_released(self, path) -> None:
        self.released_paths.add(id(path))


class _PatchSet:
    """Reversible class-level patches, refcounted for nesting."""

    def __init__(self):
        self.depth = 0
        self._saved: List[Tuple[object, str, object]] = []

    def _patch(self, owner, name: str, wrapper: Callable) -> None:
        orig = getattr(owner, name)
        self._saved.append((owner, name, orig))
        setattr(owner, name, wrapper(orig))

    def install(self) -> None:
        self.depth += 1
        if self.depth > 1:
            return
        from repro.core.engine import TreeEngine

        def tracker() -> Optional[_Tracker]:
            return _state()["guard"]

        def wrap_page_alloc(orig):
            def alloc(pool):
                pid = orig(pool)
                t = tracker()
                if t is not None:
                    t.page_alloc(pool, pid)
                return pid
            return alloc

        def wrap_page_retain(orig):
            def retain(pool, pid):
                t = tracker()
                if t is not None:
                    t.pre_page_retain(pool, pid)
                orig(pool, pid)
                if t is not None:
                    t.post_page_retain(pool, pid)
            return retain

        def wrap_page_release(orig):
            def release(pool, pid):
                t = tracker()
                if t is not None:
                    t.pre_page_release(pool, pid)
                orig(pool, pid)
                if t is not None:
                    t.post_page_release(pool, pid)
            return release

        def wrap_slot_alloc(orig):
            def alloc(slots):
                slot = orig(slots)
                t = tracker()
                if t is not None:
                    t.slot_alloc(slots, slot)
                return slot
            return alloc

        def wrap_slot_release(orig):
            def release(slots, slot):
                t = tracker()
                if t is not None:
                    t.slot_release(slots, slot)
                orig(slots, slot)
            return release

        def wrap_fork_paths(orig):
            def fork_paths(engine, parents, **kw):
                t = tracker()
                if t is not None:
                    t.check_live("fork_paths", parents)
                out = orig(engine, parents, **kw)
                if t is not None:
                    t.report.forks += len(out)
                return out
            return fork_paths

        def wrap_fork_from_prefix(orig):
            def fork_from_prefix(engine, src, *a, **kw):
                t = tracker()
                if t is not None:
                    t.check_live("fork_from_prefix", [src])
                return orig(engine, src, *a, **kw)
            return fork_from_prefix

        def wrap_decode_segments(orig):
            def decode_segments(engine, paths, *a, **kw):
                t = tracker()
                if t is not None:
                    t.check_live("decode_segments", paths)
                return orig(engine, paths, *a, **kw)
            return decode_segments

        def wrap_preempt_path(orig):
            def preempt_path(engine, path):
                t = tracker()
                if t is not None:
                    t.check_live("preempt_path", [path])
                freed = orig(engine, path)
                if t is not None:
                    t.report.preempts += 1
                    if not path.released:
                        t.violate("preempt_path left the path unreleased")
                    t.note_released(path)
                return freed
            return preempt_path

        def wrap_release_path(orig):
            def release_path(engine, path):
                t = tracker()
                already = path.released
                orig(engine, path)
                if t is not None and not already:
                    t.note_released(path)
            return release_path

        def wrap_restore_path(orig):
            def restore_path(engine, tokens):
                out = orig(engine, tokens)
                t = tracker()
                if t is not None:
                    t.report.restores += 1
                    if out.released:
                        t.violate("restore_path returned a released path")
                return out
            return restore_path

        self._patch(PagePool, "alloc", wrap_page_alloc)
        self._patch(PagePool, "retain", wrap_page_retain)
        self._patch(PagePool, "release", wrap_page_release)
        self._patch(SlotAllocator, "alloc", wrap_slot_alloc)
        self._patch(SlotAllocator, "release", wrap_slot_release)
        self._patch(TreeEngine, "fork_paths", wrap_fork_paths)
        self._patch(TreeEngine, "fork_from_prefix", wrap_fork_from_prefix)
        self._patch(TreeEngine, "decode_segments", wrap_decode_segments)
        self._patch(TreeEngine, "preempt_path", wrap_preempt_path)
        self._patch(TreeEngine, "release_path", wrap_release_path)
        self._patch(TreeEngine, "restore_path", wrap_restore_path)

    def remove(self) -> None:
        self.depth -= 1
        if self.depth > 0:
            return
        for owner, name, orig in reversed(self._saved):
            setattr(owner, name, orig)
        self._saved.clear()


_patches = _PatchSet()


@contextmanager
def lifecycle_guard(*, raise_on_violation: bool = True):
    """Arm the dynamic lifecycle tracker.  Nests; the inner guard's
    violations propagate into the enclosing one."""
    st = _state()
    prev = st["guard"]
    report = LifecycleReport()
    tracker = _Tracker(report)
    st["guard"] = tracker
    _patches.install()
    try:
        yield report
    finally:
        st["guard"] = prev
        _patches.remove()
        if prev is not None:
            prev.report.violations.extend(report.violations)
    if report.violations and raise_on_violation and prev is None:
        head = "\n  ".join(report.violations[:20])
        raise LifecycleViolation(
            f"{len(report.violations)} lifecycle violation(s):\n  {head}")
