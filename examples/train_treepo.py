"""End-to-end driver: RL-train a ~small model with TreePO for a few
hundred steps on synthetic verifiable math (deliverable b).

  PYTHONPATH=src python examples/train_treepo.py            # short demo
  PYTHONPATH=src python examples/train_treepo.py --steps 200 --bc-steps 300

The pipeline is the paper's: BC-warmed base -> tree rollout (segment
sampling, branching, fallback) -> boxed-answer reward -> dynamic-sampling
filter -> TreePO advantage -> DAPO-clipped token-level PG -> AdamW.
Checkpoints land in ./checkpoints/treepo (interval 50, as in the paper).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.base import TrainConfig, TreeConfig
from repro.rl.trainer import RLTrainer, TrainerMode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--bc-steps", type=int, default=120)
    ap.add_argument("--queries", type=int, default=2)
    ap.add_argument("--width", type=int, default=6)
    ap.add_argument("--ckpt-dir", default="checkpoints/treepo")
    ap.add_argument("--pack", action="store_true",
                    help="sequence-pack the update batches "
                         "(repro.rl.packing): several short "
                         "trajectories per row, fewer pad-token FLOPs")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    tree_cfg = TreeConfig(max_depth=5, segment_len=16,
                          max_width=args.width, branch_factor=2,
                          init_divergence_low=2, init_divergence_high=4,
                          temperature=0.9)
    train_cfg = TrainConfig(batch_size=args.queries,
                            group_size=args.width,
                            oversample_factor=2, max_resample_rounds=1,
                            learning_rate=5e-4, advantage_kind="treepo",
                            reward_shaping=0.1,
                            pack_sequences=args.pack)
    trainer = RLTrainer(cfg, train_cfg, tree_cfg, TrainerMode.TREEPO,
                        seed=0,
                        engine_kwargs=dict(num_pages=4096, page_size=16,
                                           max_slots=256, max_queries=64,
                                           max_prompt_len=256),
                        min_difficulty=1, max_difficulty=2)
    print(f"model: {cfg.name} ({cfg.num_params():,} params)")
    print("BC warmup (base-model stand-in)...")
    w = trainer.bc_warmup(steps=args.bc_steps, batch_size=8, lr=3e-3)
    print(f"  bc loss: {w['bc_loss']:.4f}")
    ev = trainer.evaluate(num_queries=8, k=4)
    print(f"  pre-RL: maj@4={ev['maj_acc']:.2f} pass={ev['pass_any']:.2f}")

    for i in range(args.steps):
        m = trainer.train_step(num_queries=args.queries,
                               progress=i / max(args.steps - 1, 1))
        print(f"step {m['step']:4d} "
              f"loss={m.get('loss', float('nan')):.4f} "
              f"reward={m['reward_mean']:.3f} "
              f"trajs={m['num_trajectories']:.0f} "
              f"len={m['response_len']:.0f} "
              f"pad={m.get('padded_token_fraction', 0.0):.2f} "
              f"entropy={m.get('entropy', float('nan')):.3f}",
              flush=True)
        if m["step"] % 50 == 0:
            save_checkpoint(args.ckpt_dir, m["step"],
                            {"params": trainer.params,
                             "opt": trainer.opt_state})
    ev = trainer.evaluate(num_queries=8, k=4)
    print(f"post-RL: maj@4={ev['maj_acc']:.2f} pass={ev['pass_any']:.2f}")
    save_checkpoint(args.ckpt_dir, trainer.step,
                    {"params": trainer.params, "opt": trainer.opt_state})
    print(f"checkpoint saved to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
