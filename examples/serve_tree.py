"""Batched tree-serving: best-of-N answer extraction over shared-prefix
trees (the inference-efficiency side of the paper, §4.1 / §4.5).

  PYTHONPATH=src python examples/serve_tree.py --requests 4 --width 8

Serves a batch of math queries; for each, samples a TreePO tree, scores
candidates by mean logprob, and returns majority + best answers — the
"free lunch of inference efficiency": the engine computes ~30-50% fewer
tokens than per-sample decoding at the same N.
"""
import argparse
import random
import sys
from collections import Counter

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_trees
from repro.data.reward import extract_boxed
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--width", type=int, default=6)
    ap.add_argument("--divergence", type=int, default=2,
                    help="tree divergence factor d (paper Fig. 9)")
    args = ap.parse_args()

    tok = ByteTokenizer()
    cfg = get_config(args.arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tree_cfg = TreeConfig(max_depth=4, segment_len=16,
                          max_width=args.width, branch_factor=2,
                          init_divergence_low=args.divergence,
                          init_divergence_high=args.divergence,
                          temperature=1.0)
    engine = TreeEngine(params, cfg, tree_cfg, num_pages=2048,
                        page_size=16, max_slots=128, max_queries=16,
                        max_prompt_len=256)

    gen = MathTaskGenerator(seed=7, min_difficulty=1, max_difficulty=2)
    samples = gen.batch(args.requests)
    prompts = [tok.encode(s.query, bos=True) for s in samples]
    trees, report = sample_trees(engine, prompts,
                                 [s.answer for s in samples],
                                 rng=random.Random(0))
    for tree, s in zip(trees, samples):
        cands = []
        for p in tree.finished:
            ans = extract_boxed(tok.decode(p.tokens))
            if ans is not None and p.logprobs:
                cands.append((ans, sum(p.logprobs) / len(p.logprobs)))
        maj = Counter(a for a, _ in cands).most_common(1)
        best = max(cands, key=lambda c: c[1]) if cands else None
        print(f"request {tree.query_idx}: {s.query[:60]}...")
        print(f"  target={s.answer!r} "
              f"majority={maj[0][0] if maj else None!r} "
              f"best-logprob={best[0] if best else None!r} "
              f"({len(cands)} candidates / {tree.num_trajectories} trajs)")

    s = engine.stats
    served = sum(len(p.tokens) + len(t.prompt_tokens)
                 for t in trees for p in t.finished)
    print(f"\nserved {report.num_trajectories} trajectories over "
          f"{args.requests} requests")
    print(f"computed {s.model_tokens} tokens for {served} served "
          f"({100 * (1 - s.model_tokens / max(served, 1)):.0f}% amortized)")


if __name__ == "__main__":
    main()
