"""Quickstart: sample a TreePO search tree and inspect its structure.

  PYTHONPATH=src python examples/quickstart.py [--arch yi-6b]

Builds a reduced (smoke) model of the chosen architecture, runs the
tree-based rollout (Algorithm 1) on two math queries, and prints the tree:
trajectories, shared prefixes, per-segment logprobs, and the engine's
KV-sharing accounting.
"""
import argparse
import random
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_trees
from repro.core.tree import ancestor_matrix
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--width", type=int, default=6)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--segment", type=int, default=16)
    args = ap.parse_args()

    tok = ByteTokenizer()
    cfg = get_config(args.arch, smoke=True)
    print(f"model: {cfg.name} ({cfg.num_params():,} params, "
          f"{cfg.arch_type})")
    params = init_params(jax.random.PRNGKey(0), cfg)

    tree_cfg = TreeConfig(
        max_depth=args.depth, segment_len=args.segment,
        max_width=args.width, branch_factor=2,
        init_divergence_low=2, init_divergence_high=4,  # "More Init Div."
        temperature=1.0)
    engine = TreeEngine(params, cfg, tree_cfg, num_pages=1024,
                        page_size=args.segment, max_slots=64,
                        max_queries=8, max_prompt_len=256)

    gen = MathTaskGenerator(seed=1, min_difficulty=1, max_difficulty=2)
    samples = gen.batch(2)
    prompts = [tok.encode(s.query, bos=True) for s in samples]
    targets = [s.answer for s in samples]
    print(f"\nquery 0: {samples[0].query}")

    trees, report = sample_trees(engine, prompts, targets,
                                 rng=random.Random(0))
    print(f"\nsampler report: {report}")
    for tree in trees:
        print(f"\n=== tree for query {tree.query_idx} "
              f"(init divergence {tree.init_div}) ===")
        anc = ancestor_matrix(tree.finished, tree_cfg.max_depth)
        for i, p in enumerate(tree.finished):
            chain = "->".join(str(n) for n in p.node_ids)
            text = tok.decode(p.tokens)[:40].replace("\n", " ")
            print(f"  traj {i}: {p.status.value:6s} ({p.finish_reason:10s})"
                  f" depth={p.depth} nodes=[{chain}]")
            print(f"           text: {text!r}")
        print(f"  ancestor matrix (subgroup ids per depth):\n{anc}")

    s = engine.stats
    print(f"\nengine accounting:")
    print(f"  prefill tokens : {s.prefill_tokens}")
    print(f"  decode tokens  : {s.decode_tokens}")
    print(f"  forks          : {s.forks} (copy-on-write pages: "
          f"{s.cow_pages})")
    print(f"  peak KV pages  : {s.peak_pages} "
          f"(page = {engine.page_size} tokens)")
    served = sum(len(p.tokens) + len(t.prompt_tokens)
                 for t in trees for p in t.finished)
    print(f"  tokens served  : {served} from {s.model_tokens} computed "
          f"-> {100 * (1 - s.model_tokens / served):.0f}% amortized by "
          f"the tree")


if __name__ == "__main__":
    main()
