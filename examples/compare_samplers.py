"""Side-by-side: sequential vs TreePO sampling on identical queries.

  PYTHONPATH=src python examples/compare_samplers.py

Reproduces the paper's core efficiency claim at demo scale: same model,
same queries, same width — the tree computes fewer tokens and finds the
same (or more diverse) answers.
"""
import argparse
import random
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_sequential, sample_trees
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params


def run_one(kind, params, cfg, tree_cfg, prompts, targets):
    engine = TreeEngine(params, cfg, tree_cfg, num_pages=2048,
                        page_size=16, max_slots=128, max_queries=16,
                        max_prompt_len=256, seed=0)
    fn = sample_sequential if kind == "sequential" else sample_trees
    trees, report = fn(engine, prompts, targets, rng=random.Random(0))
    served = sum(len(p.tokens) + len(t.prompt_tokens)
                 for t in trees for p in t.finished)
    s = engine.stats
    print(f"\n--- {kind} ---")
    print(f"  trajectories : {report.num_trajectories} "
          f"(leaves {report.num_leaves}, failed {report.num_failed}, "
          f"fallbacks {report.num_fallbacks})")
    print(f"  tokens served: {served}")
    print(f"  tokens done  : {s.model_tokens} "
          f"(prefill {s.prefill_tokens} + decode {s.decode_tokens} + "
          f"replay {s.replay_tokens})")
    print(f"  peak KV pages: {s.peak_pages}")
    return s.model_tokens, served


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-7b")
    ap.add_argument("--width", type=int, default=8)
    args = ap.parse_args()

    tok = ByteTokenizer()
    cfg = get_config(args.arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tree_cfg = TreeConfig(max_depth=4, segment_len=16,
                          max_width=args.width, branch_factor=2,
                          init_divergence_low=2, init_divergence_high=2,
                          temperature=0.9)
    gen = MathTaskGenerator(seed=3, min_difficulty=1, max_difficulty=2)
    samples = gen.batch(2)
    prompts = [tok.encode(s.query, bos=True) for s in samples]
    targets = [s.answer for s in samples]

    seq_tokens, seq_served = run_one("sequential", params, cfg, tree_cfg,
                                     prompts, targets)
    tree_tokens, _ = run_one("tree", params, cfg, tree_cfg, prompts,
                             targets)
    vanilla = seq_served  # paper baseline: no KV reuse at all
    print(f"\nGPU-hour proxy (model-processed tokens):")
    print(f"  vanilla (no sharing)  : {vanilla}")
    print(f"  sequential+prompt KV  : {seq_tokens} "
          f"({100 * (1 - seq_tokens / vanilla):.0f}% saved)")
    print(f"  TreePO tree           : {tree_tokens} "
          f"({100 * (1 - tree_tokens / vanilla):.0f}% saved)")


if __name__ == "__main__":
    main()
