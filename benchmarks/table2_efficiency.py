"""Table 2 — Sequential vs tree-based sampling efficiency.

Paper: TreePO cuts GPU hours 12-43% at matched width/budget.  Here the
GPU-hour proxy is *model-processed tokens* (every token the engine runs a
forward for, prefill + decode + fallback replay); the tree amortizes shared
prefixes so it processes strictly fewer tokens for the same returned
trajectories.  Branch budgets b in {2, 4, 8} mirror the paper's rows.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs.base import TreeConfig

from benchmarks.common import (fmt_row, make_model, make_prompts,
                               measure_rollout)


def run(quick: bool = True) -> List[dict]:
    cfg, params = make_model()
    n_queries = 2 if quick else 6
    width = 4 if quick else 8
    depth, seg = (4, 16) if quick else (6, 32)
    prompts, targets = make_prompts(n_queries, seed=1)
    rows = []

    seq_cfg = TreeConfig(max_depth=depth, segment_len=seg, max_width=width,
                         branch_factor=1, init_divergence_low=width,
                         init_divergence_high=width, fallback=False,
                         temperature=0.9)
    _, seq_cost = measure_rollout(params, cfg, seq_cfg, prompts, targets,
                                  sequential=True, seed=0)
    # the PAPER's baseline engine keeps a separate KV per rollout — it
    # recomputes every prompt+response token per trajectory:
    vanilla_tokens = seq_cost.trajectory_tokens
    rows.append(dict(sampler="vanilla (paper baseline)", b=0,
                     model_tokens=vanilla_tokens,
                     trajectories=seq_cost.trajectories,
                     sharing=0.0, wall_s=round(seq_cost.wall_s, 2),
                     saving_pct=0.0))
    rows.append(dict(sampler="seq+prompt-KV", b=0,
                     model_tokens=seq_cost.model_tokens,
                     trajectories=seq_cost.trajectories,
                     sharing=round(seq_cost.sharing_ratio, 3),
                     wall_s=round(seq_cost.wall_s, 2),
                     saving_pct=round(100 * (1 - seq_cost.model_tokens
                                             / max(vanilla_tokens, 1)), 1)))

    for b in (2, 4, 8):
        tree_cfg = TreeConfig(
            max_depth=depth, segment_len=seg, max_width=width,
            branch_factor=2, init_divergence_low=min(b, width),
            init_divergence_high=min(b, width), temperature=0.9)
        _, cost = measure_rollout(params, cfg, tree_cfg, prompts, targets,
                                  seed=0)
        saving = 100.0 * (1 - cost.model_tokens / max(vanilla_tokens, 1))
        rows.append(dict(sampler="tree", b=b,
                         model_tokens=cost.model_tokens,
                         trajectories=cost.trajectories,
                         sharing=round(cost.sharing_ratio, 3),
                         wall_s=round(cost.wall_s, 2),
                         saving_pct=round(saving, 1)))

    print("\n== Table 2: sampling cost (GPU-hour proxy = model tokens) ==")
    print(fmt_row(["sampler", "b", "model_tokens", "trajs", "sharing",
                   "wall_s", "saving%"], [24, 3, 13, 6, 8, 8, 8]))
    for r in rows:
        print(fmt_row([r["sampler"], r["b"], r["model_tokens"],
                       r["trajectories"], r["sharing"], r["wall_s"],
                       r["saving_pct"]], [24, 3, 13, 6, 8, 8, 8]))
    return rows


if __name__ == "__main__":
    run(quick=False)
