"""Training hot-path benchmark: device-residency of the update half.

The twin of ``decode_hotpath``: for each trainer mode (grpo /
grpo_tree / treepo) it rolls out once, then drives the SAME trees
through both training paths:

* **legacy** — per-tree unjitted advantage calls, dense (N, L) host
  pack (mask + token-broadcast advantages + host-side global norm),
  one jitted dispatch per ppo epoch;
* **new** — one jitted ``batch_treepo_advantage`` dispatch over the
  padded (Q, G, J) tensors recorded during sampling, compact pack
  ((N, L) tokens/logprobs + (N,) lengths/advantages; mask, broadcast
  and global norm derived on device), one jitted K-epoch ``lax.scan``
  update per (N, L) bucket with donated params/opt-state;
* **packed** — the new path plus sequence packing
  (``repro.rl.packing``): multiple short trajectories FFD-binned into
  each (N, L) row with (N, S) per-segment tables, segment-masked
  attention and per-segment RoPE resets derived on device.

Reported per mode: host-pack bytes per step, build (reward → advantage
→ pack) wall time, steady-state (post-compile) update wall time, a
``recompiles`` counter (XLA compilations observed during the
steady-state timing reps — the one-compile-per-bucket invariant says
0; counted via ``repro.core.guard.compile_delta``), and — for the
unpacked-vs-packed comparison — the padded-token fraction of the
(N, L) grid (the fwd/bwd FLOP waste packing exists to shrink).
Wall-clock on this container is relative, not TPU; the byte counts and
pad fractions are exact.  Emits ``results/BENCH_train.json``.

Besides the three qwen2.5-7b trainer modes, a ``treepo`` row per hybrid
arch (jamba / rwkv6; ``arch`` field) exercises the segment-reset packed
path the dense layout previously gated — the pad-fraction pair is
reported for the recurrent substrates too.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, warmed_trainer
from repro.configs.base import TrainConfig, TreeConfig
from repro.core.guard import compile_delta
from repro.rl.trainer import TrainerMode

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_train.json")

MODES = [TrainerMode.GRPO, TrainerMode.GRPO_TREE, TrainerMode.TREEPO]

# hybrid (SSM/RWKV) archs: tree mode only — the packed path the dense
# layout previously gated (segment-reset kernels)
HYBRID_ARCHS = ["jamba-v0.1-52b", "rwkv6-7b"]


def _cfgs(ppo_epochs: int):
    # deep/wide enough that early-stopped paths (EOS after the BC
    # warmup, repetition guard) coexist with max-depth survivors — the
    # mixed-depth length spread sequence packing exists to absorb
    tree_cfg = TreeConfig(max_depth=8, segment_len=32, max_width=8,
                          branch_factor=2, init_divergence_low=2,
                          init_divergence_high=2, temperature=0.9,
                          repetition_ngram=8, repetition_count=3)
    train_cfg = TrainConfig(batch_size=2, group_size=8,
                            oversample_factor=2, max_resample_rounds=0,
                            learning_rate=5e-4, reward_shaping=0.1,
                            ppo_epochs=ppo_epochs)
    return tree_cfg, train_cfg


def _snapshot(tr):
    return jax.tree.map(np.array, (tr.params, tr.opt_state))


def _restore(tr, snap):
    tr.params, tr.opt_state = jax.tree.map(jnp.asarray, snap)


def _time_best(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True, out_path: str = OUT_PATH) -> dict:
    n_queries = 2 if quick else 4
    ppo_epochs = 2
    bc_steps = 60      # enough BC that EOS early-stops appear (the
    reps = 3 if quick else 5   # length spread the packed mode measures)
    rows = []
    print("\n== Train hot path: batched advantage + scanned K-epoch "
          "update vs legacy host loop ==")
    hdr = ["mode", "N", "L", "pack_B", "legacy_B", "build_s",
           "lg_build_s", "upd_s", "lg_upd_s"]
    widths = [14, 5, 5, 9, 9, 9, 10, 9, 9]
    print(fmt_row(hdr, widths))
    cases = [(mode, "qwen2.5-7b") for mode in MODES]
    cases += [(TrainerMode.TREEPO, a)
              for a in (HYBRID_ARCHS[:1] if quick else HYBRID_ARCHS)]
    for mode, arch in cases:
        tree_cfg, train_cfg = _cfgs(ppo_epochs)
        tr = warmed_trainer(mode, arch=arch, tree_cfg=tree_cfg,
                            train_cfg=train_cfg, bc_steps=bc_steps,
                            seed=3)
        trees, _ = tr.rollout(n_queries)
        if not any(t.finished for t in trees):
            continue
        # warm both build paths (jit trace of the advantage dispatch)
        batch = tr.build_batch(trees)
        legacy = tr.build_batch_legacy(trees)
        if batch.tokens.shape[0] == 0:
            # dynamic sampling starved the batch; disable the filter so
            # the update path is still exercised
            tr.train_cfg = dataclasses.replace(
                tr.train_cfg, dynamic_sampling=False)
            batch = tr.build_batch(trees)
            legacy = tr.build_batch_legacy(trees)
        packed = tr.build_batch_packed(trees)
        build_s = _time_best(lambda: tr.build_batch(trees), reps)
        legacy_build_s = _time_best(
            lambda: tr.build_batch_legacy(trees), reps)
        packed_build_s = _time_best(
            lambda: tr.build_batch_packed(trees), reps)

        snap = _snapshot(tr)
        tr.update(batch)            # compile the scanned K-epoch update
        _restore(tr, snap)
        tr.update_legacy(legacy)    # compile the per-epoch legacy update
        _restore(tr, snap)
        tr.update_packed(packed)    # compile the packed K-epoch update
        _restore(tr, snap)
        # steady state: every timed rep below must hit the warm per-
        # bucket caches — `recompiles` records any that didn't
        with compile_delta() as recompiles:
            upd_s = _time_best(lambda: tr.update(batch), reps)
            _restore(tr, snap)
            legacy_upd_s = _time_best(
                lambda: tr.update_legacy(legacy), reps)
            _restore(tr, snap)
            packed_upd_s = _time_best(
                lambda: tr.update_packed(packed), reps)

        N, L = batch.tokens.shape
        Np = packed.tokens.shape[0]
        row = {
            "mode": mode.value,
            "arch": arch,
            "ppo_epochs": ppo_epochs,
            "batch_rows": int(N),
            "bucket_len": int(L),
            "trajectories": int(sum(t.num_trajectories for t in trees)),
            "host_pack_bytes": int(batch.host_pack_bytes),
            "legacy_host_pack_bytes": int(legacy.host_pack_bytes),
            "build_s": round(build_s, 4),
            "legacy_build_s": round(legacy_build_s, 4),
            "update_s": round(upd_s, 4),
            "legacy_update_s": round(legacy_upd_s, 4),
            "update_dispatches_per_step": 1,
            "legacy_update_dispatches_per_step": ppo_epochs,
            "recompiles": int(recompiles()),
            "padded_token_fraction": round(
                batch.padded_token_fraction, 4),
            "packed": {
                "batch_rows": int(Np),
                "bucket_len": int(packed.tokens.shape[1]),
                "segment_slots": int(packed.seg_prompt_lens.shape[1]),
                "host_pack_bytes": int(packed.host_pack_bytes),
                "build_s": round(packed_build_s, 4),
                "update_s": round(packed_upd_s, 4),
                "padded_token_fraction": round(
                    packed.padded_token_fraction, 4),
            },
        }
        rows.append(row)
        label = mode.value if arch == "qwen2.5-7b" else \
            f"{mode.value}:{arch.split('-')[0]}"
        print(fmt_row([label, N, L, batch.host_pack_bytes,
                       legacy.host_pack_bytes, round(build_s, 4),
                       round(legacy_build_s, 4), round(upd_s, 4),
                       round(legacy_upd_s, 4)], widths))
        print(fmt_row(["  packed", Np, packed.tokens.shape[1],
                       packed.host_pack_bytes, "-",
                       round(packed_build_s, 4), "-",
                       round(packed_upd_s, 4),
                       f"pad {packed.padded_token_fraction:.3f} vs "
                       f"{batch.padded_token_fraction:.3f}"], widths))
    result = {"benchmark": "train_hotpath", "quick": quick,
              "wall_is_container_relative": True, "rows": rows}
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {os.path.relpath(out_path)}")
    return result
