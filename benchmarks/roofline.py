"""Roofline table from the dry-run artifacts (results/dryrun.jsonl).

Prints, per (arch × shape × mesh): the three roofline terms in seconds,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs (useful-compute fraction),
and per-collective byte counts.  This is the §Roofline source of truth.
"""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import fmt_row

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.jsonl")


def load_records(path: str = DEFAULT_PATH) -> List[dict]:
    recs = []
    if not os.path.exists(path):
        return recs
    seen = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            seen[(r["arch"], r["shape"], r["mesh"],
                  r.get("tag", "baseline"))] = r
    return list(seen.values())


def run(quick: bool = True, path: str = DEFAULT_PATH) -> List[dict]:
    recs = load_records(path)
    if not recs:
        print("\n== Roofline: no dry-run records yet "
              "(run python -m repro.launch.dryrun --out "
              "results/dryrun.jsonl) ==")
        return []
    rows = []
    print("\n== Roofline terms per (arch x shape x mesh) ==")
    hdr = ["arch", "shape", "mesh", "tag", "t_comp(s)", "t_mem(s)",
           "t_coll(s)", "bottleneck", "useful%"]
    widths = [18, 12, 6, 10, 10, 10, 10, 10, 8]
    print(fmt_row(hdr, widths))
    order = {"single": 0, "multi": 1}
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         order.get(r["mesh"], 2),
                                         r.get("tag", "baseline"))):
        tag = r.get("tag", "baseline")
        if r["status"] == "skipped":
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             mesh=r["mesh"], tag=tag, status="skipped"))
            continue
        if r["status"] != "ok":
            print(fmt_row([r["arch"], r["shape"], r["mesh"], tag, "ERROR",
                           r.get("error", "")[:40], "", "", ""], widths))
            continue
        # recompute terms from the raw per-device quantities so older
        # records pick up the current roofline semantics
        from repro.launch.analysis import Roofline
        raw = r["roofline"]
        ro = Roofline(flops=raw["flops"], hbm_bytes=raw["hbm_bytes"],
                      coll_bytes=raw["coll_bytes"], chips=r["chips"],
                      model_flops=raw["model_flops"]).as_dict()
        row = dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                   tag=tag,
                   t_compute=ro["t_compute_s"], t_memory=ro["t_memory_s"],
                   t_collective=ro["t_collective_s"],
                   bottleneck=ro["bottleneck"],
                   useful=ro["useful_flops_frac"],
                   flops=raw["flops"], hbm_bytes=raw["hbm_bytes"],
                   coll_bytes=raw["coll_bytes"])
        rows.append(row)
        print(fmt_row([r["arch"], r["shape"], r["mesh"], tag,
                       f"{ro['t_compute_s']:.2e}",
                       f"{ro['t_memory_s']:.2e}",
                       f"{ro['t_collective_s']:.2e}",
                       ro["bottleneck"],
                       f"{100 * ro['useful_flops_frac']:.0f}"], widths))
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if r["status"] == "skipped")
    n_err = len(recs) - n_ok - n_skip
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_err} errors "
          f"out of {len(recs)} recorded cases")
    return rows


if __name__ == "__main__":
    run(quick=False)
