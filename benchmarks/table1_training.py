"""Table 1 — GRPO vs GRPO+TreeSampling vs TreePO (toy-scale RL).

The paper's three main rows trained from a base model.  Here: BC-warmed
tiny byte model on synthetic verifiable math, a few RL steps per mode,
reporting reward / maj@k before-vs-after.  quick=True keeps it to one RL
step per mode (CI-friendly); quick=False runs longer curves.
"""
from __future__ import annotations

from typing import List

from repro.rl.trainer import TrainerMode

from benchmarks.common import fmt_row, warmed_trainer

MODES = [(TrainerMode.GRPO, "GRPO (sequential)"),
         (TrainerMode.GRPO_TREE, "GRPO w/ TreePO sampling"),
         (TrainerMode.TREEPO, "TreePO (sampling+advantage)")]


def run(quick: bool = True) -> List[dict]:
    steps = 2 if quick else 8
    rows = []
    for mode, label in MODES:
        tr = warmed_trainer(mode, bc_steps=50 if quick else 120, seed=2)
        ev0 = tr.evaluate(num_queries=4 if quick else 12, k=2)
        rewards, toks = [], 0
        for i in range(steps):
            m = tr.train_step(num_queries=1 if quick else 2)
            rewards.append(round(m["reward_mean"], 3))
            toks += int(m["sample_model_tokens"])
        ev1 = tr.evaluate(num_queries=4 if quick else 12, k=2)
        rows.append(dict(mode=label, maj_before=ev0["maj_acc"],
                         maj_after=ev1["maj_acc"],
                         pass_any_after=ev1["pass_any"],
                         rewards=rewards, sample_tokens=toks))
    print("\n== Table 1: training modes (toy scale) ==")
    print(fmt_row(["mode", "maj@2 pre", "maj@2 post", "pass-any",
                   "rewards", "tokens"], [28, 9, 10, 8, 22, 9]))
    for r in rows:
        print(fmt_row([r["mode"], r["maj_before"], r["maj_after"],
                       r["pass_any_after"], r["rewards"],
                       r["sample_tokens"]], [28, 9, 10, 8, 22, 9]))
    return rows


if __name__ == "__main__":
    run(quick=False)
