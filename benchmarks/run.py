"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Quick mode (default) keeps every benchmark CI-sized; --full runs the
paper-shaped sweeps.  The roofline table reads results/dryrun.jsonl
produced by ``python -m repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    decode_hotpath,
    robustness_degradation,
    serve_continuous,
    train_hotpath,
    fig4_depth_segment,
    fig5_rollout_scaling,
    fig6_advantage_ablation,
    fig7_segment_budget,
    fig8_prob_branching,
    fig9_compute_scaling,
    profile_dma_compute,
    roofline,
    table1_training,
    table2_efficiency,
)

BENCHES = [
    ("decode_hotpath", decode_hotpath),
    ("profile_dma_compute", profile_dma_compute),
    ("serve_continuous", serve_continuous),
    ("train_hotpath", train_hotpath),
    ("robustness_degradation", robustness_degradation),
    ("table2_efficiency", table2_efficiency),
    ("fig4_depth_segment", fig4_depth_segment),
    ("fig5_rollout_scaling", fig5_rollout_scaling),
    ("fig8_prob_branching", fig8_prob_branching),
    ("fig6_advantage_ablation", fig6_advantage_ablation),
    ("fig7_segment_budget", fig7_segment_budget),
    ("fig9_compute_scaling", fig9_compute_scaling),
    ("table1_training", table1_training),
    ("roofline", roofline),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    for name, mod in BENCHES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"\n##### {name} #####", flush=True)
        try:
            mod.run(quick=not args.full)
            print(f"[{name}] done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"[{name}] FAILED:\n{traceback.format_exc()}",
                  flush=True)
    print(f"\nbenchmarks: {len(BENCHES) - failures}/{len(BENCHES)} ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
