"""DMA/compute overlap profile for the pipelined fused-pool paged kernels.

Sweeps the DMA ring depth (``num_buffers`` in {1, 2, 4}) against page
size and KV-head count for both fused kernels (GQA head-interleaved and
MLA latent-concat) and reports, per configuration:

* ``max_err_vs_ref`` — interpret-mode parity against the jnp oracle
  (always measured, on any backend; the acceptance gate is <= 1e-5 f32),
* ``bitwise_stable`` — outputs identical across every swept depth
  (``num_buffers`` is a pure scheduling knob; this must hold everywhere),
* ``wall_ms`` — median wall-clock per dispatch.  On TPU this times the
  real ``pallas_call`` and the depth sweep is the load-bearing number:
  depth 1 serialises copy-then-score per page, depth >= 2 overlaps the
  copy of page i+1 with the scoring of page i.  On CPU there is no DMA
  engine to overlap, so the reference path is timed instead — a
  *relative* compute-cost signal across shapes, NOT a pipelining
  measurement (``timed_path`` in each row says which one ran).
* ``dma_bytes_per_row`` — bytes one decode row ships from the pool
  (pages * page * 2*Hkv * D * itemsize), the traffic the ring hides.

Emits ``results/BENCH_kernels.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row
from repro.kernels import ref as kref
from repro.kernels.paged_attention import (
    fused_paged_attention_pallas,
    mla_fused_paged_attention_pallas,
)
from repro.kv.layout import fuse_mla, interleave_kv

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_kernels.json")

DEPTHS = (1, 2, 4)


def _time_ms(fn, iters: int) -> float:
    fn()  # warm (jit trace / first dispatch)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(samples))


def _tables(B: int, max_pages: int, num_pages: int) -> jnp.ndarray:
    # contiguous non-overlapping tables; row 0 padded short, last row full
    tbl = np.full((B, max_pages), -1, np.int32)
    nxt = 0
    for b in range(B):
        n = max(1, (b * max_pages) // max(B - 1, 1)) if b else 1
        n = min(n, max_pages)
        tbl[b, :n] = np.arange(nxt, nxt + n) % num_pages
        nxt += n
    return jnp.asarray(tbl)


def _lengths(tables: jnp.ndarray, page: int) -> jnp.ndarray:
    n = np.asarray((tables >= 0).sum(axis=1))
    return jnp.asarray(np.maximum(n * page - page // 2, 1), jnp.int32)


def run(quick: bool = True, out_path: str = OUT_PATH) -> dict:
    on_tpu = jax.default_backend() == "tpu"
    B, D, num_pages, max_pages = 8, 64, 256, 8
    page_sizes = (16,) if quick else (8, 16, 32)
    gqa_heads = ((8, 2),) if quick else ((8, 1), (8, 2), (8, 8))
    iters = 5 if quick else 20
    rows = []
    print("\n== DMA/compute overlap: pipelined fused paged kernels ==")
    print(f"backend={jax.default_backend()} "
          f"(timed_path={'pallas' if on_tpu else 'ref'})")
    hdr = ["kernel", "page", "heads", "depth", "max_err", "bitwise",
           "wall_ms", "MB/row"]
    widths = [10, 5, 7, 5, 9, 7, 8, 7]
    print(fmt_row(hdr, widths))

    for page in page_sizes:
        tables = _tables(B, max_pages, num_pages)
        lengths = _lengths(tables, page)
        for hq, hkv in gqa_heads:
            ks = jax.random.split(jax.random.PRNGKey(page * 131 + hq), 3)
            q = jax.random.normal(ks[0], (B, hq, D))
            k = jax.random.normal(ks[1], (num_pages, page, hkv, D))
            v = jax.random.normal(ks[2], (num_pages, page, hkv, D))
            kv = interleave_kv(k, v)
            want = np.asarray(kref.paged_attention_ref(
                q, k, v, tables, lengths, page_size=page))
            bytes_row = max_pages * page * 2 * hkv * D * kv.dtype.itemsize
            outs = {}
            for depth in DEPTHS:
                outs[depth] = np.asarray(fused_paged_attention_pallas(
                    q, kv, tables, lengths, page_size=page,
                    num_buffers=depth, interpret=not on_tpu))
            stable = all(np.array_equal(outs[d], outs[DEPTHS[0]])
                         for d in DEPTHS)
            for depth in DEPTHS:
                err = float(np.abs(outs[depth] - want).max())
                if on_tpu:
                    fn = (lambda d=depth: fused_paged_attention_pallas(
                        q, kv, tables, lengths, page_size=page,
                        num_buffers=d))
                else:
                    fn = (lambda: kref.fused_paged_attention_ref(
                        q, kv, tables, lengths, page_size=page))
                ms = _time_ms(fn, iters)
                rows.append({
                    "kernel": "fused_paged", "page_size": page,
                    "hq": hq, "hkv": hkv, "head_dim": D,
                    "num_buffers": depth, "batch": B,
                    "max_err_vs_ref": err, "bitwise_stable": stable,
                    "wall_ms": round(ms, 4),
                    "dma_bytes_per_row": bytes_row,
                    "timed_path": "pallas" if on_tpu else "ref",
                })
                print(fmt_row(["fused", page, f"{hq}/{hkv}", depth,
                               f"{err:.1e}", stable, round(ms, 3),
                               round(bytes_row / 2**20, 2)], widths))

        # MLA latent-concat pool: head count enters via H (query heads
        # only — the latent pool is headless), feature dim via r + rd
        for H in ((8,) if quick else (4, 8, 16)):
            r, rd = 64, 32
            ks = jax.random.split(jax.random.PRNGKey(page * 313 + H), 4)
            ql = jax.random.normal(ks[0], (B, H, r))
            qr = jax.random.normal(ks[1], (B, H, rd))
            ckv = jax.random.normal(ks[2], (num_pages, page, r))
            kr = jax.random.normal(ks[3], (num_pages, page, rd))
            mkv = fuse_mla(ckv, kr)
            scale = 1.0 / ((r + rd) ** 0.5)
            want = np.asarray(kref.mla_paged_attention_ref(
                ql, qr, ckv, kr, tables, lengths, page_size=page,
                scale=scale))
            bytes_row = max_pages * page * (r + rd) * mkv.dtype.itemsize
            outs = {}
            for depth in DEPTHS:
                outs[depth] = np.asarray(mla_fused_paged_attention_pallas(
                    ql, qr, mkv, tables, lengths, page_size=page,
                    scale=scale, num_buffers=depth, interpret=not on_tpu))
            stable = all(np.array_equal(outs[d], outs[DEPTHS[0]])
                         for d in DEPTHS)
            for depth in DEPTHS:
                err = float(np.abs(outs[depth] - want).max())
                if on_tpu:
                    fn = (lambda d=depth: mla_fused_paged_attention_pallas(
                        ql, qr, mkv, tables, lengths, page_size=page,
                        scale=scale, num_buffers=d))
                else:
                    fn = (lambda: kref.mla_fused_paged_attention_ref(
                        ql, qr, mkv, tables, lengths, page_size=page,
                        scale=scale))
                ms = _time_ms(fn, iters)
                rows.append({
                    "kernel": "mla_fused_paged", "page_size": page,
                    "hq": H, "hkv": 0, "head_dim": r + rd,
                    "num_buffers": depth, "batch": B,
                    "max_err_vs_ref": err, "bitwise_stable": stable,
                    "wall_ms": round(ms, 4),
                    "dma_bytes_per_row": bytes_row,
                    "timed_path": "pallas" if on_tpu else "ref",
                })
                print(fmt_row(["mla_fused", page, f"{H}/-", depth,
                               f"{err:.1e}", stable, round(ms, 3),
                               round(bytes_row / 2**20, 2)], widths))

    worst = max(r_["max_err_vs_ref"] for r_ in rows)
    all_stable = all(r_["bitwise_stable"] for r_ in rows)
    print(f"worst parity error: {worst:.2e}  "
          f"bitwise-stable across depths: {all_stable}")
    result = {"benchmark": "profile_dma_compute", "quick": quick,
              "backend": jax.default_backend(),
              "depths_swept": list(DEPTHS),
              "worst_max_err_vs_ref": worst,
              "bitwise_stable_all": all_stable,
              "wall_includes_jit_trace": False, "rows": rows}
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {os.path.relpath(out_path)}")
    return result
