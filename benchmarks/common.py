"""Shared benchmark scaffolding.

All benchmarks run the REAL system (engine + trainer) at reduced scale on
CPU.  Wall-clock on this container is not TPU time, so every benchmark also
reports the *hardware-neutral* quantities the paper's TokenPS / TrajPS /
GPU-hours are built from: model-processed tokens (prefill + decode +
replay), trajectories produced, shared-prefix savings, and KV bytes.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig, TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_sequential, sample_trees
from repro.core.tree import QueryTree
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params
from repro.rl.trainer import RLTrainer, TrainerMode

TOK = ByteTokenizer()

ENGINE_KW = dict(num_pages=2048, page_size=16, max_slots=128,
                 max_queries=32, max_prompt_len=256)


def make_model(arch: str = "qwen2.5-7b", seed: int = 0):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def make_prompts(n: int, seed: int = 0) -> Tuple[List[List[int]],
                                                 List[str]]:
    gen = MathTaskGenerator(seed, 1, 2)
    samples = gen.batch(n)
    return ([TOK.encode(s.query, bos=True) for s in samples],
            [s.answer for s in samples])


def warmed_trainer(mode=TrainerMode.TREEPO, *, arch="qwen2.5-7b",
                   tree_cfg: Optional[TreeConfig] = None,
                   train_cfg: Optional[TrainConfig] = None,
                   bc_steps: int = 60, seed: int = 0) -> RLTrainer:
    cfg = get_config(arch, smoke=True)
    tree_cfg = tree_cfg or TreeConfig(
        max_depth=4, segment_len=16, max_width=4, branch_factor=2,
        init_divergence_low=2, init_divergence_high=2, temperature=0.9)
    train_cfg = train_cfg or TrainConfig(
        batch_size=2, group_size=tree_cfg.max_width, oversample_factor=2,
        max_resample_rounds=0, learning_rate=5e-4, reward_shaping=0.1)
    tr = RLTrainer(cfg, train_cfg, tree_cfg, mode, seed=seed,
                   engine_kwargs=ENGINE_KW, min_difficulty=1,
                   max_difficulty=1)
    if bc_steps:
        tr.bc_warmup(steps=bc_steps, batch_size=8, lr=3e-3)
    return tr


@dataclasses.dataclass
class RolloutCost:
    wall_s: float
    model_tokens: int          # prefill + decode + replay (engine-processed)
    prefill_tokens: int
    decode_tokens: int
    trajectories: int
    trajectory_tokens: int     # tokens in returned trajectories
    shared_prefix_tokens: int  # trajectory tokens served from shared KV
    host_bytes: int = 0        # device->host transfer in the decode loop
    segments: int = 0          # path-segments decoded
    forks: int = 0
    fork_dispatches: int = 0   # jitted fork-copy / fork-sample dispatches
    cow_pages: int = 0

    @property
    def token_ps(self) -> float:
        return self.model_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_token_ps(self) -> float:
        return self.decode_tokens / max(self.wall_s, 1e-9)

    @property
    def host_bytes_per_segment(self) -> float:
        return self.host_bytes / max(self.segments, 1)

    @property
    def traj_ps(self) -> float:
        return self.trajectories / max(self.wall_s, 1e-9)

    @property
    def sharing_ratio(self) -> float:
        """Fraction of trajectory tokens NOT recomputed thanks to the tree
        (the paper's KV-amortization win)."""
        return self.shared_prefix_tokens / max(self.trajectory_tokens, 1)


def measure_rollout(params, cfg, tree_cfg: TreeConfig,
                    prompts: List[List[int]], targets: List[str], *,
                    sequential: bool = False, seed: int = 0,
                    engine_kw: Optional[Dict] = None) -> Tuple[
                        List[QueryTree], RolloutCost]:
    eng = TreeEngine(params, cfg, tree_cfg, seed=seed,
                     **(engine_kw or ENGINE_KW))
    t0 = time.time()
    fn = sample_sequential if sequential else sample_trees
    trees, rep = fn(eng, prompts, targets, rng=random.Random(seed))
    wall = time.time() - t0
    traj_tokens = sum(len(p.tokens) for t in trees for p in t.finished)
    n_traj = sum(t.num_trajectories for t in trees)
    # shared tokens: trajectory tokens whose KV was produced once but used
    # by multiple descendants = traj_tokens - decode tokens attributable
    prompt_traj_tokens = sum(
        len(t.prompt_tokens) * t.num_trajectories for t in trees)
    total_served = traj_tokens + prompt_traj_tokens
    shared = max(total_served - eng.stats.model_tokens, 0)
    cost = RolloutCost(
        wall_s=wall, model_tokens=eng.stats.model_tokens,
        prefill_tokens=eng.stats.prefill_tokens,
        decode_tokens=eng.stats.decode_tokens,
        trajectories=n_traj, trajectory_tokens=total_served,
        shared_prefix_tokens=shared,
        host_bytes=eng.stats.host_bytes, segments=eng.stats.segments,
        forks=eng.stats.forks,
        fork_dispatches=eng.stats.fork_dispatches,
        cow_pages=eng.stats.cow_pages)
    return trees, cost


def fmt_row(cols, widths=None) -> str:
    widths = widths or [18] * len(cols)
    return "  ".join(str(c)[:w].ljust(w) for c, w in zip(cols, widths))
