"""Figure 4 — TokenPS / TrajPS across depth×segment trade-offs.

Fixed per-trajectory budget B = d × l; sweep depth d (the paper uses
{56×128, 28×256, 14×512, 7×1024} under B=7k; scaled here).  Reports the
paper's throughput metrics plus the sharing ratio that drives them.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import TreeConfig

from benchmarks.common import (fmt_row, make_model, make_prompts,
                               measure_rollout)


def run(quick: bool = True) -> List[dict]:
    cfg, params = make_model()
    budget = 64 if quick else 192          # d*l per trajectory
    depths = [2, 4, 8] if quick else [2, 4, 8, 16]
    width = 4 if quick else 8
    prompts, targets = make_prompts(2 if quick else 4, seed=2)
    rows = []
    for d in depths:
        l = budget // d
        tc = TreeConfig(max_depth=d, segment_len=l, max_width=width,
                        branch_factor=2, init_divergence_low=2,
                        init_divergence_high=2, temperature=0.9)
        _, cost = measure_rollout(params, cfg, tc, prompts, targets,
                                  seed=0, engine_kw=dict(
                                      num_pages=2048,
                                      page_size=min(16, l),
                                      max_slots=128, max_queries=32,
                                      max_prompt_len=256))
        rows.append(dict(depth=d, segment=l,
                         token_ps=round(cost.token_ps, 1),
                         traj_ps=round(cost.traj_ps, 3),
                         model_tokens=cost.model_tokens,
                         sharing=round(cost.sharing_ratio, 3)))
    print("\n== Fig 4: depth x segment sweep (budget d*l fixed) ==")
    print(fmt_row(["depth", "segment", "tokenPS", "trajPS", "model_tokens",
                   "sharing"], [6, 8, 9, 9, 13, 8]))
    for r in rows:
        print(fmt_row([r["depth"], r["segment"], r["token_ps"],
                       r["traj_ps"], r["model_tokens"], r["sharing"]],
                      [6, 8, 9, 9, 13, 8]))
    return rows


if __name__ == "__main__":
    run(quick=False)
