"""Continuous-batching serving benchmark: sustained throughput + reuse.

Replays ONE seeded Poisson arrival trace of repeated-prefix requests
(shared system prompt + generated math questions) through the serving
stack twice:

* ``continuous`` — the Scheduler/ModelRunner loop with the radix cache:
  continuous admission, mixed prefill/decode dispatch, cross-request KV
  reuse;
* ``sync`` — the synchronous-batch baseline on the *same* serve
  function (admission gated on a drained batch, radix off) — what
  `launch/serve.py` did before continuous batching.

Reported per mode: sustained generated TokenPS / TrajPS (wall-clock on
this container — relative, not TPU), rounds, and for continuous mode
the KV page-reuse ratio (prompt tokens served from the radix cache) and
the warm recompile count, which must be zero: the serve loop pads every
round to one (Rb, l) bucket, so a whole serve lifetime reuses a single
compiled shape.  Arrivals are staggered in virtual round units so later
requests really do arrive after earlier prompts were cached (the
repeated-prefix workload the radix targets).

Emits ``results/BENCH_serve.json``.
"""
from __future__ import annotations

import json
import os
import random
import time

from benchmarks.common import fmt_row, make_model
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.guard import compile_delta
from repro.core.scheduler import Request, Scheduler, poisson_trace
from repro.data.synthetic_math import MathTaskGenerator
from repro.data.tokenizer import ByteTokenizer

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_serve.json")

SYSTEM_PROMPT = ("You are a careful math assistant. Work step by step "
                 "and put the final answer in \\boxed{}. ")

ENGINE_KW = dict(num_pages=1024, page_size=8, max_slots=32,
                 max_queries=16, max_prompt_len=256)
TREE_CFG = TreeConfig(max_depth=4, segment_len=8, max_width=4,
                      branch_factor=2, init_divergence_low=2,
                      init_divergence_high=2, temperature=0.9)
MAX_RUNNING = 4
MAX_NEW = 24


def _workload(n: int, seed: int):
    """Repeated-prefix requests on a seeded Poisson trace (round units:
    mean inter-arrival ~ half a request's service time, so admission is
    continuous AND later requests hit the cached shared prefix)."""
    tok = ByteTokenizer()
    gen = MathTaskGenerator(seed=seed, min_difficulty=1, max_difficulty=2)
    samples = gen.batch(n)
    prompts = [tok.encode(SYSTEM_PROMPT + s.query, bos=True)
               for s in samples]
    arrivals = poisson_trace(random.Random(seed), n, rate=0.15)
    return [Request(rid=i, prompt=p, max_new_tokens=MAX_NEW, arrival=a)
            for i, (p, a) in enumerate(zip(prompts, arrivals))]


def _serve(eng, reqs, mode: str, radix: bool):
    """One serving pass on a SHARED engine — the jitted (Rb, l) serve
    bucket compiles once in the cold pass and every measured pass runs
    warm, so `recompiles` really measures shape churn, not cache
    construction."""
    sched = Scheduler(eng, mode=mode, max_running=MAX_RUNNING,
                      base_seed=0, radix=radix)
    t0 = time.time()
    with compile_delta() as compiles:
        report = sched.run(reqs)
    wall = time.time() - t0
    assert report.finished == len(reqs)
    if sched.radix is not None:
        sched.radix.evict(eng.kv.pool.num_pages)   # drain between passes
    return {
        "mode": mode,
        "radix": radix,
        "requests": len(reqs),
        "wall_s": round(wall, 3),
        "rounds": report.rounds,
        "gen_tokens": report.gen_tokens,
        "model_tokens": report.model_tokens,
        "token_ps": round(report.gen_tokens / max(wall, 1e-9), 2),
        "traj_ps": round(report.finished / max(wall, 1e-9), 4),
        "reuse_ratio": round(report.reuse_ratio, 4),
        "preemptions": report.preemptions,
        "max_admission_wait_rounds": report.max_admission_wait,
        "evicted_pages": report.evicted_pages,
        "recompiles": compiles(),
        "peak_pages": eng.stats.peak_pages,
    }


def run(quick: bool = True, out_path: str = OUT_PATH) -> dict:
    n = 8 if quick else 24
    cfg, params = make_model("qwen2.5-7b")
    eng = TreeEngine(params, cfg, TREE_CFG, **ENGINE_KW)
    print("\n== Continuous-batching serving: Poisson trace, "
          "repeated-prefix workload ==")

    # cold pass compiles the single (Rb, l) serve bucket; both measured
    # passes below then run warm — recompiles must be 0
    _serve(eng, _workload(2, seed=9), "continuous", radix=True)

    rows = []
    for mode, radix in (("sync", False), ("continuous", True)):
        rows.append(_serve(eng, _workload(n, seed=1), mode, radix))
    hdr = ["mode", "tok/s", "traj/s", "rounds", "reuse", "preempt",
           "recompiles"]
    print(fmt_row(hdr, [12, 9, 9, 8, 7, 8, 10]))
    for r in rows:
        print(fmt_row([r["mode"], r["token_ps"], r["traj_ps"],
                       r["rounds"], r["reuse_ratio"], r["preemptions"],
                       r["recompiles"]], [12, 9, 9, 8, 7, 8, 10]))

    sync, cont = rows
    result = {
        "bench": "serve_continuous",
        "arch": "qwen2.5-7b-smoke",
        "quick": quick,
        "poisson_rate_per_round": 0.15,
        "max_running": MAX_RUNNING,
        "segment_len": TREE_CFG.segment_len,
        "max_new_tokens": MAX_NEW,
        "modes": rows,
        "speedup_token_ps": round(
            cont["token_ps"] / max(sync["token_ps"], 1e-9), 3),
        "kv_page_reuse_ratio": cont["reuse_ratio"],
        "recompiles": cont["recompiles"],
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"continuous/sync TokenPS speedup: "
          f"{result['speedup_token_ps']}x, reuse "
          f"{result['kv_page_reuse_ratio']}, recompiles "
          f"{result['recompiles']}")
    return result


if __name__ == "__main__":
    run()
