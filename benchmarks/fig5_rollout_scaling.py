"""Figure 5 — throughput scaling with rollout count (tree vs sequential).

The paper: tree-based sampling reaches ~2x baseline TrajPS as rollouts
grow (shared-prefix prefilling + parallel decode); vanilla autoregressive
sampling gains little.  Proxy: model-processed tokens per returned
trajectory (lower = better amortization) plus wall-clock PS on CPU.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import TreeConfig

from benchmarks.common import (fmt_row, make_model, make_prompts,
                               measure_rollout)


def run(quick: bool = True) -> List[dict]:
    cfg, params = make_model()
    widths = [2, 4] if quick else [2, 4, 8, 16]
    depth, seg = (4, 16) if quick else (6, 32)
    prompts, targets = make_prompts(2, seed=3)
    rows = []
    for w in widths:
        for sampler in ("tree", "sequential"):
            tc = TreeConfig(
                max_depth=depth, segment_len=seg, max_width=w,
                branch_factor=2 if sampler == "tree" else 1,
                init_divergence_low=2 if sampler == "tree" else w,
                init_divergence_high=2 if sampler == "tree" else w,
                fallback=sampler == "tree", temperature=0.9)
            _, cost = measure_rollout(
                params, cfg, tc, prompts, targets,
                sequential=sampler == "sequential", seed=0)
            rows.append(dict(
                rollouts=w, sampler=sampler,
                tokens_per_traj=round(cost.model_tokens
                                      / max(cost.trajectories, 1), 1),
                traj_ps=round(cost.traj_ps, 3),
                token_ps=round(cost.token_ps, 1),
                sharing=round(cost.sharing_ratio, 3)))
    print("\n== Fig 5: rollout-count scaling ==")
    print(fmt_row(["rollouts", "sampler", "tok/traj", "trajPS", "tokenPS",
                   "sharing"], [8, 11, 9, 9, 9, 8]))
    for r in rows:
        print(fmt_row([r["rollouts"], r["sampler"], r["tokens_per_traj"],
                       r["traj_ps"], r["token_ps"], r["sharing"]],
                      [8, 11, 9, 9, 9, 8]))
    return rows


if __name__ == "__main__":
    run(quick=False)
