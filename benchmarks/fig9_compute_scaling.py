"""Figure 9 — test-time compute scaling per tree-divergence factor.

Sweep the compute budget (number of trajectories drawn per query) for
divergence factors d ∈ {2, 4, 8}; report pass-any / maj accuracy vs the
model-token cost.  Shows the family-of-curves behaviour: small divergence
wins at low budget, large divergence peaks higher at large budget.
"""
from __future__ import annotations

import random
from collections import Counter
from typing import List

from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_trees
from repro.data.reward import extract_boxed, verify_answer
from repro.data.tokenizer import ByteTokenizer
from repro.rl.trainer import TrainerMode

from benchmarks.common import ENGINE_KW, fmt_row, make_prompts, \
    warmed_trainer

TOK = ByteTokenizer()


def run(quick: bool = True) -> List[dict]:
    # a BC-warmed model so answers are sometimes right
    tr = warmed_trainer(TrainerMode.TREEPO, bc_steps=80 if quick else 150,
                        seed=4)
    cfg, params = tr.cfg, tr.params
    prompts, targets = make_prompts(3 if quick else 8, seed=5)
    divs = [2, 4] if quick else [2, 4, 8]
    widths = [2, 4] if quick else [2, 4, 8, 16]
    rows = []
    for div in divs:
        for w in widths:
            if w < div:
                continue
            tc = TreeConfig(max_depth=4, segment_len=16, max_width=w,
                            branch_factor=2, init_divergence_low=div,
                            init_divergence_high=div, temperature=1.0)
            eng = TreeEngine(params, cfg, tc, seed=0, **ENGINE_KW)
            trees, _ = sample_trees(eng, prompts, targets,
                                    rng=random.Random(0))
            n_any, n_maj = 0, 0
            for tree, target in zip(trees, targets):
                answers = [extract_boxed(TOK.decode(p.tokens))
                           for p in tree.finished]
                answers = [a for a in answers if a]
                if any(verify_answer(a, target) for a in answers):
                    n_any += 1
                if answers and verify_answer(
                        Counter(answers).most_common(1)[0][0], target):
                    n_maj += 1
            rows.append(dict(
                tree_div=div, width=w,
                compute_tokens=eng.stats.model_tokens,
                pass_any=round(n_any / len(trees), 3),
                maj=round(n_maj / len(trees), 3)))
    print("\n== Fig 9: test-time compute scaling by divergence factor ==")
    print(fmt_row(["div", "width", "compute_tokens", "pass-any", "maj"],
                  [4, 6, 14, 9, 6]))
    for r in rows:
        print(fmt_row([r["tree_div"], r["width"], r["compute_tokens"],
                       r["pass_any"], r["maj"]], [4, 6, 14, 9, 6]))
    return rows


if __name__ == "__main__":
    run(quick=False)
