"""Figure 6 — TreePO advantage-term ablation.

Two parts:
  (a) estimator-level: on identical sampled trees, compare the four
      estimator variants' assignments (Eq. 5 vs 6 vs 7 vs no-root) —
      fast, deterministic, shows exactly where they disagree;
  (b) training-level (quick=False): short RL runs per variant, reporting
      reward trajectories (the paper's accuracy/entropy/length curves at
      toy scale).
"""
from __future__ import annotations

import random
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import TreeConfig
from repro.core.advantage import treepo_advantage
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_trees
from repro.core.tree import Status, ancestor_matrix
from repro.rl.trainer import TrainerMode

from benchmarks.common import (ENGINE_KW, fmt_row, make_model,
                               make_prompts, warmed_trainer)

VARIANTS = ["treepo", "treepo_size_weighted", "treepo_subgroup_reject",
            "treepo_no_root"]


def run(quick: bool = True) -> List[dict]:
    cfg, params = make_model()
    tc = TreeConfig(max_depth=4, segment_len=16, max_width=6,
                    branch_factor=2, init_divergence_low=2,
                    init_divergence_high=2, temperature=1.0)
    eng = TreeEngine(params, cfg, tc, seed=0, **ENGINE_KW)
    prompts, targets = make_prompts(2, seed=4)
    trees, _ = sample_trees(eng, prompts, targets, rng=random.Random(0))
    rows = []
    rng = np.random.default_rng(0)
    for tree in trees:
        G = len(tree.finished)
        anc = ancestor_matrix(tree.finished, tc.max_depth)
        # synthetic mixed rewards (the raw model rarely scores)
        rewards = rng.choice([0.0, 1.0], size=G).astype(np.float32)
        if rewards.std() == 0:
            rewards[0] = 1.0 - rewards[0]
        per = {}
        for v in VARIANTS:
            adv = np.asarray(treepo_advantage(jnp.asarray(rewards),
                                              jnp.asarray(anc), variant=v))
            per[v] = adv
        base = per["treepo"]
        for v in VARIANTS:
            rows.append(dict(
                query=tree.query_idx, variant=v,
                adv_mean=round(float(per[v].mean()), 4),
                adv_std=round(float(per[v].std()), 4),
                corr_vs_eq5=round(float(np.corrcoef(base, per[v])[0, 1]), 4)
                if per[v].std() > 0 and base.std() > 0 else 1.0))
    print("\n== Fig 6(a): advantage estimator variants on shared trees ==")
    print(fmt_row(["query", "variant", "mean", "std", "corr_vs_eq5"],
                  [5, 24, 8, 8, 11]))
    for r in rows:
        print(fmt_row([r["query"], r["variant"], r["adv_mean"],
                       r["adv_std"], r["corr_vs_eq5"]], [5, 24, 8, 8, 11]))

    if not quick:
        print("\n== Fig 6(b): short training runs per variant ==")
        for v in VARIANTS:
            tr = warmed_trainer(TrainerMode.TREEPO, bc_steps=60, seed=1)
            tr.train_cfg = tr.train_cfg.__class__(
                **{**tr.train_cfg.__dict__, "advantage_kind": v})
            rews = []
            for _ in range(3):
                m = tr.train_step(num_queries=2)
                rews.append(m["reward_mean"])
            print(fmt_row([v, [round(r, 3) for r in rews]], [24, 30]))
            rows.append(dict(variant=v, training_rewards=rews))
    return rows


if __name__ == "__main__":
    run(quick=False)
