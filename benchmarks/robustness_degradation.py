"""Robustness benchmark: graceful degradation under KV-pool pressure.

Measures the fault-tolerance layer's core trade (docs/robustness.md):
as the page pool shrinks to a fraction of the nominal run's measured
peak, how many trajectories are still produced, at what TokenPS, with
how much preemption/regeneration churn — and, the hard invariant, with
ZERO escaped ``OutOfPages``.  Pool fractions {1.0, 0.75, 0.5} of the
measured peak; each rollout is seeded, so rows are reproducible.

Emits ``results/BENCH_robustness.json``.
"""
from __future__ import annotations

import json
import os
import random
import time

from benchmarks.common import fmt_row, make_model, make_prompts
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.sampler import sample_trees
from repro.core.tree import Status
from repro.kv.cache import OutOfPages

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_robustness.json")

# growth-dominated trees: the degradable memory (tree KV) must dwarf the
# irreducible prefill footprint for sub-peak pools to be survivable
ENGINE_KW = dict(num_pages=2048, page_size=16, max_slots=64,
                 max_queries=16, max_prompt_len=256)
FRACTIONS = (1.0, 0.75, 0.5)


def _tree_cfg(quick: bool) -> TreeConfig:
    return TreeConfig(max_depth=5 if quick else 6, segment_len=16,
                      max_width=8, branch_factor=2,
                      init_divergence_low=2, init_divergence_high=2,
                      temperature=0.9)


def _rollout(params, cfg, tree_cfg, prompts, targets, num_pages, seed=0):
    eng = TreeEngine(params, cfg, tree_cfg, seed=seed,
                     **dict(ENGINE_KW, num_pages=num_pages))
    t0 = time.time()
    escaped = 0
    try:
        trees, _ = sample_trees(eng, prompts, targets,
                                rng=random.Random(seed))
    except OutOfPages:
        escaped, trees = 1, []
    wall = time.time() - t0
    kept = sum(len(t.finished) for t in trees)
    leaves = sum(1 for t in trees for p in t.finished
                 if p.status == Status.LEAF)
    failed = kept - leaves
    return {
        "num_pages": num_pages,
        "peak_pages": eng.kv.pool.peak_in_use,
        "kept_trajectories": kept,
        "leaves": leaves,
        "failed": failed,
        "preempted": eng.stats.preempted_paths,
        "regenerated": eng.stats.regenerated_paths,
        "pressure_events": eng.stats.pressure_events,
        "model_tokens": eng.stats.model_tokens,
        "wall_s": round(wall, 3),
        "token_ps": round(eng.stats.model_tokens / max(wall, 1e-9), 1),
        "escaped_oom": escaped,
    }


def run(quick: bool = True, out_path: str = OUT_PATH) -> dict:
    n_queries = 2 if quick else 4
    cfg, params = make_model("qwen2.5-7b")
    tree_cfg = _tree_cfg(quick)
    prompts, targets = make_prompts(n_queries, seed=1)

    print("\n== Robustness: degradation under KV-pool pressure ==")
    nominal = _rollout(params, cfg, tree_cfg, prompts, targets,
                       ENGINE_KW["num_pages"])
    peak = nominal["peak_pages"]
    rows = []
    hdr = ["pool_frac", "pages", "kept", "leaves", "preempted", "regen",
           "tok/s", "escaped_oom"]
    print(fmt_row(hdr, [9, 7, 6, 7, 9, 6, 10, 11]))
    for frac in FRACTIONS:
        pages = max(int(peak * frac), 1)
        row = _rollout(params, cfg, tree_cfg, prompts, targets, pages)
        row["pool_frac"] = frac
        rows.append(row)
        print(fmt_row([frac, pages, row["kept_trajectories"],
                       row["leaves"], row["preempted"],
                       row["regenerated"], row["token_ps"],
                       row["escaped_oom"]],
                      [9, 7, 6, 7, 9, 6, 10, 11]))
        assert row["escaped_oom"] == 0, \
            f"OutOfPages escaped at pool_frac={frac}"

    out = {"benchmark": "robustness_degradation",
           "arch": cfg.name, "num_queries": n_queries,
           "nominal_peak_pages": peak, "rows": rows}
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {os.path.relpath(out_path)}")
    return out


if __name__ == "__main__":
    run()
