"""Decode hot-path benchmark: device-residency of the segment inner loop.

Measures, for tree vs sequential sampling, the two quantities the
device-resident refactor targets:

* decode tokens/sec (wall-clock on this container — relative, not TPU;
  each row builds a fresh engine, so wall time includes jit tracing of
  that mode's shape buckets — the exact byte/dispatch counters below are
  the load-bearing numbers, tok/s is a coarse sanity signal),
* host-transferred bytes per decoded path-segment (``EngineStats`` counts
  the decode/fork loop's device->host copies; opt-in ``last_logits``
  debug fetches are outside the accounting — nothing here calls them).

The old engine copied the full (Rb, V) f32 boundary-logits matrix to the
host every segment and resampled forks one numpy draw at a time; the
steady state is now O(R*l) tokens + O(R) scalars, with fork divergence
sampled on device.  ``legacy_logits_bytes_per_segment`` (= V * 4) is what
the removed copy alone cost per path-segment, for comparison.

Emits ``results/BENCH_decode.json`` to seed the perf trajectory.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import (
    fmt_row,
    make_model,
    make_prompts,
    measure_rollout,
)
from repro.configs.base import TreeConfig

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_decode.json")


def _tree_cfg() -> TreeConfig:
    return TreeConfig(max_depth=4, segment_len=16, max_width=4,
                      branch_factor=2, init_divergence_low=2,
                      init_divergence_high=2, temperature=0.9)


def run(quick: bool = True, out_path: str = OUT_PATH) -> dict:
    archs = ["qwen2.5-7b"] if quick else [
        "qwen2.5-7b", "deepseek-v3-671b", "jamba-v0.1-52b"]
    n_queries = 4 if quick else 8
    rows = []
    print("\n== Decode hot path: tree vs sequential ==")
    hdr = ["arch", "mode", "decode_tok", "tok/s", "B/seg", "forks",
           "dispatches", "cow"]
    print(fmt_row(hdr, [18, 10, 10, 10, 10, 7, 10, 5]))
    for arch in archs:
        cfg, params = make_model(arch)
        vocab = cfg.vocab_size
        for mode in ("tree", "sequential"):
            prompts, targets = make_prompts(n_queries, seed=1)
            _, cost = measure_rollout(
                params, cfg, _tree_cfg(), prompts, targets,
                sequential=(mode == "sequential"), seed=1)
            row = {
                "arch": arch,
                "mode": mode,
                "decode_tokens": cost.decode_tokens,
                "wall_s": round(cost.wall_s, 3),
                "decode_token_ps": round(cost.decode_token_ps, 1),
                "segments": cost.segments,
                "host_bytes": cost.host_bytes,
                "host_bytes_per_segment": round(
                    cost.host_bytes_per_segment, 1),
                "legacy_logits_bytes_per_segment": vocab * 4,
                "forks": cost.forks,
                "fork_dispatches": cost.fork_dispatches,
                "cow_pages": cost.cow_pages,
                "trajectories": cost.trajectories,
            }
            rows.append(row)
            print(fmt_row([arch, mode, cost.decode_tokens,
                           round(cost.decode_token_ps, 1),
                           round(cost.host_bytes_per_segment, 1),
                           cost.forks, cost.fork_dispatches,
                           cost.cow_pages],
                          [18, 10, 10, 10, 10, 7, 10, 5]))
    result = {"benchmark": "decode_hotpath", "quick": quick,
              "wall_includes_jit_trace": True, "rows": rows}
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {os.path.relpath(out_path)}")
    return result
