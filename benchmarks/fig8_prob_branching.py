"""Figure 8 — probability-based heuristic branching-budget assignment.

Compares uniform vs low-prob-encourage vs high-prob-encourage vs the
scheduled variant.  Reports the structural effect (how the budget shifts
between confident/uncertain paths, entropy of the fork distribution) and,
in full mode, short training runs.
"""
from __future__ import annotations

import math
import random
from typing import List

from repro.configs.base import TreeConfig
from repro.core.branching import assign_branches

from benchmarks.common import fmt_row

HEURISTICS = ["uniform", "low_prob", "high_prob", "scheduled_low_prob"]


def run(quick: bool = True) -> List[dict]:
    rng = random.Random(0)
    # emulate a segment round: 4 active paths with spread confidences
    seg_logprobs = [-0.2, -0.9, -2.5, -6.0]
    budget = 12
    rows = []
    for h in HEURISTICS:
        tc = TreeConfig(max_depth=4, segment_len=16, max_width=16,
                        branch_factor=2, branch_heuristic=h,
                        heuristic_temp=2.0)
        for progress in ([0.0] if h != "scheduled_low_prob"
                         else [0.0, 0.5, 1.0]):
            forks = assign_branches(tc, seg_logprobs, budget,
                                    random.Random(1), progress)
            p = [f / sum(forks) for f in forks]
            ent = -sum(pi * math.log(pi) for pi in p if pi > 0)
            rows.append(dict(heuristic=h, progress=progress, forks=forks,
                             fork_entropy=round(ent, 3),
                             low_prob_share=round(p[-1], 3)))
    print("\n== Fig 8: branching-budget heuristics "
          "(4 paths, logprobs -0.2/-0.9/-2.5/-6.0, budget 12) ==")
    print(fmt_row(["heuristic", "progress", "forks", "entropy",
                   "low-prob share"], [20, 8, 16, 8, 14]))
    for r in rows:
        print(fmt_row([r["heuristic"], r["progress"], r["forks"],
                       r["fork_entropy"], r["low_prob_share"]],
                      [20, 8, 16, 8, 14]))
    return rows


if __name__ == "__main__":
    run(quick=False)
