"""Figure 7 — online depth×segment budget study (training-side).

The paper: 14×512 is the sweet spot under budget 7k; 7×1024 lags.  Toy
mirror: fixed budget d×l, short TreePO runs per (d, l), reporting reward
and response-length trends.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import TrainConfig, TreeConfig
from repro.rl.trainer import TrainerMode

from benchmarks.common import fmt_row, warmed_trainer


def run(quick: bool = True) -> List[dict]:
    budget = 64
    combos = [(2, 32), (4, 16)] if quick else [(2, 32), (4, 16), (8, 8)]
    steps = 1 if quick else 4
    rows = []
    for d, l in combos:
        tc = TreeConfig(max_depth=d, segment_len=l, max_width=4,
                        branch_factor=2, init_divergence_low=2,
                        init_divergence_high=2, temperature=0.9)
        tr = warmed_trainer(TrainerMode.TREEPO, tree_cfg=tc,
                            bc_steps=50, seed=3)
        rewards, lens = [], []
        for _ in range(steps):
            m = tr.train_step(num_queries=1 if quick else 2)
            rewards.append(round(m["reward_mean"], 3))
            lens.append(round(m["response_len"], 1))
        rows.append(dict(depth=d, segment=l, rewards=rewards,
                         response_lens=lens))
    print("\n== Fig 7: depth x segment under fixed budget "
          f"(d*l={budget}) ==")
    print(fmt_row(["depth", "segment", "rewards", "response_len"],
                  [6, 8, 24, 16]))
    for r in rows:
        print(fmt_row([r["depth"], r["segment"], r["rewards"],
                       r["response_lens"]], [6, 8, 24, 16]))
    return rows


if __name__ == "__main__":
    run(quick=False)
