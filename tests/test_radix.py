"""Property suite for the cross-request radix cache (repro.kv.radix).

Two tiers: seeded always-run twins (random-walk oracle comparisons that
run in every environment) and hypothesis properties that explore the
same invariants adversarially where hypothesis is installed — the
test_property.py / test_faults.py split, applied to the radix cache.

The invariants:
  * ``match_prefix`` returns exactly the longest cached page-aligned
    prefix (vs a brute-force oracle over every inserted sequence),
    capped one token short of the query;
  * insert / match / evict round-trip: what was inserted is found, what
    was evicted is not, and pages come back identical;
  * refcount conservation: one cache-owned ref per cached page, one ref
    per match handed out, zero net refs after eviction + caller release
    (cross-validated by ``lifecycle_guard``'s shadow refcounts);
  * eviction never frees a page a live path still references.
"""
import random

import numpy as np
import pytest

from repro.core.lifecycle import lifecycle_guard
from repro.kv.cache import PagePool
from repro.kv.radix import RadixCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.serve

PS = 4   # page size for the pure host-side tests


# ---------------------------------------------------------------------------
# brute-force oracle
# ---------------------------------------------------------------------------

class OracleCache:
    """Reference model: a dict from block-path tuples to page ids.  The
    first insert of a block path wins (the radix keeps the incumbent),
    and a lookup walks block by block until a path misses."""

    def __init__(self, page_size: int):
        self.ps = page_size
        self.pages = {}           # block-path tuple -> page id

    def _blocks(self, tokens, n):
        return tuple(tuple(tokens[i * self.ps:(i + 1) * self.ps])
                     for i in range(n))

    def insert(self, tokens, pages):
        n = len(pages)
        fresh = []
        for i in range(n):
            path = self._blocks(tokens, i + 1)
            if path not in self.pages:
                self.pages[path] = pages[i]
                fresh.append(pages[i])
        return fresh

    def match(self, tokens):
        limit = max(0, (len(tokens) - 1) // self.ps)
        out = []
        for i in range(limit):
            path = self._blocks(tokens, i + 1)
            if path not in self.pages:
                break
            out.append(self.pages[path])
        return out, len(out) * self.ps

    def drop_all(self):
        self.pages.clear()


def _release_match(pool, pages):
    for pid in pages:
        pool.release(pid)


def _sequences(rng, n, vocab=5, maxlen=6 * PS):
    """Token sequences with heavy prefix sharing (tiny vocab)."""
    return [[rng.randrange(vocab) for _ in range(rng.randrange(1, maxlen))]
            for _ in range(n)]


def _run_trace(seqs, queries):
    """Feed insert/match traffic through cache + oracle, asserting match
    agreement on every query; returns (pool, cache) for further checks."""
    pool = PagePool(num_pages=512)
    cache = RadixCache(pool, PS)
    oracle = OracleCache(PS)
    owned = {}                    # seq idx -> pages the "path" still refs
    for si, seq in enumerate(seqs):
        n = len(seq) // PS
        pages = [pool.alloc() for _ in range(n)]
        cache.insert(seq[: n * PS], pages)
        oracle.insert(seq[: n * PS], pages)
        owned[si] = pages
    for q in queries:
        got_pages, got_tokens = cache.match_prefix(q)
        want_pages, want_tokens = oracle.match(q)
        assert got_tokens == want_tokens, (q, got_tokens, want_tokens)
        assert got_pages == want_pages, (q, got_pages, want_pages)
        _release_match(pool, got_pages)
    return pool, cache, owned


# ---------------------------------------------------------------------------
# always-run seeded twins
# ---------------------------------------------------------------------------

def test_match_is_capped_one_token_short():
    """A fully-cached prompt still re-feeds its final token: the match
    limit is (len-1)//ps pages, so the caller always recomputes the
    boundary logits it samples from."""
    pool = PagePool(num_pages=16)
    cache = RadixCache(pool, PS)
    seq = [1, 2, 3, 4, 5, 6, 7, 8]
    pages = [pool.alloc(), pool.alloc()]
    cache.insert(seq, pages)
    got, n = cache.match_prefix(seq)
    assert n == PS and got == pages[:1]       # 2nd page NOT returned
    _release_match(pool, got)
    got, n = cache.match_prefix(seq + [9])    # one past the boundary
    assert n == 2 * PS and got == pages
    _release_match(pool, got)


def test_match_oracle_seeded_random_walk():
    rng = random.Random(0xC0FFEE)
    for round_ in range(20):
        seqs = _sequences(rng, rng.randrange(1, 8))
        queries = seqs + _sequences(rng, 4)
        pool, cache, owned = _run_trace(seqs, queries)
        # teardown: evict everything, then drop the path refs
        cache.evict(pool.num_pages)
        for pages in owned.values():
            _release_match(pool, pages)
        assert pool.pages_in_use == 0


def test_insert_dedups_and_counts_new_pages():
    pool = PagePool(num_pages=64)
    cache = RadixCache(pool, PS)
    seq = list(range(3 * PS))
    pages = [pool.alloc() for _ in range(3)]
    assert cache.insert(seq, pages) == 3
    # an identical re-insert keeps the incumbent: 0 new pages owned
    dup = [pool.alloc() for _ in range(3)]
    assert cache.insert(seq, dup) == 0
    assert cache.cached_pages == 3
    # a diverging suffix shares the common prefix, owns only the tail
    seq2 = seq[: 2 * PS] + [99] * PS
    pages2 = [pool.alloc() for _ in range(3)]
    assert cache.insert(seq2, pages2) == 1
    got, n = cache.match_prefix(seq2 + [0])
    assert n == 3 * PS and got == pages[:2] + pages2[2:]
    _release_match(pool, got)
    for p in pages + dup + pages2:
        pool.release(p)
    cache.evict(pool.num_pages)
    assert pool.pages_in_use == 0


def test_evict_never_frees_live_referenced_page():
    pool = PagePool(num_pages=16)
    cache = RadixCache(pool, PS)
    seq = list(range(2 * PS))
    pages = [pool.alloc(), pool.alloc()]     # the "live path" refs
    cache.insert(seq, pages)                 # cache ref on top: rc == 2
    assert cache.evictable_pages == 0
    freed = cache.evict(4)
    # eviction dropped the cache's refs but freed NOTHING to the pool
    assert freed == 0
    assert cache.cached_pages == 0
    assert all(int(pool.refcount[p]) == 1 for p in pages)
    assert pool.pages_in_use == 2            # still allocated, path-owned
    for p in pages:
        pool.release(p)
    assert pool.pages_in_use == 0


def test_evict_lru_order_and_roundtrip():
    pool = PagePool(num_pages=64)
    cache = RadixCache(pool, PS)
    old = [1] * (2 * PS)
    new = [2] * (2 * PS)
    p_old = [pool.alloc(), pool.alloc()]
    p_new = [pool.alloc(), pool.alloc()]
    cache.insert(old, p_old)
    cache.insert(new, p_new)
    for p in p_old + p_new:
        pool.release(p)                      # cache is now sole owner
    m, _ = cache.match_prefix(new + [0])     # touch `new`: old is LRU
    _release_match(pool, m)
    assert cache.evict(1) >= 1
    gone, n = cache.match_prefix(old + [0])
    assert n == 0 and gone == []             # LRU leaf evicted first
    kept, n = cache.match_prefix(new + [0])
    assert n == 2 * PS                       # recently-used leaf survives
    _release_match(pool, kept)
    cache.evict(pool.num_pages)
    assert pool.pages_in_use == 0


def test_refcount_conservation_under_lifecycle_guard():
    """The cache's retain/release traffic flows through the same patched
    PagePool methods lifecycle_guard shadows — a full insert / match /
    evict / release session must net to zero or the guard raises."""
    with lifecycle_guard() as tracker:
        pool = PagePool(num_pages=128)
        cache = RadixCache(pool, PS)
        rng = random.Random(7)
        seqs = _sequences(rng, 6)
        live = []
        for seq in seqs:
            n = len(seq) // PS
            pages = [pool.alloc() for _ in range(n)]
            cache.insert(seq[: n * PS], pages)
            live.append(pages)
        for seq in seqs:
            got, _ = cache.match_prefix(seq + [0])
            _release_match(pool, got)
        cache.evict(pool.num_pages)
        for pages in live:
            _release_match(pool, pages)
        assert pool.pages_in_use == 0
    assert tracker.violations == []


# ---------------------------------------------------------------------------
# hypothesis properties (exploratory tier)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = settings(max_examples=50, deadline=None)
    token_seq = st.lists(st.integers(0, 4), min_size=1, max_size=6 * PS)

    @SETTINGS
    @given(st.lists(token_seq, max_size=8), st.lists(token_seq, max_size=8))
    def test_prop_match_equals_bruteforce_oracle(seqs, queries):
        pool, cache, owned = _run_trace(seqs, seqs + queries)
        cache.evict(pool.num_pages)
        for pages in owned.values():
            _release_match(pool, pages)
        assert pool.pages_in_use == 0

    @SETTINGS
    @given(st.lists(token_seq, min_size=1, max_size=8),
           st.integers(0, 64))
    def test_prop_evict_conserves_refcounts(seqs, need):
        pool = PagePool(num_pages=256)
        cache = RadixCache(pool, PS)
        live = []
        for seq in seqs:
            n = len(seq) // PS
            pages = [pool.alloc() for _ in range(n)]
            cache.insert(seq[: n * PS], pages)
            live.append(pages)
        before = pool.pages_in_use
        freed = cache.evict(need)
        # freed pages had refcount 1 (cache-only); path-held pages remain
        assert pool.pages_in_use == before - freed
        assert all(int(pool.refcount[p]) >= 1
                   for pages in live for p in pages)
        cache.evict(pool.num_pages)
        for pages in live:
            _release_match(pool, pages)
        assert pool.pages_in_use == 0
