"""HLO collective-bytes parser + roofline-term units."""
import pytest

from repro.launch.analysis import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    Roofline,
    collective_bytes,
)

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[16,128,4096]{2,1,0} all-gather(%p0), replica_groups={...}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = bf16[8,512]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[32,64]{1,0} all-to-all(%z), dimensions={0}
  %cp = f32[16]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %tup = (f32[128]{0}, f32[256]{0}) all-reduce(%a, %b), to_apply=%add
  %not_a_collective = f32[999]{0} add(%u, %v)
}
"""


def test_collective_bytes_parses_all_kinds():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 128 * 4096 * 2
    assert out["all-reduce"] == 1024 * 4 + (128 + 256) * 4
    assert out["reduce-scatter"] == 8 * 512 * 2
    assert out["all-to-all"] == 32 * 64 * 2
    assert out["collective-permute"] == 16 * 4
    assert sum(out.values()) > 0


def test_collective_bytes_ignores_non_collectives():
    out = collective_bytes("%x = f32[10]{0} add(%a, %b)")
    assert sum(out.values()) == 0


def test_roofline_terms_per_device_semantics():
    ro = Roofline(flops=PEAK_FLOPS, hbm_bytes=HBM_BW,
                  coll_bytes={"all-reduce": int(2 * LINK_BW)}, chips=256,
                  model_flops=PEAK_FLOPS * 128)
    assert ro.t_compute == pytest.approx(1.0)
    assert ro.t_memory == pytest.approx(1.0)
    assert ro.t_collective == pytest.approx(2.0)
    assert ro.bottleneck == "collective"
    assert ro.useful_flops_frac == pytest.approx(0.5)
    d = ro.as_dict()
    assert d["bottleneck"] == "collective"


def test_model_flops_estimate_modes():
    from repro.configs import get_config
    from repro.launch.analysis import model_flops_estimate
    cfg = get_config("yi-6b")
    train = model_flops_estimate(cfg, "train_4k")
    prefill = model_flops_estimate(cfg, "prefill_32k")
    decode = model_flops_estimate(cfg, "decode_32k")
    assert train > prefill > decode > 0
    # MoE uses active params
    moe = get_config("olmoe-1b-7b")
    assert moe.num_active_params() < moe.num_params()
