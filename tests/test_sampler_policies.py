"""Branching / fallback / heuristic policy units (paper §2.2, §4.4)."""
import random

import pytest

from repro.configs.base import TreeConfig
from repro.core.branching import (
    assign_branches,
    depth_budget,
    heuristic_tau,
    init_divergence,
    softmax_weights,
)
from repro.core.fallback import pick_fallback
from repro.core.tree import Path, QueryTree, Status


def _tc(**kw):
    base = dict(max_depth=4, segment_len=8, max_width=8, branch_factor=2,
                init_divergence_low=2, init_divergence_high=2)
    base.update(kw)
    return TreeConfig(**base)


def test_depth_budget_binary_growth():
    tc = _tc()
    assert depth_budget(tc, 0, 2, 0) == 2
    assert depth_budget(tc, 1, 2, 0) == 4
    assert depth_budget(tc, 2, 2, 0) == 8
    assert depth_budget(tc, 3, 2, 0) == 8      # capped at w
    assert depth_budget(tc, 3, 2, 5) == 3      # width transfer to finished
    assert depth_budget(tc, 3, 2, 8) == 0


def test_init_divergence_fixed_vs_random():
    rng = random.Random(0)
    tc = _tc(init_divergence_low=3, init_divergence_high=3)
    assert init_divergence(tc, rng) == 3
    tc = _tc(init_divergence_low=2, init_divergence_high=8)
    draws = {init_divergence(tc, rng) for _ in range(100)}
    assert draws <= set(range(2, 9)) and len(draws) > 3


def test_assign_branches_uniform_budget_transfer():
    tc = _tc(branch_heuristic="uniform")
    rng = random.Random(0)
    forks = assign_branches(tc, [-1.0, -2.0, -3.0], 7, rng)
    assert sum(forks) == 7 and all(f >= 1 for f in forks)


def test_assign_branches_prune_when_budget_short():
    tc = _tc()
    forks = assign_branches(tc, [-1.0] * 5, 3, random.Random(0))
    assert sum(forks) == 3 and forks.count(0) == 2


def test_low_prob_encourage_prefers_uncertain():
    tc = _tc(branch_heuristic="low_prob", heuristic_temp=0.5)
    forks = assign_branches(tc, [-0.1, -5.0], 10, random.Random(0))
    assert forks[1] > forks[0]          # low prob path gets more budget


def test_high_prob_encourage_prefers_confident():
    tc = _tc(branch_heuristic="high_prob", heuristic_temp=0.5)
    forks = assign_branches(tc, [-0.1, -5.0], 10, random.Random(0))
    assert forks[0] > forks[1]


def test_scheduled_tau_anneals():
    tc = _tc(branch_heuristic="scheduled_low_prob")
    assert heuristic_tau(tc, 0.0) == pytest.approx(5.0)
    assert heuristic_tau(tc, 1.0) == pytest.approx(1.0)
    assert heuristic_tau(tc, 0.5) == pytest.approx(3.0)


def test_softmax_weights_sum_to_one():
    w = softmax_weights([-1.0, -2.0, -3.0], tau=2.0, sign=-1.0)
    assert sum(w) == pytest.approx(1.0)
    assert w[2] > w[0]


def _leaf(depth, bounds, reason="boxed"):
    p = Path(query_idx=0, depth=depth, node_ids=list(range(depth + 1)),
             tokens=list(range(bounds[-1])), logprobs=[0.0] * bounds[-1],
             seg_bounds=list(bounds))
    p.status = Status.LEAF
    p.finish_reason = reason
    return p


def test_fallback_candidates_filter():
    tree = QueryTree(query_idx=0, prompt_tokens=[1], target="x")
    tree.finished = [
        _leaf(3, [0, 8, 16, 24], "boxed"),
        _leaf(3, [0, 8, 16, 24], "length"),      # not a candidate
        _leaf(1, [0, 8], "eos"),                 # too shallow
    ]
    cands = tree.fallback_candidates()
    assert len(cands) == 1 and cands[0].finish_reason == "boxed"


def test_pick_fallback_depth_range():
    tree = QueryTree(query_idx=0, prompt_tokens=[1], target="x")
    tree.finished = [_leaf(4, [0, 8, 16, 24, 32], "eos")]
    rng = random.Random(0)
    seen = set()
    for _ in range(50):
        src, j = pick_fallback(tree, rng)
        assert 1 <= j <= 3
        seen.add(j)
    assert len(seen) >= 2  # random over boundaries


def test_pick_fallback_none_when_no_candidates():
    tree = QueryTree(query_idx=0, prompt_tokens=[1], target="x")
    tree.finished = [_leaf(3, [0, 8, 16, 24], "repetition")]
    tree.finished[0].status = Status.FAILED
    assert pick_fallback(tree, random.Random(0)) is None
