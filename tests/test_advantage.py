"""Unit tests for the TreePO advantage estimators (paper Eq. 2/5/6/7)."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.advantage import (
    batch_treepo_advantage,
    global_normalize,
    grpo_advantage,
    query_keep_mask,
    subgroup_sizes,
    treepo_advantage,
    _subgroup_means,
)


def _paper_tree():
    """The Figure-3 example: 8 leaves under root q; subgroups by ancestor.

    anc columns: depth0 (root), depth1 (c1/c2), depth2 (c21/c22 ...).
    """
    anc = np.array([
        [0, 1, 3],   # under c1 / c11
        [0, 1, 3],
        [0, 1, 4],
        [0, 1, 4],
        [0, 2, 5],   # under c2 / c21
        [0, 2, 5],
        [0, 2, 6],   # under c2 / c22  (the worked example)
        [0, 2, 6],
    ])
    rewards = np.array([1, 0, 0, 0, 1, 1, 0, 1], np.float32)
    return jnp.asarray(rewards), jnp.asarray(anc)


def test_subgroup_means_exact():
    rewards, anc = _paper_tree()
    means = np.asarray(_subgroup_means(rewards, anc))
    # depth 0: global mean 0.5 for everyone
    assert_allclose(means[:, 0], 0.5)
    # depth 1: first four under c1 -> 0.25; last four under c2 -> 0.75
    assert_allclose(means[:4, 1], 0.25)
    assert_allclose(means[4:, 1], 0.75)
    # depth 2 pairs
    assert_allclose(means[:2, 2], 0.5)
    assert_allclose(means[2:4, 2], 0.0)
    assert_allclose(means[4:6, 2], 1.0)
    assert_allclose(means[6:, 2], 0.5)


def test_subgroup_sizes():
    _, anc = _paper_tree()
    sizes = np.asarray(subgroup_sizes(anc))
    assert_allclose(sizes[:, 0], 8)
    assert_allclose(sizes[:, 1], 4)
    assert_allclose(sizes[:, 2], 2)


def test_grpo_advantage_matches_eq2():
    rewards, _ = _paper_tree()
    adv = np.asarray(grpo_advantage(rewards))
    want = (np.asarray(rewards) - 0.5) / (np.asarray(rewards).std() + 1e-6)
    assert_allclose(adv, want, rtol=1e-5)


def test_treepo_advantage_eq5_hand_computed():
    """Leaf 6 (R=0, under c2/c22): Â_j = 0-0.5, 0-0.75, 0-0.5."""
    rewards, anc = _paper_tree()
    adv = np.asarray(treepo_advantage(rewards, anc, variant="treepo"))
    a_j = np.array([-0.5, -0.75, -0.5])
    want6 = a_j.mean() / (a_j.std() + 1e-6)
    assert_allclose(adv[6], want6, rtol=1e-4)


def test_size_weighted_differs_and_matches_eq6():
    rewards, anc = _paper_tree()
    a5 = np.asarray(treepo_advantage(rewards, anc, variant="treepo"))
    a6 = np.asarray(treepo_advantage(rewards, anc,
                                     variant="treepo_size_weighted"))
    assert not np.allclose(a5, a6)
    # leaf 6 weighted: (8*(-.5)+4*(-.75)+2*(-.5))/14 / std
    a_j = np.array([-0.5, -0.75, -0.5])
    w = np.array([8, 4, 2], np.float32)
    want6 = (w * a_j).sum() / w.sum() / (a_j.std() + 1e-6)
    assert_allclose(a6[6], want6, rtol=1e-4)


def test_subgroup_reject_zeroes_degenerate():
    """Eq. 7: a subgroup with zero reward-std contributes nothing."""
    rewards, anc = _paper_tree()
    adv = np.asarray(treepo_advantage(rewards, anc,
                                      variant="treepo_subgroup_reject"))
    # leaves 4,5 sit in subgroup c21 with rewards (1,1): std=0 at depth 2,
    # so only depths 0,1 count for them
    a_j = np.array([1 - 0.5, 1 - 0.75])
    want4 = a_j.mean() / (np.array([0.5, 0.25, 0.0]).std() + 1e-6)
    assert_allclose(adv[4], want4, rtol=1e-4)


def test_no_root_drops_depth0():
    rewards, anc = _paper_tree()
    adv = np.asarray(treepo_advantage(rewards, anc,
                                      variant="treepo_no_root"))
    a_j = np.array([-0.75, -0.5])  # leaf 6 without the root term
    want6 = a_j.mean() / (a_j.std() + 1e-6)
    assert_allclose(adv[6], want6, rtol=1e-4)


def test_shift_invariance():
    """Subgroup baselines center the signal: adding a constant to every
    reward must not change any treepo advantage."""
    rewards, anc = _paper_tree()
    a1 = np.asarray(treepo_advantage(rewards, anc))
    a2 = np.asarray(treepo_advantage(rewards + 3.7, anc))
    assert_allclose(a1, a2, rtol=1e-4, atol=1e-5)


def test_degenerate_group_is_finite():
    """All-equal rewards (filtered upstream by dynamic sampling) must not
    produce NaNs if they slip through."""
    anc = jnp.asarray(np.zeros((4, 3), np.int64))
    adv = np.asarray(treepo_advantage(jnp.ones(4), anc))
    assert np.isfinite(adv).all()
    assert_allclose(adv, 0.0, atol=1e-3)


def test_query_keep_mask():
    r = jnp.asarray([[1., 1., 1.], [0., 1., 0.], [0., 0., 0.]])
    keep = np.asarray(query_keep_mask(r))
    assert list(keep) == [False, True, False]


def test_global_normalize_unit_variance():
    adv = jnp.asarray(np.random.RandomState(0).randn(6, 10).astype("f"))
    mask = jnp.ones_like(adv)
    out = np.asarray(global_normalize(adv, mask))
    # normalized by std -> unit second moment around the (kept) mean
    centered = out - out.mean()
    assert abs(centered.std() - 1.0) < 0.05


def test_batch_wrapper_shapes():
    rewards, anc = _paper_tree()
    r = jnp.stack([rewards, rewards])
    a = jnp.stack([anc, anc])
    out = batch_treepo_advantage(r, a, variant="treepo")
    assert out.shape == (2, 8)
    out_g = batch_treepo_advantage(r, a, variant="grpo")
    assert out_g.shape == (2, 8)
