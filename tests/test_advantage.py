"""Unit tests for the TreePO advantage estimators (paper Eq. 2/5/6/7)."""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.advantage import (
    batch_treepo_advantage,
    global_normalize,
    grpo_advantage,
    query_keep_mask,
    subgroup_sizes,
    treepo_advantage,
    _subgroup_means,
)


def _paper_tree():
    """The Figure-3 example: 8 leaves under root q; subgroups by ancestor.

    anc columns: depth0 (root), depth1 (c1/c2), depth2 (c21/c22 ...).
    """
    anc = np.array([
        [0, 1, 3],   # under c1 / c11
        [0, 1, 3],
        [0, 1, 4],
        [0, 1, 4],
        [0, 2, 5],   # under c2 / c21
        [0, 2, 5],
        [0, 2, 6],   # under c2 / c22  (the worked example)
        [0, 2, 6],
    ])
    rewards = np.array([1, 0, 0, 0, 1, 1, 0, 1], np.float32)
    return jnp.asarray(rewards), jnp.asarray(anc)


def test_subgroup_means_exact():
    rewards, anc = _paper_tree()
    means = np.asarray(_subgroup_means(rewards, anc))
    # depth 0: global mean 0.5 for everyone
    assert_allclose(means[:, 0], 0.5)
    # depth 1: first four under c1 -> 0.25; last four under c2 -> 0.75
    assert_allclose(means[:4, 1], 0.25)
    assert_allclose(means[4:, 1], 0.75)
    # depth 2 pairs
    assert_allclose(means[:2, 2], 0.5)
    assert_allclose(means[2:4, 2], 0.0)
    assert_allclose(means[4:6, 2], 1.0)
    assert_allclose(means[6:, 2], 0.5)


def test_subgroup_sizes():
    _, anc = _paper_tree()
    sizes = np.asarray(subgroup_sizes(anc))
    assert_allclose(sizes[:, 0], 8)
    assert_allclose(sizes[:, 1], 4)
    assert_allclose(sizes[:, 2], 2)


def test_grpo_advantage_matches_eq2():
    rewards, _ = _paper_tree()
    adv = np.asarray(grpo_advantage(rewards))
    want = (np.asarray(rewards) - 0.5) / (np.asarray(rewards).std() + 1e-6)
    assert_allclose(adv, want, rtol=1e-5)


def test_treepo_advantage_eq5_hand_computed():
    """Leaf 6 (R=0, under c2/c22): Â_j = 0-0.5, 0-0.75, 0-0.5."""
    rewards, anc = _paper_tree()
    adv = np.asarray(treepo_advantage(rewards, anc, variant="treepo"))
    a_j = np.array([-0.5, -0.75, -0.5])
    want6 = a_j.mean() / (a_j.std() + 1e-6)
    assert_allclose(adv[6], want6, rtol=1e-4)


def test_size_weighted_differs_and_matches_eq6():
    rewards, anc = _paper_tree()
    a5 = np.asarray(treepo_advantage(rewards, anc, variant="treepo"))
    a6 = np.asarray(treepo_advantage(rewards, anc,
                                     variant="treepo_size_weighted"))
    assert not np.allclose(a5, a6)
    # leaf 6 weighted: (8*(-.5)+4*(-.75)+2*(-.5))/14 / std
    a_j = np.array([-0.5, -0.75, -0.5])
    w = np.array([8, 4, 2], np.float32)
    want6 = (w * a_j).sum() / w.sum() / (a_j.std() + 1e-6)
    assert_allclose(a6[6], want6, rtol=1e-4)


def test_subgroup_reject_zeroes_degenerate():
    """Eq. 7: a subgroup with zero reward-std contributes nothing — to the
    numerator AND the per-trajectory std denominator (the rejected depth
    is dropped from the whole estimator, per the paper's ablation)."""
    rewards, anc = _paper_tree()
    adv = np.asarray(treepo_advantage(rewards, anc,
                                      variant="treepo_subgroup_reject"))
    # leaves 4,5 sit in subgroup c21 with rewards (1,1): std=0 at depth 2,
    # so only depths 0,1 count for them — numerator and denominator both
    a_j = np.array([1 - 0.5, 1 - 0.75])
    want4 = a_j.mean() / (a_j.std() + 1e-6)
    assert_allclose(adv[4], want4, rtol=1e-4)
    # leaf 6's subgroups are all non-degenerate: matches plain treepo
    a6 = np.array([-0.5, -0.75, -0.5])
    assert_allclose(adv[6], a6.mean() / (a6.std() + 1e-6), rtol=1e-4)


def test_no_root_drops_depth0():
    rewards, anc = _paper_tree()
    adv = np.asarray(treepo_advantage(rewards, anc,
                                      variant="treepo_no_root"))
    a_j = np.array([-0.75, -0.5])  # leaf 6 without the root term
    want6 = a_j.mean() / (a_j.std() + 1e-6)
    assert_allclose(adv[6], want6, rtol=1e-4)


def test_shift_invariance():
    """Subgroup baselines center the signal: adding a constant to every
    reward must not change any treepo advantage."""
    rewards, anc = _paper_tree()
    a1 = np.asarray(treepo_advantage(rewards, anc))
    a2 = np.asarray(treepo_advantage(rewards + 3.7, anc))
    assert_allclose(a1, a2, rtol=1e-4, atol=1e-5)


def test_degenerate_group_is_finite():
    """All-equal rewards (filtered upstream by dynamic sampling) must not
    produce NaNs if they slip through."""
    anc = jnp.asarray(np.zeros((4, 3), np.int64))
    adv = np.asarray(treepo_advantage(jnp.ones(4), anc))
    assert np.isfinite(adv).all()
    assert_allclose(adv, 0.0, atol=1e-3)


def test_query_keep_mask():
    r = jnp.asarray([[1., 1., 1.], [0., 1., 0.], [0., 0., 0.]])
    keep = np.asarray(query_keep_mask(r))
    assert list(keep) == [False, True, False]


def test_global_normalize_unit_variance():
    adv = jnp.asarray(np.random.RandomState(0).randn(6, 10).astype("f"))
    mask = jnp.ones_like(adv)
    out = np.asarray(global_normalize(adv, mask))
    # normalized by std -> unit second moment around the (kept) mean
    centered = out - out.mean()
    assert abs(centered.std() - 1.0) < 0.05


def test_batch_wrapper_shapes():
    rewards, anc = _paper_tree()
    r = jnp.stack([rewards, rewards])
    a = jnp.stack([anc, anc])
    out = batch_treepo_advantage(r, a, variant="treepo")
    assert out.shape == (2, 8)
    out_g = batch_treepo_advantage(r, a, variant="grpo")
    assert out_g.shape == (2, 8)


ALL_VARIANTS = ["grpo", "treepo", "treepo_size_weighted",
                "treepo_subgroup_reject", "treepo_no_root"]


def _hand_advantage(rewards, anc, variant, eps=1e-6):
    """Plain-loop numpy reference (hand-derived from Eq. 2/5/6/7).

    This is a *structural* cross-check (loops vs the vmapped dense
    kernels); the estimator *definitions* — including the Eq. 7
    kept-terms denominator — are pinned independently by the explicit
    numeric fixtures above (e.g. test_subgroup_reject_zeroes_degenerate).
    """
    rewards = np.asarray(rewards, np.float64)
    anc = np.asarray(anc)
    G, J = anc.shape
    if variant == "grpo":
        return (rewards - rewards.mean()) / (rewards.std() + eps)
    means = np.zeros((G, J))
    stds = np.zeros((G, J))
    sizes = np.zeros((G, J))
    for i in range(G):
        for j in range(J):
            grp = rewards[anc[:, j] == anc[i, j]]
            means[i, j] = grp.mean()
            stds[i, j] = grp.std()
            sizes[i, j] = len(grp)
    adv_j = rewards[:, None] - means
    if variant == "treepo_no_root":
        adv_j = adv_j[:, 1:]
        w = np.ones_like(adv_j)
        std_w = np.ones_like(adv_j)
    elif variant == "treepo_size_weighted":
        w = sizes
        std_w = np.ones_like(adv_j)
    elif variant == "treepo_subgroup_reject":
        w = (stds > eps).astype(np.float64)
        std_w = w
    else:
        w = np.ones_like(adv_j)
        std_w = np.ones_like(adv_j)
    agg = (w * adv_j).sum(1) / np.maximum(w.sum(1), eps)
    n = np.maximum(std_w.sum(1), 1.0)
    m = (std_w * adv_j).sum(1) / n
    std = np.sqrt((std_w * (adv_j - m[:, None]) ** 2).sum(1) / n)
    return agg / (std + eps)


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_every_variant_matches_hand_reference(variant):
    """Hand-computed fixture for each estimator on the known small tree."""
    rewards, anc = _paper_tree()
    got = np.asarray(treepo_advantage(rewards, anc, variant=variant)
                     if variant != "grpo" else grpo_advantage(rewards))
    want = _hand_advantage(rewards, anc, variant)
    assert_allclose(got, want, atol=1e-5)


def _ragged_queries():
    """Two queries with different group sizes (8 and 5) + varied rewards."""
    r0, a0 = _paper_tree()
    a1 = np.array([
        [9, 10, 12],
        [9, 10, 12],
        [9, 10, 13],
        [9, 11, 14],
        [9, 11, 15],
    ])
    r1 = np.array([0.0, 1.0, 1.0, 0.0, 1.0], np.float32)
    return [(np.asarray(r0), np.asarray(a0)), (r1, a1)]


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_masked_batched_matches_per_tree_reference(variant):
    """The one-dispatch masked batched path must agree with the per-tree
    reference on ragged group sizes to <= 1e-5."""
    queries = _ragged_queries()
    Q = len(queries)
    G = max(len(r) for r, _ in queries)
    J = queries[0][1].shape[1]
    rew = np.zeros((Q, G), np.float32)
    anc = np.zeros((Q, G, J), np.int64)
    mask = np.zeros((Q, G), np.float32)
    for qi, (r, a) in enumerate(queries):
        g = len(r)
        rew[qi, :g] = r
        anc[qi, :g] = a
        mask[qi, :g] = 1.0
        for slot in range(g, G):
            anc[qi, slot] = -(qi * G + slot + 1)   # sentinel singleton
    got = np.asarray(batch_treepo_advantage(
        jnp.asarray(rew), jnp.asarray(anc), jnp.asarray(mask),
        variant=variant, use_global_norm=False))
    for qi, (r, a) in enumerate(queries):
        g = len(r)
        if variant == "grpo":
            want = np.asarray(grpo_advantage(jnp.asarray(r)))
        else:
            want = np.asarray(treepo_advantage(
                jnp.asarray(r), jnp.asarray(a), variant=variant))
        assert_allclose(got[qi, :g], want, atol=1e-5)
        assert_allclose(got[qi, g:], 0.0, atol=1e-6)  # padded slots zeroed


def test_batched_global_norm_masks_padding():
    """Global normalization must use only valid entries."""
    queries = _ragged_queries()
    G = 8
    rew = np.zeros((2, G), np.float32)
    anc = np.zeros((2, G, 3), np.int64)
    mask = np.zeros((2, G), np.float32)
    for qi, (r, a) in enumerate(queries):
        g = len(r)
        rew[qi, :g] = r
        anc[qi, :g] = a
        mask[qi, :g] = 1.0
        for slot in range(g, G):
            anc[qi, slot] = -(qi * G + slot + 1)
    out = np.asarray(batch_treepo_advantage(
        jnp.asarray(rew), jnp.asarray(anc), jnp.asarray(mask),
        variant="treepo", use_global_norm=True))
    valid = out[np.asarray(mask) > 0]
    # normalized second moment ~ 1 over the valid entries
    assert abs(np.sqrt((valid ** 2).mean()) - 1.0) < 0.2
    assert_allclose(out[np.asarray(mask) == 0], 0.0, atol=1e-6)


def test_batch_group_tensors_roundtrip():
    """batch_group_tensors pads with unique sentinels and preserves the
    incremental per-path rows."""
    from repro.core.tree import Path, QueryTree, Status, batch_group_tensors

    trees = []
    for qi, g in enumerate([3, 2]):
        t = QueryTree(query_idx=qi, prompt_tokens=[1], target="x",
                      max_depth=2)
        for i in range(g):
            p = Path(query_idx=qi, depth=1, node_ids=[100 * qi, i + 1],
                     tokens=[1, 2], logprobs=[0.0, 0.0])
            p.status = Status.LEAF
            p.reward = float(i)
            t.add_finished(p)
        trees.append(t)
    anc, rew, mask = batch_group_tensors(trees, max_depth=2)
    assert anc.shape == (2, 3, 3) and rew.shape == (2, 3)
    assert mask.tolist() == [[1, 1, 1], [1, 1, 0]]
    # short path repeats its leaf id below its depth
    assert anc[0, 0].tolist() == [0, 1, 1]
    assert rew[1].tolist() == [0.0, 1.0, 0.0]
    # padded slot has a unique negative id that matches nothing real
    assert anc[1, 2, 0] < 0
    assert (anc[1, 2] != anc[1, 1]).all()
