"""Data / optimizer / checkpoint substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data.reward import reward_fn
from repro.data.synthetic_math import MathTaskGenerator, make_dataset
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    warmup_constant_schedule,
)


def test_generator_deterministic_and_verifiable():
    a = MathTaskGenerator(7).batch(20)
    b = MathTaskGenerator(7).batch(20)
    assert [s.query for s in a] == [s.query for s in b]
    for s in a:
        assert reward_fn(s.cot, s.answer) == 1.0  # CoT answers its own task
        assert 3 <= s.difficulty <= 5


def test_generator_difficulty_bounds():
    for s in MathTaskGenerator(0, 1, 2).batch(10):
        assert s.difficulty in (1, 2)


def test_make_dataset():
    ds = make_dataset(5, seed=1)
    assert len(ds) == 5


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    st = adamw_init(params)
    lr = 0.1
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, st = adamw_update(params, grads, st, lr=lr)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(st.step) == 200


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.ones(4)}
    st = adamw_init(params)
    grads = {"w": jnp.zeros(4)}
    params2, _ = adamw_update(params, grads, st, lr=0.1, weight_decay=0.5)
    assert float(params2["w"][0]) < 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_schedule():
    f = warmup_constant_schedule(1e-3, 10)
    assert float(f(jnp.asarray(0))) == pytest.approx(1e-4)
    assert float(f(jnp.asarray(9))) == pytest.approx(1e-3)
    assert float(f(jnp.asarray(100))) == pytest.approx(1e-3)


def test_checkpoint_roundtrip_mixed_dtypes():
    pytest.importorskip("zstandard",
                        reason="checkpoint compression needs zstandard")
    tree = {
        "p": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
        "opt": (jnp.zeros((), jnp.int32), [jnp.ones(2)]),
        "meta": 3,
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 50, tree)
        save_checkpoint(d, 100, tree)
        assert latest_step(d) == 100
        back = load_checkpoint(d, 50)
        assert back["p"]["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(back["p"]["w"], np.float32),
                                      np.asarray(tree["p"]["w"], np.float32))
        assert isinstance(back["opt"], tuple)
        assert back["meta"] == 3


def test_checkpoint_atomic_no_partial(tmp_path):
    pytest.importorskip("zstandard",
                        reason="checkpoint compression needs zstandard")
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(3)})
    files = os.listdir(tmp_path)
    assert files == ["step_00000001.ckpt"]
