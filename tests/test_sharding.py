"""Sharding-rule tests (host-scale mesh; the 512-device mesh is dryrun's)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    fsdp_axes,
    param_pspecs,
)
from repro.models.model import init_cache, init_params


def _mesh_1dev(axes=("data", "model")):
    devs = np.asarray(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(devs, axes)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_pspecs_cover_tree(arch):
    """Specs exist for every leaf and never exceed the leaf's rank."""
    cfg = get_config(arch)
    mesh = _mesh_1dev()
    pshape = jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_pspecs(cfg, pshape, mesh)
    flat_p = jax.tree.leaves(pshape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape)


def test_divisibility_filter():
    """whisper's 51865 vocab is indivisible by 16 -> must be replicated."""
    class FakeAxis(dict):
        pass
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    # emulate a 16-way model axis via a mesh-shape monkeypatch
    import repro.distributed.sharding as sh
    spec = sh._filter_spec(("model", None), (51865, 384), mesh)
    # 1-way axis -> dropped regardless
    assert spec == P(None, None)


def test_filter_spec_drops_uneven():
    import repro.distributed.sharding as sh

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = sh._filter_spec(("model", ("data",)), (51865, 384), M)
    assert spec[0] is None          # 51865 % 16 != 0 -> dropped
    assert spec[1] is not None      # 384 % 16 == 0 -> kept
    spec2 = sh._filter_spec((("data",), "model"), (64, 384), M)
    assert spec2 == P(("data",), "model")


def test_batch_pspec():
    import repro.distributed.sharding as sh

    class M:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    assert sh.batch_pspec(M, 256) == P(("pod", "data"))
    assert sh.batch_pspec(M, 16) == P("data")
    assert sh.batch_pspec(M, 1) == P(None)


def test_cache_pspecs_seq_on_model():
    import repro.distributed.sharding as sh

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("yi-6b")
    cache = init_cache(cfg, 128, 32768, jnp.bfloat16)
    specs = sh.cache_pspecs(cfg, cache, M)
    k_spec = specs["layers"][0]["k"]
    assert k_spec[0] == "data" and k_spec[1] == "model"


def test_cache_pspecs_recurrent_state():
    import repro.distributed.sharding as sh

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_config("rwkv6-7b")
    cache = init_cache(cfg, 128, 32768, jnp.bfloat16)
    specs = sh.cache_pspecs(cfg, cache, M)
    wkv_spec = specs["layers"][0]["wkv"]
    assert wkv_spec[0] == "data" and wkv_spec[1] == "model"  # heads


def test_jit_with_specs_on_one_device():
    """End-to-end: sharded jit runs on the single local device."""
    cfg = get_config("yi-6b", smoke=True)
    mesh = _mesh_1dev()
    params = init_params(jax.random.PRNGKey(0), cfg)
    pshape = jax.eval_shape(lambda: params)
    specs = param_pspecs(cfg, pshape, mesh)
    from repro.models.model import forward

    with mesh:
        from repro.distributed.sharding import to_named_sharding
        out = jax.jit(
            lambda p, t: forward(p, cfg, t)[0],
            in_shardings=(to_named_sharding(mesh, specs), None),
        )(params, jnp.zeros((2, 8), jnp.int32))
    assert out.shape == (2, 8, cfg.vocab_size)
