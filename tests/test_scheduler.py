"""Deterministic scheduler-simulation tests (repro.core.scheduler).

Seeded Poisson arrival traces replay through the continuous-batching
serve loop and must prove the scheduler's three contracts:

  * liveness — every request finishes and admission wait is bounded
    (FCFS + preempted-to-front means the queue head cannot starve);
  * determinism — a request's token/logprob stream is bitwise
    independent of arrival interleaving, batch composition and
    preemption/replay (position-keyed per-row sampling);
  * parity — continuous serving and the synchronous batch baseline
    produce identical per-request outputs, with `lifecycle_guard`
    armed and zero violations.

All runs use the virtual round clock, so the suite is exactly
reproducible on any host.
"""
import random

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.guard import hot_path_guard
from repro.core.lifecycle import lifecycle_guard
from repro.core.scheduler import Request, Scheduler, poisson_trace
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import init_params

pytestmark = pytest.mark.serve

TOK = ByteTokenizer()
SYS = "You are a helpful math assistant. Answer concisely."
TREE_CFG = TreeConfig(max_depth=4, segment_len=8, max_width=4,
                      branch_factor=2, init_divergence_low=2,
                      init_divergence_high=2, temperature=0.9)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2.5-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(model, num_pages=256):
    cfg, params = model
    return TreeEngine(params, cfg, TREE_CFG, num_pages=num_pages,
                      page_size=8, max_slots=16, max_queries=8,
                      max_prompt_len=128, seed=0)


def _prompts(n):
    return [TOK.encode(SYS + f" What is {i}+{i}?", bos=True)
            for i in range(n)]


def _requests(prompts, arrivals, max_new=12):
    return [Request(rid=i, prompt=p, max_new_tokens=max_new, arrival=a)
            for i, (p, a) in enumerate(zip(prompts, arrivals))]


def _streams(reqs):
    return [(r.out_tokens, r.out_logprobs) for r in reqs]


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

def test_poisson_trace_is_seeded_and_monotone():
    a = poisson_trace(random.Random(123), 50, rate=2.0)
    b = poisson_trace(random.Random(123), 50, rate=2.0)
    c = poisson_trace(random.Random(124), 50, rate=2.0)
    assert a == b and a != c
    assert all(x < y for x, y in zip(a, a[1:]))
    assert len(a) == 50 and a[0] > 0.0


# ---------------------------------------------------------------------------
# liveness: everything finishes, admission wait is bounded
# ---------------------------------------------------------------------------

def test_poisson_replay_no_starvation(model):
    """8 requests through 2 slots: every request finishes, and no
    request waits longer than the drain time of the queue ahead of it
    (FCFS bound: ceil(N / max_running) * rounds-per-request)."""
    prompts = _prompts(8)
    arrivals = poisson_trace(random.Random(42), len(prompts), rate=1.0)
    reqs = _requests(prompts, arrivals, max_new=8)
    sched = Scheduler(_engine(model), mode="continuous", max_running=2,
                      base_seed=3)
    report = sched.run(reqs)
    assert report.finished == len(reqs)
    assert all(r.state == "finished" for r in reqs)
    assert all(r.finish_reason in ("eos", "length") for r in reqs)
    # rounds per request <= ceil((prompt+gen)/l) + 1; 4 waves of 2
    per_req = -(-(len(prompts[0]) + 8) // sched.seg_len) + 1
    assert report.max_admission_wait <= 4 * per_req
    assert report.rounds < 200


# ---------------------------------------------------------------------------
# determinism: arrival interleaving, preemption replay
# ---------------------------------------------------------------------------

def test_outputs_bitwise_independent_of_arrival_interleaving(model):
    """The same requests under three different arrival patterns (burst,
    Poisson, widely spaced) produce bitwise-identical per-request
    streams — batch composition never leaks into a row."""
    prompts = _prompts(6)
    ref = None
    for arrivals in ([0.0] * 6,
                     poisson_trace(random.Random(9), 6, rate=0.7),
                     [4.0 * i for i in range(6)]):
        reqs = _requests(prompts, arrivals)
        sched = Scheduler(_engine(model), mode="continuous",
                          max_running=4, base_seed=7)
        report = sched.run(reqs)
        assert report.finished == len(reqs)
        if ref is None:
            ref = _streams(reqs)
        else:
            assert _streams(reqs) == ref      # bitwise, tokens + logprobs


def test_preemption_replay_is_bitwise(model):
    """A pool too small for the full working set forces preemption;
    replayed requests regenerate their dropped pending draws bitwise
    (absolute-position sampling keys), so outputs match an ample-pool
    run exactly."""
    prompts = _prompts(6)
    arrivals = poisson_trace(random.Random(5), 6, rate=0.8)

    ample = _requests(prompts, arrivals)
    Scheduler(_engine(model, num_pages=256), mode="continuous",
              max_running=4, base_seed=7).run(ample)

    tight = _requests(prompts, arrivals)
    sched = Scheduler(_engine(model, num_pages=24), mode="continuous",
                      max_running=4, base_seed=7)
    report = sched.run(tight)
    assert report.preemptions > 0             # the pool really was tight
    assert report.finished == len(tight)
    assert _streams(tight) == _streams(ample)


def test_radix_reuse_does_not_change_outputs(model):
    """Cross-request KV reuse is a pure optimization: staggered arrivals
    let later requests hit the radix (reuse > 0), and their streams stay
    bitwise identical to a radix-off run."""
    prompts = _prompts(6)
    arrivals = [20.0 * i for i in range(6)]   # arrive after predecessors

    base = _requests(prompts, arrivals)
    Scheduler(_engine(model), mode="continuous", max_running=4,
              base_seed=7, radix=False).run(base)

    cached = _requests(prompts, arrivals)
    sched = Scheduler(_engine(model), mode="continuous", max_running=4,
                      base_seed=7, radix=True)
    report = sched.run(cached)
    assert report.reuse_ratio > 0.3           # shared SYS prefix hits
    assert all(r.cached_len > 0 for r in cached[1:])
    assert _streams(cached) == _streams(base)


# ---------------------------------------------------------------------------
# parity: continuous vs synchronous, under the armed lifecycle guard
# ---------------------------------------------------------------------------

def test_continuous_vs_sync_parity(model):
    """The acceptance invariant: per-request token/logprob parity
    between continuous serving and the synchronous batch baseline, with
    `lifecycle_guard` armed over both runs and zero violations.  Tokens
    must match exactly; logprobs within 1e-5 (they are bitwise here)."""
    prompts = _prompts(6)
    with lifecycle_guard() as tracker:
        cont = _requests(prompts,
                         poisson_trace(random.Random(11), 6, rate=0.8))
        rep_c = Scheduler(_engine(model), mode="continuous",
                          max_running=4, base_seed=7).run(cont)
        sync = _requests(prompts, [0.0] * 6)
        rep_s = Scheduler(_engine(model), mode="sync", max_running=4,
                          base_seed=7, radix=False).run(sync)
    assert tracker.violations == []
    assert rep_c.finished == rep_s.finished == len(prompts)
    for a, b in zip(cont, sync):
        assert a.out_tokens == b.out_tokens
        assert a.finish_reason == b.finish_reason
        np.testing.assert_allclose(a.out_logprobs, b.out_logprobs,
                                   atol=1e-5)


def test_warm_serve_zero_violations_zero_compiles(model):
    """After a cold round compiles the (Rb, l) serve bucket, a whole
    warm serve run performs no un-annotated transfer and no
    recompilation — the continuous loop reuses ONE compiled shape for
    its entire lifetime."""
    prompts = _prompts(5)
    eng = _engine(model)
    sched = Scheduler(eng, mode="continuous", max_running=4, base_seed=7)
    warm = _requests(_prompts(2), [0.0, 0.0], max_new=4)
    sched.run(warm)                           # cold: compiles the bucket
    with hot_path_guard(use_transfer_guard=False) as rep:
        sched2 = Scheduler(eng, mode="continuous", max_running=4,
                           base_seed=7)
        report = sched2.run(_requests(prompts, [0.0] * 5))
    assert report.finished == len(prompts)
    assert rep.violations == []
    assert rep.compiles == 0
    assert "serve-pack" in rep.annotated_reasons
    assert "serve-segment" in rep.annotated_reasons
