"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device
(the 512-device placeholder platform is exclusively dryrun.py's)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
