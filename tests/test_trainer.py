"""RL trainer integration: all three paper modes run a full step; loss and
advantages are wired correctly; BC warmup reduces CE loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainConfig, TreeConfig
from repro.core.loss import dapo_pg_loss, entropy_from_logits
from repro.rl.trainer import RLTrainer, TrainerMode

ENGINE_KW = dict(num_pages=512, page_size=16, max_slots=32, max_queries=16,
                 max_prompt_len=256)


def _trainer(mode, advantage="treepo", seed=0):
    cfg = get_config("qwen2.5-7b", smoke=True)
    tc = TreeConfig(max_depth=4, segment_len=16, max_width=4,
                    branch_factor=2, init_divergence_low=2,
                    init_divergence_high=2, temperature=0.9)
    trc = TrainConfig(batch_size=2, group_size=4, oversample_factor=2,
                      max_resample_rounds=0, learning_rate=1e-3,
                      advantage_kind=advantage, reward_shaping=0.1)
    return RLTrainer(cfg, trc, tc, mode, seed=seed,
                     engine_kwargs=ENGINE_KW, min_difficulty=1,
                     max_difficulty=1)


def test_loss_clip_higher_asymmetry():
    """DAPO clip-higher: positive-advantage ratios clip at 1+eps_high."""
    lp_old = jnp.zeros((1, 4))
    adv = jnp.ones((1, 4))
    mask = jnp.ones((1, 4))
    lp_hi = jnp.full((1, 4), 0.5)  # ratio e^0.5 ~ 1.65 > 1.28
    loss_hi, m = dapo_pg_loss(lp_hi, lp_old, adv, mask,
                              clip_eps_low=0.2, clip_eps_high=0.28)
    assert float(loss_hi) == pytest.approx(-1.28, abs=1e-5)
    # negative advantage, ratio below 1-eps_low: min() keeps the clipped
    # (more pessimistic, more negative) branch: 0.8 * (-1)
    lp_lo = jnp.full((1, 4), -0.5)
    loss_lo, _ = dapo_pg_loss(lp_lo, lp_old, -adv, mask)
    assert float(loss_lo) == pytest.approx(0.8, abs=1e-5)


def test_entropy_from_logits_uniform():
    logits = jnp.zeros((1, 3, 7))
    mask = jnp.ones((1, 3))
    ent = float(entropy_from_logits(logits, mask))
    assert ent == pytest.approx(np.log(7), abs=1e-5)


def test_bc_warmup_reduces_loss():
    tr = _trainer(TrainerMode.TREEPO)
    first = None

    # capture initial CE by running one step with lr tiny? simpler: run two
    # warmups and compare reported losses
    m1 = tr.bc_warmup(steps=5, batch_size=4, lr=1e-3)
    m2 = tr.bc_warmup(steps=30, batch_size=4, lr=3e-3)
    assert m2["bc_loss"] < m1["bc_loss"]


@pytest.mark.parametrize("mode", [TrainerMode.GRPO, TrainerMode.GRPO_TREE,
                                  TrainerMode.TREEPO])
def test_train_step_all_modes(mode):
    tr = _trainer(mode)
    tr.bc_warmup(steps=25, batch_size=4, lr=3e-3)
    m = tr.train_step(num_queries=1)
    assert m["step"] == 1
    assert m["sample_model_tokens"] > 0
    # either a real update happened or dynamic sampling starved the batch
    assert ("loss" in m) or ("skipped" in m)
    if "loss" in m:
        assert np.isfinite(m["loss"])


def test_advantage_variants_run():
    for variant in ["treepo", "treepo_size_weighted",
                    "treepo_subgroup_reject", "treepo_no_root", "grpo"]:
        tr = _trainer(TrainerMode.TREEPO, advantage=variant, seed=1)
        tr.bc_warmup(steps=20, batch_size=4, lr=3e-3)
        m = tr.train_step(num_queries=1)
        assert ("loss" in m) or ("skipped" in m)


def test_build_batch_shapes_and_masks():
    tr = _trainer(TrainerMode.TREEPO)
    tr.bc_warmup(steps=20, batch_size=4, lr=3e-3)
    trees, eng = tr.rollout(2)
    batch = tr.build_batch(trees)
    if batch.tokens.shape[0] == 0:
        pytest.skip("dynamic sampling dropped everything (all-equal rewards)")
    N, L = batch.tokens.shape
    assert batch.response_mask.shape == (N, L)
    assert batch.logprobs_old.shape == (N, L)
    # logprobs only on response tokens
    assert (np.abs(batch.logprobs_old) * (1 - batch.response_mask)).sum() \
        == 0
    # advantages constant within each trajectory's response (before norm)
    for i in range(N):
        on = batch.advantages[i][batch.response_mask[i] > 0]
        if on.size:
            assert np.allclose(on, on[0])


def test_evaluate_returns_metrics():
    tr = _trainer(TrainerMode.TREEPO)
    ev = tr.evaluate(num_queries=2, k=2)
    assert set(ev) == {"maj_acc", "pass_any"}
    assert 0 <= ev["maj_acc"] <= 1
