"""Training-side hot path: mixed-depth branching budgets, fallback
segment-logprob inheritance, reward memoization, double-release
idempotency, new-vs-legacy build/update parity, packed-vs-unpacked
(sequence packing) build/update parity — including the seeded
all-11-arch sweep and the hybrid (SSM/RWKV) full-pipeline parity the
universal packer is gated on — and the donated rollout-logprobs buffer
aliasing regression."""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import TrainConfig, TreeConfig
from repro.core import advantage as adv_mod
from repro.core.branching import depth_budget, mixed_depth_budgets
from repro.core.engine import TreeEngine
from repro.core.sampler import SamplerReport, _branch_tree, _fallback_tree
from repro.core.tree import Path, QueryTree, Status
from repro.models.model import init_params
from repro.rl.trainer import RLTrainer, TrainerMode

ENGINE_KW = dict(num_pages=512, page_size=16, max_slots=32, max_queries=16,
                 max_prompt_len=256)


def _trainer(mode, advantage="treepo", seed=0, **train_kw):
    cfg = get_config("qwen2.5-7b", smoke=True)
    tc = TreeConfig(max_depth=4, segment_len=16, max_width=4,
                    branch_factor=2, init_divergence_low=2,
                    init_divergence_high=2, temperature=0.9)
    base = dict(batch_size=2, group_size=4, oversample_factor=2,
                max_resample_rounds=0, learning_rate=1e-3,
                advantage_kind=advantage, reward_shaping=0.1)
    base.update(train_kw)
    trc = TrainConfig(**base)
    return RLTrainer(cfg, trc, tc, mode, seed=seed,
                     engine_kwargs=ENGINE_KW, min_difficulty=1,
                     max_difficulty=1)


# ---------------------------------------------------------------------------
# mixed-depth branching budget
# ---------------------------------------------------------------------------

class _FakeEngine:
    """Just enough engine surface for host-side _branch/_fallback units."""

    n_prefix = 0

    def __init__(self):
        self.released = []

    def fork_paths(self, parents):
        return [None] * len(parents)

    def fork_from_prefix(self, src_ep, prefix_position, replay):
        return None

    def release_path(self, ep):
        self.released.append(ep)


def _tc(**kw):
    base = dict(max_depth=6, segment_len=8, max_width=8, branch_factor=2,
                init_divergence_low=2, init_divergence_high=2)
    base.update(kw)
    return TreeConfig(**base)


def test_mixed_depth_budgets_single_depth_reduces_to_depth_budget():
    tc = _tc()
    for depth in range(5):
        for finished in (0, 3, 8):
            got = mixed_depth_budgets(tc, [depth] * 3, 2, finished)
            assert got == {depth: depth_budget(tc, depth, 2, finished)}


def test_mixed_depth_budgets_every_group_keeps_a_continuation():
    """A fresh shallow fallback child must not be starved by a deeper
    group's fan-out while width remains."""
    tc = _tc()
    got = mixed_depth_budgets(tc, [3, 1], 2, 6)   # cap = 2
    assert got[3] >= 1 and got[1] >= 1


def _leaf_path(depth, seg_len=8, reason="eos"):
    bounds = [seg_len * k for k in range(depth + 1)]
    p = Path(query_idx=0, depth=depth, node_ids=list(range(depth + 1)),
             tokens=list(range(bounds[-1])), logprobs=[-0.1] * bounds[-1],
             seg_bounds=bounds,
             seg_logprobs=[-float(k + 1) for k in range(depth)],
             seg_logprob=-float(depth))
    p.status = Status.LEAF
    p.finish_reason = reason
    return p


def test_branch_tree_mixed_depth_budget_regression():
    """Two fallback children at different fork depths j: each must be
    branched under its OWN depth's budget, not active[0]'s.

    Regression: the old code read tree.active[0].depth (here the shallow
    path) and applied depth_budget(1) == 4 to the whole round, leaving
    the depth-3 path underbudgeted; per-depth budgets give the deep
    group its full remaining allowance."""
    tc = _tc()
    tree = QueryTree(query_idx=0, prompt_tokens=[1], target="x")
    tree.init_div = 2
    tree.finished = [_leaf_path(4), _leaf_path(4)]   # 2 trajectories -> cap 6
    shallow = Path(query_idx=0, depth=1, node_ids=[0, 1],
                   tokens=list(range(8)), logprobs=[-0.1] * 8,
                   seg_bounds=[0, 8], seg_logprobs=[-1.0],
                   seg_logprob=-1.0)
    deep = Path(query_idx=0, depth=3, node_ids=[0, 1, 2, 3],
                tokens=list(range(24)), logprobs=[-0.1] * 24,
                seg_bounds=[0, 8, 16, 24],
                seg_logprobs=[-1.0, -2.0, -3.0], seg_logprob=-3.0)
    tree.active = [shallow, deep]    # shallow FIRST: the old failure mode
    eng = _FakeEngine()
    _branch_tree(tree, tc, eng, random.Random(0), 0.0)
    depths = sorted(p.depth for p in tree.active)
    # cap = 8 - 2 = 6: depth-3 group gets 1 + min(2*2^3 - 1, 4) = 5,
    # depth-1 group keeps its guaranteed single continuation
    assert len(tree.active) == 6
    assert depths == [1, 3, 3, 3, 3, 3]
    # nothing was pruned: both survived under their own budgets
    assert all(p.status == Status.LEAF for p in tree.finished)


def test_fallback_child_inherits_prefix_segment_logprob():
    """The fallback child's heuristic signal must be the mean logprob of
    prefix segment j — not the source leaf's final-segment value."""
    tc = _tc(max_width=4)
    tree = QueryTree(query_idx=0, prompt_tokens=[5, 6], target="x")
    src = _leaf_path(4)
    tree.finished = [src]
    report = SamplerReport()
    _fallback_tree(tree, tc, _FakeEngine(), random.Random(0),
                   guard=10_000, n_prefix=0, report=report)
    assert report.num_fallbacks == 3      # max_width - 1 children
    depths = set()
    for child in tree.active:
        j = child.depth
        depths.add(j)
        assert child.seg_logprobs == src.seg_logprobs[:j]
        assert child.seg_logprob == src.seg_logprobs[j - 1]
        assert child.seg_logprob != src.seg_logprob or j == src.depth
    assert len(depths) >= 2               # mixed-depth refill really occurs


# ---------------------------------------------------------------------------
# engine release idempotency
# ---------------------------------------------------------------------------

def test_release_path_idempotent():
    cfg = get_config("yi-6b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = TreeEngine(params, cfg, _tc(), num_pages=256, page_size=8,
                     max_slots=16, max_queries=4, max_prompt_len=32)
    base_pages = eng.kv.pool.pages_in_use   # engine-reserved scratch pages
    [root] = eng.prefill_queries([[1, 2, 3, 4, 5]])
    child = eng.fork_path(root)
    eng.release_path(child)
    pages_after_first = eng.kv.pool.pages_in_use
    assert child.released
    eng.release_path(child)               # double release: no-op
    assert eng.kv.pool.pages_in_use == pages_after_first
    eng.release_path(root)
    pages_final = eng.kv.pool.pages_in_use
    eng.release_path(root)
    assert eng.kv.pool.pages_in_use == pages_final == base_pages


# ---------------------------------------------------------------------------
# reward memoization
# ---------------------------------------------------------------------------

def test_reward_scored_exactly_once_per_trajectory():
    tr = _trainer(TrainerMode.TREEPO)
    tr.bc_warmup(steps=15, batch_size=4, lr=3e-3)
    calls = []
    orig = tr._score_path
    tr._score_path = lambda tree, path: (calls.append(id(path)),
                                         orig(tree, path))[1]
    trees, _ = tr.rollout(2)
    leaves = sum(1 for t in trees for p in t.finished
                 if p.status != Status.FAILED)
    assert len(calls) == leaves           # scored at finish time only
    assert len(set(calls)) == len(calls)  # ... and once per path
    # every downstream consumer hits the memo, never the reward fn
    tr._count_kept(trees)
    tr._count_kept(trees)
    tr.build_batch(trees)
    assert len(calls) == leaves
    for t in trees:
        for p in t.finished:
            assert p.reward is not None
            if p.status == Status.FAILED:
                assert p.reward == 0.0


# ---------------------------------------------------------------------------
# new vs legacy parity
# ---------------------------------------------------------------------------

def _rollout_with_batch(tr, n=2):
    tr.bc_warmup(steps=20, batch_size=4, lr=3e-3)
    trees, _ = tr.rollout(n)
    batch = tr.build_batch(trees)
    if batch.tokens.shape[0] == 0:
        pytest.skip("dynamic sampling dropped everything")
    return trees, batch


@pytest.mark.parametrize("mode,advantage", [
    (TrainerMode.TREEPO, "treepo"),
    (TrainerMode.TREEPO, "treepo_subgroup_reject"),
    (TrainerMode.GRPO_TREE, "treepo"),   # grpo advantage over tree groups
])
def test_build_batch_matches_legacy(mode, advantage):
    tr = _trainer(mode, advantage=advantage, seed=3)
    trees, batch = _rollout_with_batch(tr)
    legacy = tr.build_batch_legacy(trees)
    np.testing.assert_array_equal(batch.tokens, legacy.tokens)
    np.testing.assert_array_equal(batch.response_mask,
                                  legacy.response_mask)
    np.testing.assert_allclose(batch.logprobs_old, legacy.logprobs_old)
    np.testing.assert_allclose(batch.rewards, legacy.rewards)
    dense = batch.advantages
    if tr._use_global_norm:
        dense = np.asarray(adv_mod.global_normalize(
            jnp.asarray(dense), jnp.asarray(batch.response_mask)))
    np.testing.assert_allclose(dense, legacy.advantages, atol=1e-5)
    # the compact pack ships strictly fewer bytes than the dense one
    assert batch.host_pack_bytes < legacy.host_pack_bytes


def test_update_matches_legacy_k_epochs():
    """The single scanned K-epoch jitted update must land on the same
    params as the legacy one-dispatch-per-epoch loop."""
    tr = _trainer(TrainerMode.TREEPO, seed=5, ppo_epochs=2)
    trees, batch = _rollout_with_batch(tr)
    legacy_batch = tr.build_batch_legacy(trees)
    snap = jax.tree.map(np.array, (tr.params, tr.opt_state))

    m_new = tr.update(batch)
    new_params = jax.tree.map(np.array, tr.params)

    tr.params, tr.opt_state = jax.tree.map(jnp.asarray, snap)
    m_old = tr.update_legacy(legacy_batch)
    old_params = jax.tree.map(np.array, tr.params)

    assert np.isfinite(m_new["loss"]) and np.isfinite(m_old["loss"])
    np.testing.assert_allclose(m_new["loss"], m_old["loss"],
                               rtol=1e-4, atol=1e-6)
    flat_new = jax.tree.leaves(new_params)
    flat_old = jax.tree.leaves(old_params)
    for a, b in zip(flat_new, flat_old):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)
    # one compiled update per (N, L) bucket
    assert len(tr._update_fns) == 1


def test_packed_build_matches_unpacked():
    """Sequence packing must preserve the trajectory set exactly: same
    token/logprob/advantage content per trajectory, same rewards, same
    queries — only the row layout (and the pad fraction) changes."""
    tr = _trainer(TrainerMode.TREEPO, seed=3)
    trees, batch = _rollout_with_batch(tr)
    packed = tr.build_batch_packed(trees)
    N = batch.tokens.shape[0]
    assert packed.num_trajectories == N
    assert packed.num_queries == batch.num_queries
    np.testing.assert_allclose(sorted(packed.rewards),
                               sorted(batch.rewards))
    # per-trajectory content parity: match each unpacked row to a packed
    # segment by (prompt_len, resp_len, advantage)
    sid = packed.segment_ids
    seg_tot = packed.seg_prompt_lens + packed.seg_resp_lens
    seg_start = np.cumsum(seg_tot, axis=1) - seg_tot
    matched = np.zeros(packed.seg_prompt_lens.shape, bool)
    for i in range(N):
        n_p, n_r = int(batch.prompt_lens[i]), int(batch.resp_lens[i])
        found = False
        for r in range(packed.tokens.shape[0]):
            for s in range(packed.seg_prompt_lens.shape[1]):
                if matched[r, s] or \
                        packed.seg_prompt_lens[r, s] != n_p or \
                        packed.seg_resp_lens[r, s] != n_r:
                    continue
                off = int(seg_start[r, s])
                if not np.array_equal(packed.tokens[r, off: off + n_p + n_r],
                                      batch.tokens[i, : n_p + n_r]):
                    continue
                if not np.isclose(packed.seg_adv[r, s], batch.adv_traj[i]):
                    continue
                np.testing.assert_allclose(
                    packed.logprobs_old[r, off: off + n_p + n_r],
                    batch.logprobs_old[i, : n_p + n_r])
                assert (sid[r, off: off + n_p + n_r] == s).all()
                matched[r, s] = True
                found = True
                break
            if found:
                break
        assert found, f"unpacked trajectory {i} missing from the pack"
    # packing at equal bucket length can only reduce (or keep) pad waste
    assert packed.tokens.shape[1] == batch.tokens.shape[1]
    assert packed.padded_token_fraction <= batch.padded_token_fraction


def test_packed_update_matches_unpacked():
    """The packed K-epoch update (segment-masked attention, per-segment
    RoPE resets, on-device mask/advantage derivation) must land on the
    same loss and parameters as the unpacked oracle."""
    tr = _trainer(TrainerMode.TREEPO, seed=5, ppo_epochs=2)
    trees, batch = _rollout_with_batch(tr)
    packed = tr.build_batch_packed(trees)
    snap = jax.tree.map(np.array, (tr.params, tr.opt_state))

    m_unpacked = tr.update(batch)
    unpacked_params = jax.tree.map(np.array, tr.params)

    tr.params, tr.opt_state = jax.tree.map(jnp.asarray, snap)
    m_packed = tr.update_packed(packed)
    packed_params = jax.tree.map(np.array, tr.params)

    assert np.isfinite(m_packed["loss"])
    np.testing.assert_allclose(m_packed["loss"], m_unpacked["loss"],
                               rtol=1e-4, atol=1e-6)
    for key in ("pg_loss", "ratio_mean", "adv_mean"):
        np.testing.assert_allclose(m_packed[key], m_unpacked[key],
                                   rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(packed_params),
                    jax.tree.leaves(unpacked_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)
    # one compiled packed update per (N, L, S) bucket
    assert len(tr._packed_update_fns) == 1


def test_packed_train_step_end_to_end():
    """TrainConfig.pack_sequences routes train_step through the packed
    build/update pair and reports the pad-fraction metric."""
    tr = _trainer(TrainerMode.TREEPO, seed=3, pack_sequences=True)
    tr.bc_warmup(steps=15, batch_size=4, lr=3e-3)
    m = tr.train_step()
    assert m["step"] == 1
    assert "padded_token_fraction" in m
    if "loss" in m:                        # batch may be starved
        assert np.isfinite(m["loss"])
        assert 0.0 <= m["padded_token_fraction"] < 1.0


# ---------------------------------------------------------------------------
# universal packing: seeded packed-vs-unpacked parity across ALL archs
# ---------------------------------------------------------------------------

def _synthetic_layouts(cfg, seed):
    """One deterministic trajectory set in both layouts.

    Mixed-depth-style lengths so FFD really bins (3 trajectories -> 2
    packed rows at the same bucket length); identical per-row modality
    stubs where the arch needs them (shared conditioning is the packed
    convention).  Returns (dense_batch, packed_batch)."""
    from repro.rl.packing import bucket_segments, first_fit_decreasing

    rng = np.random.default_rng(seed)
    trajs = [(3, 6), (2, 9), (4, 3)]            # (prompt_len, resp_len)
    L = 16
    N = len(trajs)
    rows = []
    for n_p, n_r in trajs:
        toks = rng.integers(1, cfg.vocab_size, n_p + n_r).astype(np.int32)
        lps = (-rng.uniform(0.1, 2.0, n_r)).astype(np.float32)
        adv = float(rng.normal())
        rows.append((toks, n_p, n_r, lps, adv))

    tokens = np.zeros((N, L), np.int32)
    rmask = np.zeros((N, L), np.float32)
    lp_old = np.zeros((N, L), np.float32)
    advs = np.zeros((N, L), np.float32)
    for i, (toks, n_p, n_r, lps, adv) in enumerate(rows):
        tokens[i, : n_p + n_r] = toks
        rmask[i, n_p: n_p + n_r] = 1.0
        lp_old[i, n_p: n_p + n_r] = lps
        advs[i, n_p: n_p + n_r] = adv
    dense = {"tokens": jnp.asarray(tokens),
             "response_mask": jnp.asarray(rmask),
             "logprobs_old": jnp.asarray(lp_old),
             "advantages": jnp.asarray(advs)}

    totals = [n_p + n_r for _, n_p, n_r, _, _ in rows]
    packing_rows = first_fit_decreasing(totals, L)
    assert len(packing_rows) < N                # FFD really binned
    Np = len(packing_rows)
    S = bucket_segments(max(len(r) for r in packing_rows))
    ptoks = np.zeros((Np, L), np.int32)
    plp = np.zeros((Np, L), np.float32)
    seg_p = np.zeros((Np, S), np.int32)
    seg_r = np.zeros((Np, S), np.int32)
    seg_a = np.zeros((Np, S), np.float32)
    for i, members in enumerate(packing_rows):
        off = 0
        for s, j in enumerate(members):
            toks, n_p, n_r, lps, adv = rows[j]
            ptoks[i, off: off + n_p + n_r] = toks
            plp[i, off + n_p: off + n_p + n_r] = lps
            seg_p[i, s], seg_r[i, s], seg_a[i, s] = n_p, n_r, adv
            off += n_p + n_r
    packed = {"tokens": jnp.asarray(ptoks),
              "logprobs_old": jnp.asarray(plp),
              "seg_prompt_lens": jnp.asarray(seg_p),
              "seg_resp_lens": jnp.asarray(seg_r),
              "seg_adv": jnp.asarray(seg_a)}

    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        pre = rng.normal(size=(1, cfg.frontend.num_prefix_tokens,
                               cfg.frontend.embed_dim)).astype(np.float32)
        dense["prefix_embeds"] = jnp.asarray(np.repeat(pre, N, axis=0))
        packed["prefix_embeds"] = jnp.asarray(np.repeat(pre, Np, axis=0))
    if cfg.encoder is not None:
        frames = rng.normal(size=(1, 8, cfg.encoder.d_model)).astype(
            np.float32)
        dense["enc_frames"] = jnp.asarray(np.repeat(frames, N, axis=0))
        packed["enc_frames"] = jnp.asarray(np.repeat(frames, Np, axis=0))
    return dense, packed


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_packed_vs_unpacked_update_parity_all_archs(arch):
    """One seeded PG update (the train_step's update half, shared with
    the pjit train_4k case) in both layouts for every architecture —
    attention, MLA, MoE, sliding-window, SSM/RWKV hybrids, encoder and
    vision-prefix — must land on the same loss and the same parameters
    (<= 1e-3): segment-masked attention + per-segment position and
    recurrent-state resets make packing exact everywhere.

    The MoE aux loss is zeroed: it is batch-composition-dependent by
    construction (pad tokens route too), so it legitimately differs
    between layouts; routing itself still runs in fwd+bwd."""
    from repro.models.model import init_params
    from repro.optim import adamw_init
    from repro.rl.update import make_ppo_update

    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, aux_loss_coef=0.0))
    tc = TrainConfig(ppo_epochs=1, learning_rate=1e-3)
    dense, packed = _synthetic_layouts(cfg, seed=17)
    params = init_params(jax.random.PRNGKey(2), cfg)
    opt = adamw_init(params)

    upd_dense = make_ppo_update(cfg, tc)
    upd_packed = make_ppo_update(cfg, tc, packed=True)
    step = jnp.asarray(0, jnp.int32)
    p1, _, m1 = upd_dense(params, opt, dense, step)
    p2, _, m2 = upd_packed(params, opt, packed, step)

    assert np.isfinite(float(m1["loss"]))
    np.testing.assert_allclose(float(m2["loss"]), float(m1["loss"]),
                               rtol=1e-4, atol=1e-6)
    for key in ("pg_loss", "ratio_mean", "adv_mean"):
        np.testing.assert_allclose(float(m2[key]), float(m1[key]),
                                   rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "rwkv6-7b"])
def test_full_train_pipeline_packed_vs_unpacked_hybrid(arch):
    """The full trainer pipeline (one shared seeded rollout through the
    real engine -> memoized rewards -> DAPO filter -> batched advantage
    -> pack -> jitted K-epoch update) must land on the same loss and
    params (<= 1e-3, the packing acceptance bound) in both layouts for
    the SSM/RWKV hybrids — the archs the dense layout previously gated.
    Rewards are injected (seeded) so the untrained policy still yields
    non-degenerate groups.  One ppo epoch (the K-epoch scan is pinned by
    the qwen parity tests) so the reported loss is computed from
    identical params; params still get atol 1e-3 rather than the
    synthetic sweep's 1e-5 — FFD reorders rows, and Adam amplifies the
    resulting f32 reduction-order noise on near-zero gradient entries
    to O(lr) regardless of layout correctness (the multi-segment
    content itself is pinned by the all-arch sweep and the packing unit
    tests).  The MoE aux loss (jamba) is zeroed for the same reason as
    in the sweep: pad tokens route too, so the aux term is
    batch-composition-dependent by construction."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, aux_loss_coef=0.0))
    tc = TreeConfig(max_depth=3, segment_len=8, max_width=4,
                    branch_factor=2, init_divergence_low=2,
                    init_divergence_high=2, temperature=0.9)
    trc = TrainConfig(batch_size=2, group_size=4, oversample_factor=1,
                      max_resample_rounds=0, learning_rate=5e-4,
                      ppo_epochs=1, pack_sequences=True)
    tr = RLTrainer(cfg, trc, tc, TrainerMode.TREEPO, seed=11,
                   engine_kwargs=dict(num_pages=256, page_size=8,
                                      max_slots=16, max_queries=8,
                                      max_prompt_len=128),
                   min_difficulty=1, max_difficulty=1)
    trees, _ = tr.rollout(2)
    rng = np.random.default_rng(11)
    for t in trees:
        for p in t.finished:
            p.reward = round(float(rng.uniform()), 3)   # seeded memo
    batch = tr.build_batch(trees)
    assert batch.tokens.shape[0] > 0
    packed = tr.build_batch_packed(trees)
    assert packed.num_trajectories == batch.tokens.shape[0]
    assert packed.tokens.shape[0] <= batch.tokens.shape[0]
    snap = jax.tree.map(np.array, (tr.params, tr.opt_state))

    m_unpacked = tr.update(batch)
    unpacked_params = jax.tree.map(np.array, tr.params)

    tr.params, tr.opt_state = jax.tree.map(jnp.asarray, snap)
    m_packed = tr.update_packed(packed)
    packed_params = jax.tree.map(np.array, tr.params)

    assert np.isfinite(m_packed["loss"])
    np.testing.assert_allclose(m_packed["loss"], m_unpacked["loss"],
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(packed_params),
                    jax.tree.leaves(unpacked_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-3)
    # both bucketed updates donated their rollout-logprobs plane
    assert len(tr._donated_lp_buckets) == 2


def test_packed_bc_warmup_matches_dense():
    """The packed BC warmup scores the same token set with the same
    normalization as the dense one: from identical init, one step of
    each lands on the same loss (the generator is re-seeded)."""
    tr1 = _trainer(TrainerMode.TREEPO, seed=9)
    m1 = tr1.bc_warmup(steps=3, batch_size=8, lr=1e-3, packed=False)
    tr2 = _trainer(TrainerMode.TREEPO, seed=9)
    m2 = tr2.bc_warmup(steps=3, batch_size=8, lr=1e-3, packed=True)
    assert m2["bc_packed"] == 1.0
    np.testing.assert_allclose(m2["bc_loss"], m1["bc_loss"],
                               rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(tr2.params),
                    jax.tree.leaves(tr1.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# per-bucket donation of the rollout logprobs buffer
# ---------------------------------------------------------------------------

def _assert_aliases_logprobs(tr, lowered, lp_bytes):
    """alias_size_in_bytes must cover params + opt-state + the donated
    rollout-logprobs plane — the compile-time proof the executable
    reuses the buffer in place (runtime pointer identity is an
    allocator detail and is deliberately not asserted)."""
    ma = lowered.compile().memory_analysis()
    if ma is None or not hasattr(ma, "alias_size_in_bytes"):
        pytest.skip("backend exposes no memory analysis")
    params_bytes = sum(a.nbytes for a in jax.tree.leaves(tr.params))
    opt_bytes = sum(a.nbytes for a in jax.tree.leaves(tr.opt_state))
    assert ma.alias_size_in_bytes >= params_bytes + opt_bytes + lp_bytes


def test_update_donates_rollout_logprobs_buffer():
    """Mirror of the PR 3 params/opt aliasing check, extended to the
    rollout-logprobs plane: the compiled (N, L) bucket update aliases
    the donated f32 plane into its output, and calling it consumes
    (deletes) the donated input."""
    tr = _trainer(TrainerMode.TREEPO, seed=1)
    Nb, L = 4, 64
    fn = tr._get_update_fn(Nb, L)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, tr.cfg.vocab_size, (Nb, L)),
                         jnp.int32)
    plens = jnp.asarray(np.full((Nb,), 4), jnp.int32)
    rlens = jnp.asarray(np.full((Nb,), 8), jnp.int32)
    lp = jnp.asarray(rng.normal(size=(Nb, L)).astype(np.float32))
    adv = jnp.asarray(rng.normal(size=(Nb,)).astype(np.float32))
    step = jnp.asarray(0, jnp.int32)

    lowered = fn.lower(tr.params, tr.opt_state, tokens, plens, rlens,
                       np.zeros((Nb, L), np.float32), adv, step)
    _assert_aliases_logprobs(tr, lowered, Nb * L * 4)

    tr.params, tr.opt_state, lp_out, _ = fn(
        tr.params, tr.opt_state, tokens, plens, rlens, lp, adv, step)
    assert lp.is_deleted()                       # donation consumed
    assert lp_out.shape == (Nb, L) and lp_out.dtype == jnp.float32


def test_packed_update_donates_rollout_logprobs_buffer():
    """Same aliasing contract for the packed (N, L, S) bucket update."""
    tr = _trainer(TrainerMode.TREEPO, seed=1)
    Nb, L, S = 4, 64, 2
    fn = tr._get_packed_update_fn(Nb, L, S)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, tr.cfg.vocab_size, (Nb, L)),
                         jnp.int32)
    seg_p = jnp.asarray(np.tile([4, 3], (Nb, 1)), jnp.int32)
    seg_r = jnp.asarray(np.tile([8, 6], (Nb, 1)), jnp.int32)
    seg_a = jnp.asarray(rng.normal(size=(Nb, S)).astype(np.float32))
    lp = jnp.asarray(rng.normal(size=(Nb, L)).astype(np.float32))
    step = jnp.asarray(0, jnp.int32)

    lowered = fn.lower(tr.params, tr.opt_state, tokens,
                       np.zeros((Nb, L), np.float32), seg_p, seg_r,
                       seg_a, step)
    _assert_aliases_logprobs(tr, lowered, Nb * L * 4)

    tr.params, tr.opt_state, lp_out, _ = fn(
        tr.params, tr.opt_state, tokens, lp, seg_p, seg_r, seg_a, step)
    assert lp.is_deleted()
    assert lp_out.shape == (Nb, L) and lp_out.dtype == jnp.float32


def test_update_pads_batch_rows_without_changing_loss():
    """Row padding to the bucket size must be invisible to the loss (the
    padded rows carry an empty response mask)."""
    import dataclasses as dc

    from repro.data.tokenizer import ByteTokenizer
    from repro.rl.trainer import _bucket_rows

    tr = _trainer(TrainerMode.TREEPO, seed=7)
    trees, batch = _rollout_with_batch(tr)
    N = batch.tokens.shape[0]
    assert _bucket_rows(N) >= N
    snap = jax.tree.map(np.array, (tr.params, tr.opt_state))
    m1 = tr.update(batch)
    tr.params, tr.opt_state = jax.tree.map(jnp.asarray, snap)
    # append explicit dead rows (forces the next bucket up): same loss
    pad = _bucket_rows(N)
    bigger = dc.replace(
        batch,
        tokens=np.concatenate(
            [batch.tokens,
             np.full((pad, batch.tokens.shape[1]), ByteTokenizer.PAD,
                     np.int32)]),
        prompt_lens=np.concatenate(
            [batch.prompt_lens, np.zeros((pad,), np.int32)]),
        resp_lens=np.concatenate(
            [batch.resp_lens, np.zeros((pad,), np.int32)]),
        logprobs_old=np.concatenate(
            [batch.logprobs_old,
             np.zeros((pad, batch.logprobs_old.shape[1]), np.float32)]),
        adv_traj=np.concatenate(
            [batch.adv_traj, np.zeros((pad,), np.float32)]),
        rewards=np.concatenate(
            [batch.rewards, np.zeros((pad,), np.float32)]))
    m2 = tr.update(bigger)
    np.testing.assert_allclose(m1["loss"], m2["loss"],
                               rtol=1e-4, atol=1e-6)
    assert len(tr._update_fns) == 2       # two distinct (N, L) buckets
