"""Docs integrity: every intra-repo link / path / module reference in
README.md and docs/*.md must resolve (tools/check_docs.py).

The scan runs at *collection time* (module import) so a dangling
reference fails the tier-1 suite even under ``pytest --collect-only``
workflows; the assertions below report the specifics.
"""
import importlib.util
import os

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_spec = importlib.util.spec_from_file_location(
    "check_docs", os.path.join(_ROOT, "tools", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)

# collection-time scan: import-time work, surfaced by the tests below
_ERRORS = check_docs.collect_errors(_ROOT)
_FILES = check_docs._doc_files(_ROOT)


def test_docs_exist():
    names = {os.path.relpath(f, _ROOT) for f in _FILES}
    assert "README.md" in names
    assert os.path.join("docs", "architecture.md") in names
    assert os.path.join("docs", "benchmarks.md") in names


def test_docs_references_resolve():
    assert not _ERRORS, "\n".join(_ERRORS)


def test_checker_catches_dangling_refs(tmp_path):
    """The checker itself must flag a bad link, a bad path and a bad
    module reference (guards against the scan silently matching
    nothing)."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[gone](docs/missing.md) and `repro.no.such.module` and "
        "`src/repro/nope.py`\n")
    errors = check_docs.collect_errors(str(tmp_path))
    assert len(errors) == 3, errors
