"""Tree-engine correctness: paged decode == dense teacher-forced forward,
fork/COW/refcount lifecycle, fallback forks, EOS truncation."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine, sample_token_host
from repro.core.loss import token_logprobs_from_logits
from repro.core.sampler import sample_sequential, sample_trees
from repro.core.tree import Status
from repro.models.model import forward, init_params

TC = TreeConfig(max_depth=3, segment_len=8, max_width=3, branch_factor=2,
                init_divergence_low=2, init_divergence_high=2,
                temperature=1.0)


def _engine(arch, tc=TC, seed=0, **kw):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kwargs = dict(num_pages=256, page_size=8, max_slots=16, max_queries=4,
                  max_prompt_len=32, seed=seed)
    kwargs.update(kw)
    return cfg, params, TreeEngine(params, cfg, tc, **kwargs)


@pytest.mark.parametrize("arch", ["yi-6b", "gemma3-12b", "olmoe-1b-7b",
                                  "deepseek-v3-671b", "jamba-v0.1-52b",
                                  "rwkv6-7b"])
def test_engine_matches_dense_forward(arch):
    """Every trajectory's recorded logprobs == teacher-forced dense model."""
    cfg, params, eng = _engine(arch)
    prompts = [[1, 2, 3, 4, 5, 6, 7]]
    trees, _ = sample_trees(eng, prompts, ["x"], rng=random.Random(1))
    assert trees[0].num_trajectories >= TC.max_width
    for path in trees[0].finished[:2]:
        full = prompts[0] + path.tokens
        toks = jnp.asarray([full])
        logits, _ = forward(params, cfg, toks)
        lp = token_logprobs_from_logits(logits[:, :-1], toks[:, 1:])[0]
        ref = np.asarray(lp[len(prompts[0]) - 1:])
        got = np.asarray(path.logprobs)
        np.testing.assert_allclose(ref[: len(got)], got, atol=2e-3)


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v3-671b"])
def test_fused_and_split_pool_layouts_agree(arch):
    """The fused head-interleaved pool (default) and the legacy split
    K/V pools are pure layout choices: the same seeded rollout must emit
    identical token sequences and matching logprobs under both."""
    rollouts = []
    for fused in (True, False):
        _, _, eng = _engine(arch, fused_kv=fused)
        trees, _ = sample_trees(eng, [[1, 2, 3, 4, 5, 6, 7]], ["x"],
                                rng=random.Random(3))
        rollouts.append(sorted(
            (tuple(p.tokens), tuple(p.logprobs)) for p in trees[0].finished))
    assert len(rollouts[0]) == len(rollouts[1]) >= 1
    for (tok_f, lp_f), (tok_s, lp_s) in zip(*rollouts):
        assert tok_f == tok_s
        np.testing.assert_allclose(lp_f, lp_s, atol=2e-5)


def test_fork_shares_pages_and_cow():
    cfg, params, eng = _engine("yi-6b")
    [root] = eng.prefill_queries([[1, 2, 3, 4, 5]])  # 5 tokens, page=8
    pages_before = eng.kv.pool.pages_in_use
    child = eng.fork_path(root)
    # partial page -> COW: exactly one extra page
    assert eng.kv.pool.pages_in_use == pages_before + 1
    assert child.table[0] != root.table[0]
    # page-aligned fork: no COW
    eng.decode_segments([root])  # position 5 -> 13... still partial
    root2 = eng.prefill_queries([[1, 2, 3, 4, 5, 6, 7, 8]])[0]  # aligned
    pages_before = eng.kv.pool.pages_in_use
    child2 = eng.fork_path(root2)
    assert eng.kv.pool.pages_in_use == pages_before
    assert child2.table == root2.table


def test_release_returns_pages():
    cfg, params, eng = _engine("yi-6b")
    base = eng.kv.pool.pages_in_use
    trees, _ = sample_trees(eng, [[1, 2, 3]], ["x"], rng=random.Random(0))
    assert eng.kv.pool.pages_in_use == base  # all pages returned


def test_refcount_never_negative_and_slots_freed():
    cfg, params, eng = _engine("rwkv6-7b")
    slots_free = len(eng.kv.slots.free)
    trees, _ = sample_trees(eng, [[1, 2, 3], [4, 5]], ["x", "y"],
                            rng=random.Random(0))
    assert (eng.kv.pool.refcount >= 0).all()
    assert len(eng.kv.slots.free) == slots_free


def test_divergence_after_fork():
    """Forked children resample their pending token: siblings usually
    diverge at the first post-fork token."""
    cfg, params, eng = _engine("yi-6b", seed=3)
    [root] = eng.prefill_queries([[9, 8, 7]])
    children = [eng.fork_path(root) for _ in range(6)]
    firsts = {c.pending_token for c in children} | {root.pending_token}
    assert len(firsts) > 1  # with V=512 and T=1.0 collisions are unlikely


def test_fork_paths_batched_on_device_sampling():
    """A whole branching generation forks in one engine call: children
    diverge (on-device fork_sample) with finite, <=0 logprobs, and the
    round costs O(1) jitted dispatches, not one per fork per layer."""
    cfg, params, eng = _engine("yi-6b", seed=3)
    [root] = eng.prefill_queries([[9, 8, 7]])
    d0 = eng.stats.fork_dispatches
    children = eng.fork_paths([root] * 6)
    assert len(children) == 6
    # one COW/slot-copy dispatch at most + one fork_sample dispatch
    assert eng.stats.fork_dispatches - d0 <= 2
    firsts = {c.pending_token for c in children} | {root.pending_token}
    assert len(firsts) > 1  # V=512, T=1.0: collisions of all 7 ~impossible
    for c in children:
        assert np.isfinite(c.pending_logprob) and c.pending_logprob <= 0.0
        assert c.logits_buf is root.logits_buf  # boundary logits shared


def test_fork_paths_recurrent_single_dispatch():
    """Recurrent archs batch their slot copies into the same round
    dispatch; children still diverge and carry valid state slots."""
    cfg, params, eng = _engine("rwkv6-7b", seed=5)
    [root] = eng.prefill_queries([[1, 2, 3, 4]])
    d0 = eng.stats.fork_dispatches
    children = eng.fork_paths([root] * 4)
    assert eng.stats.fork_dispatches - d0 <= 2
    assert all(c.slot >= 0 and c.slot != root.slot for c in children)
    assert len({c.slot for c in children}) == 4
    res = eng.decode_segments([root] + children)
    assert all(np.isfinite(r.seg_logprob) for r in res)


def test_decode_host_transfer_is_vocab_free():
    """Steady-state decode transfer is O(R*l) tokens + O(R) scalars — the
    (Rb, V) boundary logits never cross to the host."""
    cfg, params, eng = _engine("yi-6b")
    [root] = eng.prefill_queries([[1, 2, 3]])
    before = eng.stats.host_bytes
    eng.decode_segments([root])
    per_round = eng.stats.host_bytes - before
    # Rb=1, l=8: tokens + logprobs (Rb*l*4 each) + pending tok/lp (Rb*4 each)
    assert per_round == 1 * 8 * 4 * 2 + 1 * 4 * 2
    assert per_round < cfg.vocab_size * 4  # old path moved >= V*4 per round
    # the full distribution is still reachable as an explicit debug fetch
    lg = root.last_logits
    assert lg.shape == (cfg.vocab_size,) and np.isfinite(lg).all()


def test_sequential_baseline_no_branching():
    cfg, params, eng = _engine("yi-6b", tc=TC)
    trees, rep = sample_sequential(eng, [[1, 2, 3]], ["x"],
                                   rng=random.Random(0))
    assert trees[0].num_trajectories == TC.max_width
    # all node chains diverge at depth 1 (root children, no deeper shares)
    chains = [tuple(p.node_ids) for p in trees[0].finished]
    d1 = [c[1] for c in chains]
    assert len(set(d1)) == len(d1)


def test_eos_truncation():
    from repro.core.early_stop import truncate_at_eos
    toks = [1, 2, 258, 4, 5]
    lps = [0.1, 0.2, 0.3, 0.4, 0.5]
    t2, l2 = truncate_at_eos(toks, lps, eos_id=258)
    assert t2 == [1, 2, 258] and l2 == [0.1, 0.2, 0.3]


def test_repetition_early_stop():
    from repro.core.early_stop import has_repetition
    assert has_repetition([1, 2, 3] * 5, max_ngram=4, count=4)
    assert has_repetition([7] * 10, max_ngram=4, count=4)
    assert not has_repetition(list(range(50)), max_ngram=8, count=3)


def test_host_sampler_matches_device_distribution():
    """sample_token_host draws from the same (temperature) distribution."""
    logits = np.array([2.0, 1.0, 0.0, -1.0], np.float64)
    rng = np.random.default_rng(0)
    draws = [sample_token_host(rng, logits, 1.0, 1.0)[0]
             for _ in range(2000)]
    freq = np.bincount(draws, minlength=4) / 2000
    want = np.exp(logits) / np.exp(logits).sum()
    np.testing.assert_allclose(freq, want, atol=0.05)
    # logprob reported matches log softmax
    _, lp = sample_token_host(np.random.default_rng(1), logits, 1.0, 1.0)
    assert lp <= 0


def test_stats_accounting():
    cfg, params, eng = _engine("yi-6b")
    trees, _ = sample_trees(eng, [[1, 2, 3, 4]], ["x"],
                            rng=random.Random(0))
    s = eng.stats
    assert s.prefill_tokens == 4
    assert s.decode_tokens == s.segments * TC.segment_len
    assert s.model_tokens == s.prefill_tokens + s.decode_tokens \
        + s.replay_tokens
    assert s.peak_pages > 0


def test_subgroup_nesting_invariant():
    """Eq. 4: node chains form nested subgroups — two paths sharing a node
    at depth j share every ancestor above j."""
    cfg, params, eng = _engine("yi-6b", tc=TreeConfig(
        max_depth=4, segment_len=8, max_width=6, branch_factor=2,
        init_divergence_low=2, init_divergence_high=2, temperature=1.0))
    trees, _ = sample_trees(eng, [[5, 6, 7]], ["x"], rng=random.Random(2))
    chains = [p.node_ids for p in trees[0].finished]
    for a in chains:
        for b in chains:
            for j in range(min(len(a), len(b))):
                if a[j] == b[j]:
                    assert a[: j] == b[: j]
