"""Expert-parallel shard_map MoE: exactness vs the plain path, capacity
semantics, and the mamba Pallas scan kernel (added in §Perf iterations)."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# These tests need >1 device for the model axis; run in a subprocess with
# a forced device count (device count is process-global).
_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.model import init_params, forward

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
for arch in ["olmoe-1b-7b", "deepseek-v3-671b"]:
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    l_plain, _ = forward(params, cfg, toks)
    with jax.set_mesh(mesh):
        l_ep, _ = jax.jit(lambda p, t: forward(p, cfg, t))(params, toks)
    np.testing.assert_allclose(np.asarray(l_plain), np.asarray(l_ep),
                               rtol=3e-4, atol=3e-4)
    # ample-capacity GShard packing is also exact
    cfg_cap = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ep_capacity_factor=8.0))
    with jax.set_mesh(mesh):
        l_cap, _ = jax.jit(lambda p, t: forward(p, cfg_cap, t))(params,
                                                                toks)
    np.testing.assert_allclose(np.asarray(l_plain), np.asarray(l_cap),
                               rtol=3e-4, atol=3e-4)
    print(arch, "EP ok")
print("ALL_OK")
"""


@pytest.mark.timeout(540)
def test_ep_moe_matches_plain_subprocess():
    if not hasattr(jax.sharding, "AxisType"):
        pytest.skip("needs the sharding-in-types mesh API "
                    "(jax.sharding.AxisType / jax.set_mesh); "
                    "not in this jax version")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT],
                          capture_output=True, text=True, env=env,
                          cwd=ROOT, timeout=520)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "ALL_OK" in proc.stdout


def test_mamba_scan_pallas_matches_ref():
    from repro.kernels.mamba_scan import mamba_scan_pallas
    from repro.kernels.ref import mamba_scan_ref
    B, T, d_in, N = 2, 9, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    u = jax.random.normal(ks[0], (B, T, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, d_in)))
    Bm = jax.random.normal(ks[2], (B, T, N))
    Cm = jax.random.normal(ks[3], (B, T, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d_in, N)))
    D = jnp.ones(d_in)
    h0 = jax.random.normal(ks[5], (B, d_in, N))
    y1, h1 = mamba_scan_pallas(u, dt, Bm, Cm, A, D, h0, blk_d=8,
                               interpret=True)
    y2, h2 = mamba_scan_ref(u, dt, Bm, Cm, A, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-5, atol=2e-5)


def test_mamba_scan_state_chaining():
    from repro.kernels.ref import mamba_scan_ref
    B, T, d_in, N = 1, 8, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    u = jax.random.normal(ks[0], (B, T, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, d_in)))
    Bm = jax.random.normal(ks[2], (B, T, N))
    Cm = jax.random.normal(ks[3], (B, T, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d_in, N)))
    D = jnp.zeros(d_in)
    h0 = jnp.zeros((B, d_in, N))
    y_full, h_full = mamba_scan_ref(u, dt, Bm, Cm, A, D, h0)
    h = T // 2
    y1, s1 = mamba_scan_ref(u[:, :h], dt[:, :h], Bm[:, :h], Cm[:, :h],
                            A, D, h0)
    y2, s2 = mamba_scan_ref(u[:, h:], dt[:, h:], Bm[:, h:], Cm[:, h:],
                            A, D, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)


def test_masked_kv_update_matches_scatter():
    from repro.configs import get_config
    from repro.models.model import decode_step, init_params, prefill
    cfg = get_config("yi-6b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, Sp, N = 2, 6, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Sp + N), 0,
                              cfg.vocab_size)
    _, c1 = prefill(params, cfg, toks[:, :Sp], Sp + N, dtype=jnp.float32)
    _, c2 = prefill(params, cfg, toks[:, :Sp], Sp + N, dtype=jnp.float32)
    for t in range(N - 1):
        pos = jnp.full((B,), Sp + t, jnp.int32)
        l1, c1 = decode_step(params, cfg, toks[:, Sp + t], c1, pos,
                             kv_update="scatter")
        l2, c2 = decode_step(params, cfg, toks[:, Sp + t], c2, pos,
                             kv_update="masked")
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)
