"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core.advantage import treepo_advantage
from repro.core.early_stop import has_repetition
from repro.core.engine import _bucket, _top_p_mask
from repro.core.lifecycle import lifecycle_guard
from repro.core.tree import Path, ancestor_matrix
from repro.data.reward import extract_boxed, reward_fn, verify_answer
from repro.data.tokenizer import ByteTokenizer
from repro.kv.cache import PagePool

SETTINGS = settings(max_examples=50, deadline=None)


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.text(max_size=200))
def test_tokenizer_roundtrip(s):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(s)) == s


@SETTINGS
@given(st.text(max_size=50))
def test_tokenizer_specials_never_collide(s):
    tok = ByteTokenizer()
    ids = tok.encode(s, bos=True, eos=True)
    assert ids[0] == ByteTokenizer.BOS and ids[-1] == ByteTokenizer.EOS
    assert all(0 <= t < tok.vocab_size for t in ids)


# ---------------------------------------------------------------------------
# reward
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.integers(-10**9, 10**9))
def test_reward_self_consistent(n):
    assert reward_fn(f"thinking... \\boxed{{{n}}}", str(n)) == 1.0
    assert verify_answer(str(n), f"{n}.0".replace("-0.0", "0.0")) or n != 0


@SETTINGS
@given(st.integers(-100, 100), st.integers(-100, 100))
def test_reward_discriminates(a, b):
    r = reward_fn(f"\\boxed{{{a}}}", str(b))
    assert (r == 1.0) == (a == b)


def test_extract_boxed_takes_last():
    assert extract_boxed(r"\boxed{1} then \boxed{2}") == "2"
    assert extract_boxed("no box") is None


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.lists(st.sampled_from(["alloc", "retain", "release"]),
                max_size=200))
def test_page_pool_invariants(ops):
    pool = PagePool(16)
    held = []
    for op in ops:
        if op == "alloc":
            if len(pool.free) == 0:
                continue
            held.append(pool.alloc())
        elif op == "retain" and held:
            pool.retain(held[0])
            held.append(held[0])
        elif op == "release" and held:
            pool.release(held.pop())
        # invariants
        assert (pool.refcount >= 0).all()
        in_use = set(np.nonzero(pool.refcount)[0])
        assert in_use == set(held)
        assert len(pool.free) == 16 - len(in_use)


@SETTINGS
@given(st.lists(st.tuples(st.sampled_from(["alloc", "fork", "grow",
                                           "preempt"]),
                          st.integers(0, 10**6)),
                max_size=150))
def test_page_pool_preempt_interleaving(ops):
    """Refcount hygiene under the pressure protocol's op mix: path
    tables fork (retain every page), grow (alloc), and preempt (release
    the whole table at once).  After draining, the pool must be exactly
    empty — no leaked or double-freed page, and the high-water mark
    never exceeds the pool."""
    pool = PagePool(24)
    tables = []
    for op, r in ops:
        if op == "alloc" and pool.num_free:
            tables.append([pool.alloc()])
        elif op == "fork" and tables:
            src = tables[r % len(tables)]
            for pid in src:
                pool.retain(pid)
            tables.append(list(src))
        elif op == "grow" and tables and pool.num_free:
            tables[r % len(tables)].append(pool.alloc())
        elif op == "preempt" and tables:
            for pid in tables.pop(r % len(tables)):
                pool.release(pid)
        assert (pool.refcount >= 0).all()
        held = {p for t in tables for p in t}
        assert set(np.nonzero(pool.refcount)[0]) == held
        assert pool.pages_in_use == len(held) <= pool.peak_in_use <= 24
        assert 0.0 <= pool.watermark <= 1.0
    for tbl in tables:
        for pid in tbl:
            pool.release(pid)
    assert pool.pages_in_use == 0 and pool.num_free == 24


@SETTINGS
@given(st.lists(st.tuples(st.sampled_from(["alloc", "fork", "release",
                                           "preempt", "restore"]),
                          st.integers(0, 10**6)),
                max_size=120),
       st.booleans())
def test_lifecycle_tracker_interleavings(ops, inject):
    """The runtime lifecycle tracker (repro.core.lifecycle) must stay
    silent across arbitrary clean alloc/fork/release/preempt/restore
    interleavings, and must flag an injected double release in every
    one of them — the dynamic twin of static rule R5."""
    pool = PagePool(32)
    live, preempted = [], []
    with lifecycle_guard(raise_on_violation=False) as rep:
        for op, r in ops:
            if op == "alloc" and pool.num_free:
                live.append([pool.alloc()])
            elif op == "fork" and live:
                src = live[r % len(live)]
                for pid in src:
                    pool.retain(pid)
                live.append(list(src))
            elif op == "release" and live:
                for pid in live.pop(r % len(live)):
                    pool.release(pid)
            elif op == "preempt" and live:
                tbl = live.pop(r % len(live))
                preempted.append(len(tbl))
                for pid in tbl:
                    pool.release(pid)
            elif op == "restore" and preempted:
                n = preempted.pop()
                if pool.num_free >= n:
                    live.append([pool.alloc() for _ in range(n)])
        for tbl in live:
            for pid in tbl:
                pool.release(pid)
        assert rep.violations == []
        if inject:
            try:
                pool.release(0)       # everything was drained above
            except AssertionError:
                pass
    if inject:
        assert any("double release" in v for v in rep.violations)
    else:
        assert rep.violations == []
    assert pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# advantage
# ---------------------------------------------------------------------------

@st.composite
def _tree_case(draw):
    G = draw(st.integers(2, 8))
    J = draw(st.integers(1, 4))
    # valid nested ancestor matrix: children ids derived from parent ids
    anc = np.zeros((G, J), np.int64)
    next_id = [1]
    def assign(rows, j):
        if j >= J:
            return
        k = draw(st.integers(1, max(1, len(rows))))
        groups = np.array_split(rows, k)
        for g in groups:
            if len(g) == 0:
                continue
            nid = next_id[0]; next_id[0] += 1
            anc[g, j] = nid
            assign(g, j + 1)
    assign(np.arange(G), 1) if J > 1 else None
    # realistic RLVR rewards ({0, shaping, 1}); sub-f32-resolution gaps
    # cancel under +const shifts and are not meaningful reward structure
    rewards = np.asarray(draw(st.lists(
        st.sampled_from([0.0, 0.1, 0.5, 1.0]), min_size=G, max_size=G)),
        np.float32)
    return rewards, anc


@SETTINGS
@given(_tree_case())
def test_treepo_advantage_finite_and_shift_invariant(case):
    rewards, anc = case
    # eps=1e-3 keeps the degenerate (zero per-depth-std) regime's
    # amplification of f32 rounding below the tolerance; the default 1e-6
    # is fine in training where global normalization rescales anyway
    a1 = np.asarray(treepo_advantage(jnp.asarray(rewards),
                                     jnp.asarray(anc), eps=1e-3))
    assert np.isfinite(a1).all()
    a2 = np.asarray(treepo_advantage(jnp.asarray(rewards + 5.0),
                                     jnp.asarray(anc), eps=1e-3))
    np.testing.assert_allclose(a1, a2, rtol=1e-3, atol=1e-3)


@SETTINGS
@given(_tree_case())
def test_grpo_equals_treepo_on_flat_tree(case):
    """With only the root subgroup (J=1), Eq. 5 reduces to centered Eq. 2
    (up to the std normalizer semantics)."""
    rewards, anc = case
    flat = np.zeros((len(rewards), 1), np.int64)
    a = np.asarray(treepo_advantage(jnp.asarray(rewards),
                                    jnp.asarray(flat)))
    centered = rewards - rewards.mean()
    # J=1: per-traj std over a single depth is 0 -> adv/(0+eps): sign match
    assert np.all(np.sign(a) == np.sign(np.where(
        np.abs(centered) < 1e-7, a, centered)))


# ---------------------------------------------------------------------------
# early stop / sampling utils
# ---------------------------------------------------------------------------

@SETTINGS
@given(st.lists(st.integers(0, 5), min_size=1, max_size=12),
       st.integers(2, 4))
def test_repetition_detector_fires_on_built_repeats(seq, count):
    tail = seq * count
    assert has_repetition(tail, max_ngram=len(seq), count=count)


@SETTINGS
@given(st.integers(1, 1000))
def test_bucket_monotone_pow2(n):
    b = _bucket(n)
    assert b >= n and (b & (b - 1)) == 0
    assert b < 2 * n or n == 1


@SETTINGS
@given(st.lists(st.floats(-5, 5, allow_nan=False, width=32), min_size=4,
                max_size=32),
       st.floats(0.1, 0.99))
def test_top_p_mask_keeps_nucleus(logits, p):
    logits32 = np.asarray(logits, np.float32)
    lg = jnp.asarray([logits32])
    masked = np.asarray(_top_p_mask(lg, p))[0]
    probs = np.exp(logits32 - logits32.max())
    probs = probs / probs.sum()
    # at least one maximal element is kept (ties may break either way)
    max_idx = np.flatnonzero(logits32 == logits32.max())
    assert any(masked[i] > -1e29 for i in max_idx)
    # kept mass >= p (nucleus property)
    kept = probs[masked > -1e29].sum()
    assert kept >= min(p, 1.0) - 1e-4


# ---------------------------------------------------------------------------
# sequence packing (repro.rl.packing)
# ---------------------------------------------------------------------------

@st.composite
def _packing_case(draw):
    """Random trajectory (prompt_len, resp_len) pairs + a row capacity.

    Lengths may exceed the capacity (oversized trajectories get dedicated
    rows) and prompts may be empty (fallback-style segments)."""
    n = draw(st.integers(1, 20))
    plens = draw(st.lists(st.integers(0, 12), min_size=n, max_size=n))
    rlens = draw(st.lists(st.integers(1, 24), min_size=n, max_size=n))
    capacity = draw(st.integers(4, 48))
    return plens, rlens, capacity


@SETTINGS
@given(_packing_case())
def test_ffd_places_each_item_once_and_never_overflows(case):
    from repro.rl.packing import first_fit_decreasing

    plens, rlens, capacity = case
    lengths = [p + r for p, r in zip(plens, rlens)]
    rows = first_fit_decreasing(lengths, capacity)
    placed = sorted(i for row in rows for i in row)
    assert placed == list(range(len(lengths)))     # exactly once
    for row in rows:
        total = sum(lengths[i] for i in row)
        # a row only exceeds capacity when a single oversized item owns it
        assert total <= capacity or len(row) == 1
    assert len(rows) <= len(lengths)


@SETTINGS
@given(_packing_case())
def test_segment_tables_roundtrip_through_packed_row_tensors(case):
    """Tables built from an FFD pack must decode (via the ONE shared
    derivation) back to exactly the packed layout: per-segment column
    counts, within-segment positions, response spans, -1 pads — and no
    row overflows the bucket length."""
    from repro.rl.packing import first_fit_decreasing, packed_row_tensors

    plens, rlens, capacity = case
    lengths = [p + r for p, r in zip(plens, rlens)]
    L = max([capacity] + lengths)                  # bucket covers oversize
    rows = first_fit_decreasing(lengths, L)
    N, S = len(rows), max(len(r) for r in rows)
    seg_p = np.zeros((N, S), np.int32)
    seg_r = np.zeros((N, S), np.int32)
    for i, row in enumerate(rows):
        for s, j in enumerate(row):
            seg_p[i, s] = plens[j]
            seg_r[i, s] = rlens[j]
    tot = seg_p + seg_r
    assert (tot.sum(axis=1) <= L).all()            # no row overflow
    sid, pos, rmask = packed_row_tensors(seg_p, seg_r, L)
    for i in range(N):
        off = 0
        for s in range(S):
            t = int(tot[i, s])
            if t == 0:
                continue
            assert (sid[i, off: off + t] == s).all()
            np.testing.assert_array_equal(pos[i, off: off + t],
                                          np.arange(t))
            np.testing.assert_array_equal(
                rmask[i, off: off + t],
                (np.arange(t) >= seg_p[i, s]).astype(np.float32))
            off += t
        assert (sid[i, off:] == -1).all()          # pads, nothing else
        assert (rmask[i, off:] == 0).all()
    # every trajectory's response is scored exactly once across the pack
    assert int(rmask.sum()) == sum(rlens)


@SETTINGS
@given(_packing_case())
def test_packed_pad_fraction_never_exceeds_unpacked(case):
    """At the same bucket length, FFD packing can only reduce (or keep)
    the padded-token fraction of the grid the update runs."""
    from repro.rl.packing import first_fit_decreasing

    plens, rlens, capacity = case
    lengths = [p + r for p, r in zip(plens, rlens)]
    L = max([capacity] + lengths)
    rows = first_fit_decreasing(lengths, L)
    used = sum(lengths)
    unpacked = 1.0 - used / float(len(lengths) * L)
    packed = 1.0 - used / float(len(rows) * L)
    assert packed <= unpacked + 1e-12


# ---------------------------------------------------------------------------
# ancestor matrix
# ---------------------------------------------------------------------------

def test_ancestor_matrix_pads_short_paths():
    p1 = Path(query_idx=0, depth=3, node_ids=[1, 2, 3, 4], tokens=[],
              logprobs=[])
    p2 = Path(query_idx=0, depth=1, node_ids=[1, 9], tokens=[],
              logprobs=[])
    anc = ancestor_matrix([p1, p2], max_depth=3)
    assert anc.shape == (2, 4)
    assert list(anc[0]) == [1, 2, 3, 4]
    assert list(anc[1]) == [1, 9, 9, 9]
