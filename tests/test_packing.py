"""Sequence-packing unit tests: FFD binning edge cases, the shared
segment-table -> dense-tensor derivation, cross-segment attention
isolation, and the boundary loss-mask guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.models.model import forward, init_params
from repro.rl.packing import (
    PackedRolloutBatch,
    bucket_segments,
    first_fit_decreasing,
    packed_batch_tensors,
    packed_row_tensors,
)
from repro.rl.update import make_pg_loss


# ---------------------------------------------------------------------------
# first-fit-decreasing binning
# ---------------------------------------------------------------------------

def test_ffd_all_short_exactly_fills_rows():
    """Four length-8 items at capacity 16: two rows, both exactly full."""
    rows = first_fit_decreasing([8, 8, 8, 8], 16)
    assert len(rows) == 2
    assert all(len(r) == 2 for r in rows)
    assert sorted(i for r in rows for i in r) == [0, 1, 2, 3]


def test_ffd_single_item_longer_than_capacity_gets_own_row():
    """An oversized trajectory is never truncated or co-binned: it gets a
    dedicated row, and nothing else is placed after it."""
    rows = first_fit_decreasing([20, 4, 4], 16)
    assert rows[0] == [0]
    assert sorted(i for r in rows[1:] for i in r) == [1, 2]
    # the short items still pack together in one row
    assert len(rows) == 2


def test_ffd_first_fit_order_and_capacity():
    rows = first_fit_decreasing([10, 6, 4, 16, 2], 16)
    lens = [10, 6, 4, 16, 2]
    for r in rows:
        total = sum(lens[i] for i in r)
        assert total <= 16 or len(r) == 1
    assert sorted(i for r in rows for i in r) == [0, 1, 2, 3, 4]
    assert len(rows) == 3  # [16], [10, 6], [4, 2]


def test_packing_supported_universal_and_pjit_specs_packed():
    """Since the segment-reset kernels, packing is exact for EVERY arch
    (SSM/RWKV state resets, shared-prefix segment, per-row encoder
    conditioning) — the gate is universally true and the pjit train_4k
    specs ship the packed compact layout (segment tables, no dense
    mask/advantage planes) for all 11 archs."""
    from repro.configs import ALL_ARCHS
    from repro.launch.steps import input_specs
    from repro.rl.packing import packing_supported

    assert len(ALL_ARCHS) == 11
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        assert packing_supported(cfg) is True, arch
        specs = input_specs(cfg, "train_4k")
        assert "seg_adv" in specs, arch
        assert "seg_prompt_lens" in specs and "seg_resp_lens" in specs
        assert "response_mask" not in specs and "advantages" not in specs


def test_trainer_accepts_pack_sequences_on_hybrid_archs():
    """The old attention-only guard is retired: hybrid (SSM/RWKV) and
    encoder/prefix configs construct with pack_sequences=True."""
    from repro.configs.base import TreeConfig
    from repro.rl.trainer import RLTrainer, TrainerMode

    for arch in ("jamba-v0.1-52b", "rwkv6-7b"):
        cfg = get_config(arch, smoke=True)
        tr = RLTrainer(cfg, TrainConfig(pack_sequences=True), TreeConfig(),
                       TrainerMode.TREEPO,
                       engine_kwargs=dict(num_pages=64, page_size=16,
                                          max_slots=8, max_queries=4,
                                          max_prompt_len=64))
        assert tr.train_cfg.pack_sequences


def test_bucket_segments_quantum():
    assert bucket_segments(1) == 2
    assert bucket_segments(2) == 2
    assert bucket_segments(3) == 4
    assert bucket_segments(5) == 6


# ---------------------------------------------------------------------------
# segment-table -> dense tensor derivation (shared np/jnp definition)
# ---------------------------------------------------------------------------

def _tables():
    plens = np.array([[2, 3, 0], [1, 0, 0]], np.int32)
    rlens = np.array([[3, 2, 0], [4, 0, 0]], np.int32)
    adv = np.array([[1.0, 2.0, 0.0], [3.0, 0.0, 0.0]], np.float32)
    return plens, rlens, adv


def test_packed_row_tensors_hand_checked():
    plens, rlens, _ = _tables()
    sid, pos, rmask = packed_row_tensors(plens, rlens, 12)
    np.testing.assert_array_equal(sid[0], [0] * 5 + [1] * 5 + [-1] * 2)
    np.testing.assert_array_equal(sid[1], [0] * 5 + [-1] * 7)
    # positions reset to 0 at each segment start (RoPE offsets)
    np.testing.assert_array_equal(pos[0], [0, 1, 2, 3, 4, 0, 1, 2, 3, 4,
                                           0, 0])
    # response mask covers exactly each segment's response span
    np.testing.assert_array_equal(
        rmask[0], [0, 0, 1, 1, 1, 0, 0, 0, 1, 1, 0, 0])
    np.testing.assert_array_equal(
        rmask[1], [0, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0])


def test_packed_batch_tensors_advantage_broadcast_and_jnp_parity():
    plens, rlens, adv = _tables()
    sid, pos, rmask, a = packed_batch_tensors(plens, rlens, adv, 12)
    np.testing.assert_allclose(
        a[0], [0, 0, 1, 1, 1, 0, 0, 0, 2, 2, 0, 0])
    np.testing.assert_allclose(a[1, 1:5], [3.0] * 4)
    sj, pj, rj, aj = packed_batch_tensors(
        jnp.asarray(plens), jnp.asarray(rlens), jnp.asarray(adv), 12,
        xp=jnp)
    np.testing.assert_array_equal(np.asarray(sj), sid)
    np.testing.assert_array_equal(np.asarray(pj), pos)
    np.testing.assert_array_equal(np.asarray(rj), rmask)
    np.testing.assert_allclose(np.asarray(aj), a)


def test_packed_batch_views_consistent():
    plens, rlens, adv = _tables()
    b = PackedRolloutBatch(
        tokens=np.ones((2, 12), np.int32),
        logprobs_old=np.zeros((2, 12), np.float32),
        seg_prompt_lens=plens, seg_resp_lens=rlens, seg_adv=adv,
        seg_rewards=adv.copy(), num_trajectories=3)
    assert b.response_mask.shape == (2, 12)
    assert b.rewards.shape == (3,)
    used = (plens + rlens).sum()
    assert b.padded_token_fraction == pytest.approx(1 - used / 24.0)


# ---------------------------------------------------------------------------
# no cross-segment attention leakage
# ---------------------------------------------------------------------------

def test_packed_forward_isolates_segments():
    """Perturbing a token inside segment 0 must not move ANY logit of
    segment 1 in the same packed row (segment-masked attention +
    per-segment RoPE reset)."""
    cfg = get_config("qwen2.5-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    plens = np.array([[2, 2]], np.int32)
    rlens = np.array([[4, 3]], np.int32)
    L = 12
    sid, pos, _ = packed_row_tensors(plens, rlens, L)
    rng = np.random.default_rng(0)
    tokens = rng.integers(1, cfg.vocab_size, (1, L)).astype(np.int32)
    logits1, _ = forward(params, cfg, jnp.asarray(tokens),
                         positions=jnp.asarray(pos),
                         segment_ids=jnp.asarray(sid))
    tokens2 = tokens.copy()
    tokens2[0, 5] = (tokens2[0, 5] + 1) % cfg.vocab_size  # seg-0 last token
    logits2, _ = forward(params, cfg, jnp.asarray(tokens2),
                         positions=jnp.asarray(pos),
                         segment_ids=jnp.asarray(sid))
    a = np.asarray(logits1)[0]
    b = np.asarray(logits2)[0]
    assert not np.allclose(a[5], b[5])              # seg 0 itself moved
    np.testing.assert_allclose(a[6:11], b[6:11],    # seg 1 untouched
                               rtol=1e-6, atol=1e-6)


def test_packed_forward_matches_unpacked_rows():
    """Each packed segment's logits equal the same trajectory's logits in
    its own unpacked row — the per-token forward-parity that makes the
    packed update a drop-in for the unpacked one."""
    cfg = get_config("qwen2.5-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    seg_a = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
    seg_b = rng.integers(1, cfg.vocab_size, 4).astype(np.int32)
    L = 12
    packed_tokens = np.zeros((1, L), np.int32)
    packed_tokens[0, :6] = seg_a
    packed_tokens[0, 6:10] = seg_b
    sid, pos, _ = packed_row_tensors(np.array([[2, 1]], np.int32),
                                     np.array([[4, 3]], np.int32), L)
    packed_logits, _ = forward(params, cfg, jnp.asarray(packed_tokens),
                               positions=jnp.asarray(pos),
                               segment_ids=jnp.asarray(sid))
    packed_logits = np.asarray(packed_logits)[0]
    for toks, sl in ((seg_a, slice(0, 6)), (seg_b, slice(6, 10))):
        row = np.zeros((1, len(toks)), np.int32)
        row[0] = toks
        solo, _ = forward(params, cfg, jnp.asarray(row))
        np.testing.assert_allclose(packed_logits[sl],
                                   np.asarray(solo)[0],
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# boundary loss-mask guard
# ---------------------------------------------------------------------------

def test_loss_mask_ignores_previous_segment_last_token():
    """A segment whose first token is a *response* token (prompt_len 0)
    would be scored against the previous segment's last token; the
    packed loss mask must drop it.  Compare against the plain response
    mask, which alone would keep it."""
    plens = np.array([[2, 0]], np.int32)   # 2nd segment: no prompt
    rlens = np.array([[3, 3]], np.int32)
    L = 8
    sid, _, rmask = packed_row_tensors(plens, rlens, L)
    # the packed loss builds: mask = rmask[:, 1:] * (sid aligned)
    guard = (sid[:, 1:] == sid[:, :-1]).astype(np.float32)
    mask = rmask[:, 1:] * guard
    start_col = 5                          # 2nd segment starts at col 5
    assert rmask[0, start_col] == 1.0      # response token at seg start
    assert mask[0, start_col - 1] == 0.0   # ... but never scored across
    # all other response tokens survive the guard
    assert mask.sum() == rmask[:, 1:].sum() - 1


def test_packed_pg_loss_runs_and_masks_pad_rows():
    """make_pg_loss(packed=True): finite loss; an extra all-pad row (the
    row-bucket padding) leaves loss and grads unchanged."""
    cfg = get_config("qwen2.5-7b", smoke=True)
    tc = TrainConfig()
    params = init_params(jax.random.PRNGKey(2), cfg)
    loss_fn = make_pg_loss(cfg, tc, packed=True)
    rng = np.random.default_rng(2)
    L, S = 16, 2

    def batch(n_pad_rows=0):
        N = 1 + n_pad_rows
        tokens = np.zeros((N, L), np.int32)
        tokens[0] = rng.integers(1, cfg.vocab_size, L)
        plens = np.zeros((N, S), np.int32)
        rlens = np.zeros((N, S), np.int32)
        plens[0], rlens[0] = (2, 3), (5, 4)
        adv = np.zeros((N, S), np.float32)
        adv[0] = (0.5, -0.5)
        lp = np.zeros((N, L), np.float32)
        lp[0, 2:7] = -1.0
        lp[0, 8:12] = -1.0
        return {"tokens": jnp.asarray(tokens),
                "logprobs_old": jnp.asarray(lp),
                "seg_prompt_lens": jnp.asarray(plens),
                "seg_resp_lens": jnp.asarray(rlens),
                "seg_adv": jnp.asarray(adv)}

    rng = np.random.default_rng(2)
    loss1, m1 = loss_fn(params, batch(0))
    rng = np.random.default_rng(2)
    loss2, m2 = loss_fn(params, batch(2))
    assert np.isfinite(float(loss1))
    np.testing.assert_allclose(float(loss1), float(loss2),
                               rtol=1e-6, atol=1e-7)
