"""Deterministic fault-injection suite (docs/robustness.md).

Every robustness claim is *proven* here by injecting the fault and
asserting the degradation contract:

* KV pressure — injected and real page exhaustion are absorbed by
  preemption + throttled branching; a 50%-of-peak pool still completes
  with zero escaped ``OutOfPages``.
* Numeric quarantine — NaN decode/fork logits fail only the affected
  paths; a NaN-poisoned update batch skips the param update bitwise.
* Crash-safe resume — ``RLTrainer.state_dict`` checkpoints reproduce
  the uninterrupted run's remaining metrics stream and final params;
  a kill at any checkpoint-store kill point leaves the newest complete
  checkpoint loadable; the launch driver resumes its JSONL stream.

All tests carry the ``fault`` marker (``pytest -m fault``).
"""
import glob
import json
import os
import random

import jax
import numpy as np
import pytest

from repro.checkpoint import (latest_step, list_steps, load_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.configs.base import TrainConfig, TreeConfig
from repro.core import branching as br
from repro.core import faults
from repro.core.engine import TreeEngine
from repro.core.faults import FaultInjector, InjectedCrash
from repro.core.lifecycle import lifecycle_guard
from repro.core.sampler import sample_trees
from repro.core.tree import Status
from repro.kv.cache import OutOfPages, PagePool
from repro.models.model import init_params
from repro.rl.trainer import RLTrainer, TrainerMode

pytestmark = pytest.mark.fault


@pytest.fixture(autouse=True)
def _lifecycle_tracker():
    """Every fault test runs with the runtime lifecycle tracker armed:
    any page/slot refcount or path-FSM violation under injected faults
    fails the test at teardown (docs/static_analysis.md, R5/R6 runtime
    twin)."""
    with lifecycle_guard() as rep:
        yield rep

ENGINE_KW = dict(num_pages=256, page_size=16, max_slots=32, max_queries=16,
                 max_prompt_len=128)
TREE_CFG = TreeConfig(max_depth=5, segment_len=16, max_width=8,
                      branch_factor=2, init_divergence_low=2,
                      init_divergence_high=2, temperature=0.9)


def _trainer(seed=0, engine_kwargs=None, tree_cfg=TREE_CFG, ppo_epochs=2):
    cfg = get_config("qwen2.5-7b", smoke=True)
    trc = TrainConfig(batch_size=2, group_size=tree_cfg.max_width,
                      oversample_factor=1, max_resample_rounds=0,
                      dynamic_sampling=False, learning_rate=1e-3,
                      ppo_epochs=ppo_epochs, reward_shaping=0.1)
    return RLTrainer(cfg, trc, tree_cfg, TrainerMode.TREEPO, seed=seed,
                     engine_kwargs=dict(engine_kwargs or ENGINE_KW),
                     min_difficulty=1, max_difficulty=2)


def _leaves(trees):
    return [p for t in trees for p in t.finished if p.status == Status.LEAF]


# ---------------------------------------------------------------------------
# injector mechanics
# ---------------------------------------------------------------------------

def test_injector_deterministic_and_scoped():
    def drive(fi):
        fired = []
        with fi:
            for _ in range(6):
                fired.append(faults.fires("page_pool.alloc"))
            a = np.ones((3, 4), np.float32)
            out = faults.corrupt_array("engine.decode_logprobs", a)
        return fired, out

    mk = lambda: (FaultInjector(seed=7)
                  .page_exhaustion(at_alloc=3, times=2)
                  .nan_logits(at_round=1, rows=(1,)))
    f1, o1 = drive(mk())
    f2, o2 = drive(mk())
    assert f1 == f2 == [False, False, True, True, False, False]
    np.testing.assert_array_equal(o1, o2)
    assert np.isnan(o1[1, 0]) and np.isfinite(o1[0]).all()
    # disarmed: helpers are identity no-ops
    assert faults.active() is None
    assert not faults.fires("page_pool.alloc")
    a = np.ones((2, 2), np.float32)
    assert faults.corrupt_array("engine.decode_logprobs", a) is a
    faults.kill_point("train.step")  # no raise


def test_injector_does_not_nest_and_disarms_on_error():
    with pytest.raises(RuntimeError, match="does not nest"):
        with FaultInjector():
            with FaultInjector():
                pass
    assert faults.active() is None  # outer __exit__ ran
    with pytest.raises(ValueError):
        with FaultInjector():
            raise ValueError("boom")
    assert faults.active() is None


# ---------------------------------------------------------------------------
# KV-pressure degradation
# ---------------------------------------------------------------------------

def test_injected_page_exhaustion_absorbed():
    """An injected mid-rollout OutOfPages triggers the pressure protocol
    (leaf-KV release + retry) instead of escaping the rollout."""
    tr = _trainer()
    with FaultInjector().page_exhaustion(at_alloc=40):
        trees, eng = tr.rollout(2)
    assert eng.stats.pressure_events >= 1
    assert sum(len(t.finished) for t in trees) > 0
    assert len(_leaves(trees)) > 0


def test_half_pool_completes_without_escape():
    """Acceptance: a seeded rollout with the pool capped at 50% of the
    measured nominal peak completes with zero escaped OutOfPages and a
    non-trivial share of kept trajectories."""
    nominal = _trainer(seed=0)
    trees0, eng0 = nominal.rollout(2)
    peak = eng0.kv.pool.peak_in_use
    n0 = sum(len(t.finished) for t in trees0)
    assert peak > 0 and n0 > 0

    half = _trainer(seed=0, engine_kwargs=dict(
        ENGINE_KW, num_pages=max(peak // 2, 1)))
    trees, eng = half.rollout(2)  # must not raise
    assert eng.kv.pool.peak_in_use <= max(peak // 2, 1)
    assert eng.stats.preempted_paths > 0  # degradation actually engaged
    kept = sum(len(t.finished) for t in trees)
    assert kept > 0 and len(_leaves(trees)) > 0
    # every path was accounted for: finished or explicitly preempted
    for t in trees:
        assert not t.active and not t.preempted


def test_throttle_budget_scales_with_pressure():
    tc = TreeConfig(kv_watermark_soft=0.8, kv_watermark_hard=0.95)
    assert br.pressure_scale(tc, 0.5) == 1.0
    assert br.pressure_scale(tc, 0.95) == 0.0
    mid = br.pressure_scale(tc, (0.8 + 0.95) / 2)
    assert 0.4 < mid < 0.6
    # continuations (one per active path) are never throttled
    assert br.throttle_budget(tc, 8, 3, 0.99) == 3
    assert br.throttle_budget(tc, 8, 3, 0.0) == 8
    off = TreeConfig(pressure_aware=False)
    assert br.pressure_scale(off, 0.99) == 1.0


def test_preempt_restore_roundtrip():
    """restore_path replays a preempted path's tokens into fresh pages
    and resumes with a sampled pending token."""
    cfg = get_config("yi-6b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = TreeEngine(params, cfg, TREE_CFG, num_pages=64, page_size=8,
                     max_slots=8, max_queries=4, max_prompt_len=32, seed=0)
    prompt = [1, 2, 3, 4, 5]
    [root] = eng.prefill_queries([prompt])
    [res] = eng.decode_segments([root])
    tokens = prompt + list(res.tokens)
    in_use = eng.kv.pool.pages_in_use
    freed = eng.preempt_path(root)
    assert freed > 0 and eng.kv.pool.pages_in_use == in_use - freed
    assert eng.stats.preempted_paths == 1
    assert eng.can_restore
    path = eng.restore_path(tokens)
    assert path.position == len(tokens)
    assert eng.stats.regenerated_paths == 1
    # the restored context decodes exactly like a never-preempted one
    [res2] = eng.decode_segments([path])
    assert len(res2.tokens) > 0 and res2.finite


def test_serve_radix_eviction_before_preemption():
    """Injected page exhaustion mid-serve: the engine reclaims radix
    leaves (recomputable cache) before any live request is preempted
    (non-recomputable working set), and the serve run completes."""
    from repro.core.scheduler import Request, Scheduler
    from repro.data.tokenizer import ByteTokenizer

    tok = ByteTokenizer()
    cfg = get_config("qwen2.5-7b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = TreeEngine(params, cfg, TREE_CFG, **ENGINE_KW)
    sched = Scheduler(eng, mode="continuous", max_running=4, base_seed=7)
    sys_prompt = "You are a helpful math assistant. Answer concisely."
    prompts = [tok.encode(sys_prompt + f" What is {i}+{i}?", bos=True)
               for i in range(4)]
    # wave 1 populates the radix (requests finish -> cache is sole owner)
    wave1 = [Request(rid=i, prompt=p, max_new_tokens=8)
             for i, p in enumerate(prompts[:2])]
    sched.run(wave1)
    assert sched.radix is not None and sched.radix.cached_pages > 0
    # wave 2 hits an injected allocator exhaustion mid-serve
    wave2 = [Request(rid=10 + i, prompt=p, max_new_tokens=8)
             for i, p in enumerate(prompts[2:])]
    with FaultInjector().page_exhaustion(at_alloc=2):
        report = sched.run(wave2)
    assert eng.stats.pressure_events >= 1          # the fault really fired
    assert sched.radix.evicted_pages > 0           # eviction kicked in...
    assert eng.stats.preempted_paths == 0          # ...before preemption
    assert report.finished == len(wave1) + len(wave2)   # cumulative report
    assert all(r.state == "finished" for r in wave2)
    sched.radix.evict(eng.kv.pool.num_pages)       # drain the cache


def test_out_of_pages_diagnostics():
    pool = PagePool(2)
    pool.alloc(), pool.alloc()
    with pytest.raises(OutOfPages) as ei:
        pool.alloc()
    assert "pages_in_use=2/2" in str(ei.value)
    # rollout-level annotation: a pool too small for even the prefill
    # escapes (nothing preemptible exists yet) but carries the forensics
    tr = _trainer(engine_kwargs=dict(ENGINE_KW, num_pages=2))
    with pytest.raises(OutOfPages) as ei:
        tr.rollout(2)
    msg = str(ei.value)
    assert "live_paths=" in msg and "per_query_pages=" in msg
    # serving annotation: pressure failures with a radix attached report
    # cache-held vs evictable pages, distinguishing them from path-held
    exc = OutOfPages("pool exhausted", pages_in_use=2, num_pages=2)
    exc.annotate(radix_pages=5, radix_evictable=3)
    assert "radix_pages=5(evictable 3)" in str(exc)


def test_allocator_interleaving_seeded():
    """Randomized (seeded) alloc/retain/release/preempt interleaving
    keeps refcounts consistent and drains back to an empty pool —
    the always-run twin of the hypothesis property in test_property.py."""
    rng = np.random.default_rng(123)
    pool = PagePool(32)
    tables = []  # simulated per-path page tables (shared via retain)
    for _ in range(400):
        op = rng.integers(4)
        if op == 0 and pool.num_free:
            tables.append([pool.alloc()])
        elif op == 1 and tables:  # fork: share every page
            src = tables[rng.integers(len(tables))]
            for pid in src:
                pool.retain(pid)
            tables.append(list(src))
        elif op == 2 and tables and pool.num_free:  # grow one table
            tables[rng.integers(len(tables))].append(pool.alloc())
        elif op == 3 and tables:  # preempt: drop a whole table
            tbl = tables.pop(rng.integers(len(tables)))
            for pid in tbl:
                pool.release(pid)
        assert (pool.refcount >= 0).all()
        held = {p for t in tables for p in t}
        assert set(np.nonzero(pool.refcount)[0]) == held
        assert pool.pages_in_use == len(held)
    for tbl in tables:
        for pid in tbl:
            pool.release(pid)
    assert pool.pages_in_use == 0 and pool.num_free == 32
    assert pool.peak_in_use > 0


# ---------------------------------------------------------------------------
# numeric quarantine
# ---------------------------------------------------------------------------

def test_nan_logits_quarantine_only_affected_paths():
    tr = _trainer()
    with FaultInjector().nan_logits(at_round=2, rows=(0,)):
        trees, eng = tr.rollout(2)
    bad = [p for t in trees for p in t.finished
           if p.finish_reason == "nonfinite"]
    ok = [p for t in trees for p in t.finished
          if p.finish_reason != "nonfinite"]
    assert eng.stats.quarantined_paths >= 1
    assert len(bad) >= 1
    assert all(p.status == Status.FAILED for p in bad)
    assert len(ok) > 0  # the tree survived the poisoned row
    for t in trees:
        assert not t.active


def test_nan_fork_logits_quarantine():
    tr = _trainer()
    with FaultInjector().nan_fork_logits(at_call=2, rows=(0,)):
        trees, eng = tr.rollout(2)
    assert eng.stats.quarantined_paths >= 1
    assert sum(len(t.finished) for t in trees) > 0
    for t in trees:
        assert not t.active


def test_nan_grads_skip_preserves_params_bitwise():
    tr = _trainer(ppo_epochs=2)
    before = jax.device_get(tr.params)
    opt_step = int(tr.opt_state.step)
    with FaultInjector().nan_grads(at_step=1):
        m = tr.train_step(num_queries=2)
    # every epoch of the poisoned batch is skipped and reported
    assert m["skipped_nonfinite"] == float(tr.train_cfg.ppo_epochs)
    after = jax.device_get(tr.params)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(tr.opt_state.step) == opt_step  # Adam state also reverted
    # the next, clean batch updates normally
    m2 = tr.train_step(num_queries=2)
    assert m2["skipped_nonfinite"] == 0.0
    assert int(tr.opt_state.step) == opt_step + tr.train_cfg.ppo_epochs


# ---------------------------------------------------------------------------
# crash-safe resume
# ---------------------------------------------------------------------------

def _params_equal(a, b, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


def test_trainer_resume_reproduces_run(tmp_path):
    """4 uninterrupted steps == 2 steps + checkpoint + fresh-process
    restore + 2 steps: params within 1e-6, metrics stream identical."""
    ref = _trainer(seed=3)
    ref_metrics = [ref.train_step(num_queries=2) for _ in range(4)]

    half = _trainer(seed=3)
    for _ in range(2):
        half.train_step(num_queries=2)
    save_checkpoint(str(tmp_path), half.step, half.state_dict())

    fresh = _trainer(seed=3)
    fresh.train_step(num_queries=2)  # desync before restore, on purpose
    fresh.load_state_dict(load_checkpoint(str(tmp_path)))
    # the cursor truncates rows logged AFTER the checkpoint; a fresh
    # process (whose history lives in the JSONL file) just keeps its own
    assert fresh.step == 2 and len(fresh.metrics_log) <= 2
    resumed = [fresh.train_step(num_queries=2) for _ in range(2)]

    _params_equal(ref.params, fresh.params, atol=1e-6)
    for want, got in zip(ref_metrics[2:], resumed):
        assert want["step"] == got["step"]
        for k in ("reward_mean", "response_len", "num_trajectories"):
            assert want[k] == pytest.approx(got[k], abs=1e-9), k


def test_kill_during_save_keeps_latest_loadable(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(4, dtype=np.float32)}
    save_checkpoint(d, 1, tree)
    for point in ("ckpt.pre_write", "ckpt.pre_rename"):
        with pytest.raises(InjectedCrash):
            with FaultInjector().kill(point):
                save_checkpoint(d, 2, {"w": np.zeros(4, np.float32)})
        assert latest_step(d) == 1  # half-written step 2 is invisible
        out = load_checkpoint(d)
        np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    # post-rename kill: step 2 IS complete, and the next save prunes
    # any stale tmp files left behind
    with pytest.raises(InjectedCrash):
        with FaultInjector().kill("ckpt.post_rename"):
            save_checkpoint(d, 2, {"w": np.ones(4, np.float32)})
    assert latest_step(d) == 2
    save_checkpoint(d, 3, tree, keep_last=2)
    assert list_steps(d) == [2, 3]
    assert not glob.glob(os.path.join(d, "*.tmp"))


def test_checkpoint_low_precision_roundtrip(tmp_path):
    """bf16 / fp8 arrays round-trip through the store (np.dtype alone
    rejects their names — the ml_dtypes fallback resolves them)."""
    jnp = pytest.importorskip("jax.numpy")
    tree = {
        "bf16": jnp.asarray([[1.5, -2.25], [0.125, 3.0]], jnp.bfloat16),
        "fp8": jnp.asarray([1.0, -0.5, 2.0], jnp.float8_e4m3fn),
        "f32": np.linspace(0, 1, 7, dtype=np.float32),
        "meta": {"step": 5, "tag": "x", "blob": b"\x00\x01",
                 "tup": (1, 2.5)},
    }
    save_checkpoint(str(tmp_path), 1, tree)
    out = load_checkpoint(str(tmp_path), 1)
    assert out["bf16"].dtype == jnp.bfloat16
    assert out["fp8"].dtype == jnp.float8_e4m3fn
    np.testing.assert_array_equal(
        np.asarray(out["bf16"], np.float32),
        np.asarray(tree["bf16"], np.float32))
    np.testing.assert_array_equal(
        np.asarray(out["fp8"], np.float32),
        np.asarray(tree["fp8"], np.float32))
    assert out["meta"] == tree["meta"]


def test_keep_last_pruning(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        save_checkpoint(d, s, {"s": np.asarray([s])}, keep_last=3)
    assert list_steps(d) == [3, 4, 5]
    assert latest_step(d) == 5


def test_launch_driver_crash_and_resume(tmp_path, monkeypatch, capsys):
    """In-process end-to-end: the driver is killed mid-run, then
    relaunched with --resume — the JSONL stream is contiguous, the
    post-resume rows carry ``resumed_from``, and the stream matches an
    uninterrupted run's."""
    from repro.launch import train as launch_train

    def run(extra, ckpt, log):
        argv = ["train", "--arch", "qwen2.5-7b-smoke", "--mode", "treepo",
                "--steps", "4", "--bc-steps", "2", "--queries", "2",
                "--width", "4", "--depth", "3", "--segment", "16",
                "--seed", "5", "--eval-every", "100",
                "--ckpt-dir", ckpt, "--ckpt-interval", "1",
                "--log", log] + extra
        monkeypatch.setattr("sys.argv", argv)
        launch_train.main()

    ref_log = str(tmp_path / "ref.jsonl")
    run([], str(tmp_path / "ck_ref"), ref_log)

    crash_log = str(tmp_path / "crash.jsonl")
    ckpt = str(tmp_path / "ck")
    with pytest.raises(InjectedCrash):
        with FaultInjector().kill("train.step", at=3):
            run([], ckpt, crash_log)
    assert latest_step(ckpt) == 2
    run(["--resume"], ckpt, crash_log)

    ref_rows = [json.loads(l) for l in open(ref_log)]
    rows = [json.loads(l) for l in open(crash_log)]
    assert [r["step"] for r in rows] == [1, 2, 3, 4]
    assert "resumed_from" not in rows[0] and "resumed_from" not in rows[1]
    assert rows[2]["resumed_from"] == 2 and rows[3]["resumed_from"] == 2
    for want, got in zip(ref_rows, rows):
        assert want["step"] == got["step"]
        for k in ("reward_mean", "response_len", "num_trajectories"):
            assert want[k] == pytest.approx(got[k], abs=1e-9), k
