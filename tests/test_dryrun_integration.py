"""Dry-run integration: the 512-device placeholder platform is process-
global state, so this runs in a subprocess (whisper-tiny = the cheapest
full config).  Marked slow-ish but bounded (~1 min)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.timeout(540)
def test_dryrun_whisper_decode_single(tmp_path):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=520)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    recs = [json.loads(l) for l in out.read_text().splitlines()]
    assert len(recs) == 1
    r = recs[0]
    assert r["status"] == "ok"
    assert r["chips"] == 256
    ro = r["roofline"]
    assert ro["flops"] > 0 and ro["hbm_bytes"] > 0
    assert ro["bottleneck"] in ("compute", "memory", "collective")
    assert r["memory"] is None or r["memory"].get(
        "argument_size_in_bytes", 0) >= 0
