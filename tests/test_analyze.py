"""repro-lint (tools/analyze) rule suite: every rule R1-R8 is proven by
a failing bad-fixture and a passing good-fixture, the baseline
round-trips, stale baseline entries fail loudly, and the repo itself is
exactly clean against the checked-in baseline.

The repo-level scan runs at *collection time* (module import), mirroring
tests/test_docs.py: a new un-baselined finding fails tier-1 even under
``pytest --collect-only`` workflows.
"""
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.analyze import (DEFAULT_BASELINE, RULES, analyze_paths,
                           analyze_sources, apply_baseline, index_sources,
                           load_baseline, write_baseline)

# collection-time scan of the real tree (surfaced by test_repo_is_clean)
_REPO_FINDINGS = analyze_paths(_ROOT, ["src/repro"])
_REPO_BASELINE = load_baseline(DEFAULT_BASELINE)


def _keys(findings):
    return sorted(f.key for f in findings)


def _rules_hit(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# R1 — host-sync
# ---------------------------------------------------------------------------

R1_TRACED_BAD = '''
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def tracer_branch(x):
    s = x.sum()
    if s > 0:
        return x
    return -x

@jax.jit
def item_pull(x):
    y = x.reshape(-1)
    return y.item()

@jax.jit
def float_pull(x):
    m = x.mean()
    return float(m)

@jax.jit
def asarray_pull(x):
    a = x.astype(jnp.float32)
    return np.asarray(a)
'''

R1_TRACED_GOOD = '''
import jax
import jax.numpy as jnp

@jax.jit
def config_flags(x, causal=True, window=0):
    # literal-default params are config flags, not tracers
    if causal:
        x = x * 2
    if window > 0:
        x = x + window
    return x

@jax.jit
def static_attrs(x):
    # shape/dtype are concrete at trace time: branching is fine
    if x.ndim == 2 and x.dtype == jnp.float32:
        pass
    n = len(x)
    return jnp.where(x > 0, x, -x) * n
'''


def test_r1_traced_bad_fixture_fires():
    f = analyze_sources({"src/pkg/traced.py": R1_TRACED_BAD})
    details = {x.detail for x in f if x.rule == "R1"}
    assert any(d.startswith("tracer-bool:") for d in details)
    assert any(d.startswith("sync-method:item:") for d in details)
    assert any(d.startswith("sync-builtin:float:") for d in details)
    assert any(d.startswith("d2h:numpy.asarray:") for d in details)


def test_r1_traced_good_fixture_clean():
    f = analyze_sources({"src/pkg/traced.py": R1_TRACED_GOOD})
    assert "R1" not in _rules_hit(f), _keys(f)


# Note the fixture path: R1's host half only patrols the repo's declared
# hot-path modules, so the fixture masquerades as repro.rl.trainer.
R1_HOST_BAD = '''
import jax
import numpy as np
import jax.numpy as jnp

@jax.jit
def step(x):
    return x * 2

def pull(batch):
    out = step(batch)
    return np.asarray(out)

def push(rows):
    return jnp.asarray(rows)
'''

R1_HOST_GOOD = '''
import jax
import numpy as np

from repro.core.guard import annotated_transfer

@jax.jit
def step(x):
    return x * 2

def pull(batch):
    out = step(batch)
    return annotated_transfer(out, reason="test-pull")

def host_math(rows):
    # numpy over plain host data is not a transfer
    return np.asarray(rows).sum()
'''


def test_r1_host_bad_fixture_fires():
    f = analyze_sources({"src/repro/rl/trainer.py": R1_HOST_BAD})
    details = {x.detail for x in f if x.rule == "R1"}
    assert any(d.startswith("d2h:numpy.asarray:out") for d in details)
    assert any(d.startswith("h2d:jax.numpy.asarray:") for d in details)


def test_r1_host_good_fixture_clean():
    f = analyze_sources({"src/repro/rl/trainer.py": R1_HOST_GOOD})
    assert "R1" not in _rules_hit(f), _keys(f)


def test_r1_host_half_only_patrols_hot_path_modules():
    # identical raw-pull code in a non-hot-path module: no device
    # values cross a per-token loop there, so R1's host half stays out
    f = analyze_sources({"src/pkg/offline.py": R1_HOST_BAD})
    assert not any(x.detail.startswith("h2d:") for x in f)


# ---------------------------------------------------------------------------
# R2 — donation hygiene
# ---------------------------------------------------------------------------

R2_BAD = '''
import jax

def make_update():
    def update(params, opt_state, lp_old, batch):
        return params, opt_state, lp_old
    return jax.jit(update)
'''

R2_GOOD = R2_BAD.replace("jax.jit(update)",
                         "jax.jit(update, donate_argnums=(0, 1, 2))")

R2_USE_AFTER_DONATE = '''
import jax

def _update(params, opt_state, batch):
    return params, opt_state

def train(params, opt_state, batch):
    fn = jax.jit(_update, donate_argnums=(0, 1))
    new_p, new_o = fn(params, opt_state, batch)
    leak = params
    return new_p, new_o, leak
'''

R2_REBIND_OK = '''
import jax

def _update(params, opt_state, batch):
    return params, opt_state

def train(params, opt_state, batches):
    fn = jax.jit(_update, donate_argnums=(0, 1))
    for batch in batches:
        params, opt_state = fn(params, opt_state, batch)
    return params, opt_state
'''


def test_r2_no_donate_fires():
    f = analyze_sources({"src/pkg/upd.py": R2_BAD})
    details = {x.detail for x in f if x.rule == "R2"}
    assert "no-donate:make_update.update:params" in details
    assert "no-donate:make_update.update:opt_state" in details
    assert "no-donate:make_update.update:lp_old" in details


def test_r2_donated_clean():
    f = analyze_sources({"src/pkg/upd.py": R2_GOOD})
    assert "R2" not in _rules_hit(f), _keys(f)


def test_r2_use_after_donate_fires():
    f = analyze_sources({"src/pkg/upd.py": R2_USE_AFTER_DONATE})
    details = {x.detail for x in f if x.rule == "R2"}
    assert "use-after-donate:params" in details


def test_r2_same_statement_rebind_is_clean():
    # the idiomatic `params, opt_state = fn(params, opt_state, ...)`
    # loop revives the donated names every iteration
    f = analyze_sources({"src/pkg/upd.py": R2_REBIND_OK})
    assert not any(x.detail.startswith("use-after-donate")
                   for x in f), _keys(f)


# ---------------------------------------------------------------------------
# R3 — recompile hazards
# ---------------------------------------------------------------------------

R3_JIT_IN_LOOP = '''
import jax

def run(xs):
    out = []
    for x in xs:
        f = jax.jit(lambda a: a + 1)
        out.append(f(x))
    return out
'''

R3_HOISTED = '''
import jax

def run(xs):
    f = jax.jit(lambda a: a + 1)
    return [f(x) for x in xs]
'''

R3_UNHASHABLE = '''
import functools
import jax

@functools.partial(jax.jit, static_argnames=("dims",))
def kernel(x, dims):
    return x

def call_bad(x):
    return kernel(x, dims=[1, 2])

def call_good(x):
    return kernel(x, dims=(1, 2))
'''

R3_CLOSURE = '''
import jax

def make(cfg):
    tables = [1, 2, 3]

    @jax.jit
    def f(x):
        return x + tables[0]

    return f
'''

R3_SHAPE_BRANCH = '''
import jax

@jax.jit
def f(x):
    if x.ndim == 3:
        return x.sum()
    return x
'''


def test_r3_jit_in_loop_fires_and_hoisted_is_clean():
    bad = analyze_sources({"src/pkg/loop.py": R3_JIT_IN_LOOP})
    assert any(x.detail.startswith("jit-in-loop") for x in bad)
    good = analyze_sources({"src/pkg/loop.py": R3_HOISTED})
    assert not any(x.detail.startswith("jit-in-loop") for x in good)


def test_r3_unhashable_static_fires_on_list_not_tuple():
    f = analyze_sources({"src/pkg/stat.py": R3_UNHASHABLE})
    hits = [x for x in f if x.detail.startswith("unhashable-static")]
    assert len(hits) == 1
    assert hits[0].func == "call_bad"
    assert hits[0].detail == "unhashable-static:kernel:dims"


def test_r3_mutable_closure_capture_fires():
    f = analyze_sources({"src/pkg/clos.py": R3_CLOSURE})
    assert any(x.detail == "closure-mutable:tables" for x in f)


def test_r3_shape_branch_fires():
    f = analyze_sources({"src/pkg/shp.py": R3_SHAPE_BRANCH})
    assert any(x.detail.startswith("shape-branch:x.ndim") for x in f)


# ---------------------------------------------------------------------------
# R4 — kernel-surface parity (the PR-5 bug class, made unrepresentable)
# ---------------------------------------------------------------------------

_R4_REF = '''
def attn_ref(q, k, v, *, causal=True, segment_ids=None):
    return q
'''

_R4_PALLAS_DESYNCED = '''
def attn_pallas(q, k, v, *, causal=True, blk_q=64, interpret=False):
    return q
'''

_R4_PALLAS_SYNCED = '''
def attn_pallas(q, k, v, *, causal=True, segment_ids=None,
                blk_q=64, interpret=False):
    return q
'''

_R4_OPS = '''
from pkg.kernels.flash import attn_pallas
from pkg.kernels.ref import attn_ref

def attn(q, k, v, *, causal=True, segment_ids=None, use_pallas=True):
    if use_pallas:
        return attn_pallas(q, k, v, causal=causal)
    return attn_ref(q, k, v, causal=causal, segment_ids=segment_ids)
'''

_R4_OPS_NO_PLUMB = '''
from pkg.kernels.flash import attn_pallas
from pkg.kernels.ref import attn_ref

def attn(q, k, v, *, causal=True, use_pallas=True):
    if use_pallas:
        return attn_pallas(q, k, v, causal=causal)
    return attn_ref(q, k, v, causal=causal)
'''


def test_r4_desynced_kernel_signature_fires():
    """A pallas kernel that silently drops ``segment_ids`` (exactly the
    packing bug PR 5 fixed by hand) must be an R4 finding."""
    f = analyze_sources({
        "src/pkg/kernels/ops.py": _R4_OPS,
        "src/pkg/kernels/flash.py": _R4_PALLAS_DESYNCED,
        "src/pkg/kernels/ref.py": _R4_REF,
    })
    details = {x.detail for x in f if x.rule == "R4"}
    assert "pallas-missing:attn_pallas:segment_ids" in details


def test_r4_synced_kernels_clean_despite_pallas_knobs():
    # blk_q / interpret are pallas-only tuning knobs, not surface drift
    f = analyze_sources({
        "src/pkg/kernels/ops.py": _R4_OPS,
        "src/pkg/kernels/flash.py": _R4_PALLAS_SYNCED,
        "src/pkg/kernels/ref.py": _R4_REF,
    })
    assert "R4" not in _rules_hit(f), _keys(f)


def test_r4_dispatch_must_plumb_segment_ids():
    f = analyze_sources({
        "src/pkg/kernels/ops.py": _R4_OPS_NO_PLUMB,
        "src/pkg/kernels/flash.py": _R4_PALLAS_SYNCED,
        "src/pkg/kernels/ref.py": _R4_REF,
    })
    details = {x.detail for x in f if x.rule == "R4"}
    assert "dispatch-missing:attn:segment_ids" in details


def test_r4_ref_only_op_is_allowed():
    f = analyze_sources({
        "src/pkg/kernels/ops.py": (
            "from pkg.kernels.ref import attn_ref\n"
            "def decode_attn(q, k, v):\n"
            "    return attn_ref(q, k, v)\n"),
        "src/pkg/kernels/ref.py": _R4_REF,
    })
    assert "R4" not in _rules_hit(f), _keys(f)


# ---------------------------------------------------------------------------
# R5 — KV page/slot lifecycle (CFG dataflow over alloc/release tails)
# ---------------------------------------------------------------------------

R5_LEAK_ON_EXIT = '''
def grab(pool):
    pid = pool.alloc()
    return 0
'''

R5_LEAK_ON_RAISE = '''
def build_pair(pool):
    a = pool.alloc()
    b = pool.alloc()       # may raise OutOfPages: `a` leaks
    pool.release(a)
    pool.release(b)
'''

R5_RAISE_SAFE = '''
def build_pair(pool):
    a = pool.alloc()
    try:
        b = pool.alloc()
    except Exception:
        pool.release(a)
        raise
    pool.release(a)
    pool.release(b)
'''

R5_DOUBLE_RELEASE = '''
def drop_twice(pool):
    pid = pool.alloc()
    pool.release(pid)
    pool.release(pid)
'''

R5_USE_AFTER_RELEASE = '''
def regrow(self):
    child = self.make_child()
    self._ensure_capacity(child, 4)
    self.release_path(child)
    self._ensure_capacity(child, 8)
'''

R5_TRANSFERRED = '''
def grab(pool, paths):
    pid = pool.alloc()
    paths.append(pid)      # ownership moves to the container
    return 0
'''


def test_r5_leak_on_exit_fires():
    f = analyze_sources({"src/pkg/kv.py": R5_LEAK_ON_EXIT})
    assert any(x.detail == "leak:pid" for x in f), _keys(f)


def test_r5_leak_on_raise_fires_and_tryexcept_is_clean():
    bad = analyze_sources({"src/pkg/kv.py": R5_LEAK_ON_RAISE})
    assert any(x.detail == "leak-on-raise:a" for x in bad), _keys(bad)
    good = analyze_sources({"src/pkg/kv.py": R5_RAISE_SAFE})
    assert "R5" not in _rules_hit(good), _keys(good)


def test_r5_double_release_fires():
    f = analyze_sources({"src/pkg/kv.py": R5_DOUBLE_RELEASE})
    assert any(x.detail == "double-release:pid" for x in f), _keys(f)


def test_r5_use_after_release_fires():
    f = analyze_sources({"src/pkg/kv.py": R5_USE_AFTER_RELEASE})
    assert any(x.detail == "use-after-release:child" for x in f), _keys(f)


def test_r5_ownership_transfer_is_clean():
    f = analyze_sources({"src/pkg/kv.py": R5_TRANSFERRED})
    assert "R5" not in _rules_hit(f), _keys(f)


# ---------------------------------------------------------------------------
# R6 — path-FSM conformance (declared transition table)
# ---------------------------------------------------------------------------

R6_UNDECLARED = '''
def rogue_cleanup(engine, path):
    engine.release_path(path)
'''

R6_DOUBLE_RELEASE_PATH = '''
def drop(engine, path):
    engine.release_path(path)
    engine.release_path(path)
'''

R6_BRANCH_AFTER_PREEMPT = '''
def bad_branch(engine, path):
    engine.preempt_path(path)
    engine.fork_paths([path])
'''

R6_USE_AFTER_RELEASE_PATH = '''
def bad_decode(engine, path):
    engine.release_path(path)
    engine.decode_segments([path])
'''

# a declared site (module + qualname in FSM_TRANSITIONS) is legal
R6_DECLARED = '''
def _release_leaf_kv(engine, path):
    engine.release_path(path)
'''

R6_RESTORE_THEN_BRANCH = '''
def ok_branch(engine, path):
    engine.preempt_path(path)
    path = engine.restore_path([1, 2])
    engine.fork_paths([path])
'''


def test_r6_undeclared_transition_fires():
    f = analyze_sources({"src/pkg/fsm.py": R6_UNDECLARED})
    assert any(x.detail == "undeclared:release" for x in f), _keys(f)


def test_r6_declared_site_is_clean():
    f = analyze_sources({"src/repro/core/sampler.py": R6_DECLARED})
    assert "R6" not in _rules_hit(f), _keys(f)


def test_r6_double_release_path_fires():
    f = analyze_sources({"src/pkg/fsm.py": R6_DOUBLE_RELEASE_PATH})
    assert any(x.detail == "double-release-path:path" for x in f), _keys(f)


def test_r6_branch_after_preempt_fires_and_restore_clears():
    bad = analyze_sources({"src/pkg/fsm.py": R6_BRANCH_AFTER_PREEMPT})
    assert any(x.detail == "branch-after-preempt:path"
               for x in bad), _keys(bad)
    good = analyze_sources({"src/pkg/fsm.py": R6_RESTORE_THEN_BRANCH})
    assert not any(x.detail.startswith("branch-after-preempt")
                   for x in good), _keys(good)


def test_r6_use_after_release_path_fires():
    f = analyze_sources({"src/pkg/fsm.py": R6_USE_AFTER_RELEASE_PATH})
    assert any(x.detail == "use-after-release-path:path"
               for x in f), _keys(f)


# ---------------------------------------------------------------------------
# R7 — PRNG-key discipline
# ---------------------------------------------------------------------------

R7_KEY_REUSE = '''
import jax

def sample(key):
    a = jax.random.normal(key, (2,))
    b = jax.random.uniform(key, (2,))
    return a + b
'''

R7_SPLIT_OK = '''
import jax

def sample(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (2,))
    b = jax.random.uniform(k2, (2,))
    return a + b
'''

R7_SPLIT_DROP = '''
import jax

def advance(key):
    extra = jax.random.split(key)
    return key
'''

R7_HOST_RNG = '''
import random

def make_rng(seed):
    return random.Random(seed)
'''


def test_r7_key_reuse_fires_and_split_is_clean():
    bad = analyze_sources({"src/pkg/rng.py": R7_KEY_REUSE})
    assert any(x.detail == "key-reuse:key" for x in bad), _keys(bad)
    good = analyze_sources({"src/pkg/rng.py": R7_SPLIT_OK})
    assert "R7" not in _rules_hit(good), _keys(good)


def test_r7_split_and_drop_fires():
    f = analyze_sources({"src/pkg/rng.py": R7_SPLIT_DROP})
    assert any(x.detail == "split-drop:extra" for x in f), _keys(f)


def test_r7_host_rng_fires_outside_captured_modules():
    bad = analyze_sources({"src/pkg/rng.py": R7_HOST_RNG})
    assert any(x.detail == "host-rng:random.Random" for x in bad), _keys(bad)
    # the trainer's generators ARE the checkpoint-captured state
    good = analyze_sources({"src/repro/rl/trainer.py": R7_HOST_RNG})
    assert not any(x.detail.startswith("host-rng") for x in good), _keys(good)


# ---------------------------------------------------------------------------
# R8 — sharding-spec consistency (needs a declared mesh to arm)
# ---------------------------------------------------------------------------

_R8_MESH = '''
import jax

def build_mesh(devices):
    return jax.make_mesh((2, 4), ("data", "model"))
'''

_R8_BAD_AXIS = '''
from jax.sharding import PartitionSpec as P

def spec():
    return P("data", "modle")
'''

_R8_GOOD_AXIS = '''
from jax.sharding import PartitionSpec as P

def spec():
    return P("data", None, "model")
'''

_R8_BAD_DONATE = '''
import jax
from jax.sharding import PartitionSpec as P

def jit_step(fn):
    shard = (P("data"), P("model"))
    return jax.jit(fn, in_shardings=shard, donate_argnums=(0, 5))
'''


def test_r8_bad_axis_fires_and_good_axes_clean():
    bad = analyze_sources({"src/pkg/mesh.py": _R8_MESH,
                           "src/pkg/spec.py": _R8_BAD_AXIS})
    assert any(x.detail == "bad-axis:modle" for x in bad), _keys(bad)
    good = analyze_sources({"src/pkg/mesh.py": _R8_MESH,
                            "src/pkg/spec.py": _R8_GOOD_AXIS})
    assert "R8" not in _rules_hit(good), _keys(good)


def test_r8_donate_out_of_range_fires():
    f = analyze_sources({"src/pkg/mesh.py": _R8_MESH,
                         "src/pkg/spec.py": _R8_BAD_DONATE})
    assert any(x.detail == "donate-out-of-range:5" for x in f), _keys(f)


def test_r8_inert_without_declared_mesh():
    # no mesh anywhere in the index -> nothing to validate against
    f = analyze_sources({"src/pkg/spec.py": _R8_BAD_AXIS})
    assert "R8" not in _rules_hit(f), _keys(f)


# ---------------------------------------------------------------------------
# baseline round-trip + staleness
# ---------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    findings = analyze_sources({"src/pkg/upd.py": R2_BAD})
    assert findings
    path = tmp_path / "baseline.json"
    write_baseline(str(path), findings, previous={})
    bl = load_baseline(str(path))
    new, stale = apply_baseline(findings, bl)
    assert new == [] and stale == []


def test_baseline_keeps_hand_written_justifications(tmp_path):
    findings = analyze_sources({"src/pkg/upd.py": R2_BAD})
    path = tmp_path / "baseline.json"
    write_baseline(str(path), findings, previous={})
    bl = load_baseline(str(path))
    key = findings[0].key
    bl[key] = "hand-written: kept on purpose"
    write_baseline(str(path), findings, previous=bl)
    assert load_baseline(str(path))[key] == "hand-written: kept on purpose"


def test_stale_baseline_entry_fails_loudly():
    findings = analyze_sources({"src/pkg/upd.py": R2_GOOD})
    stale_bl = {"R2:pkg.upd:make_update:no-donate:make_update.update:"
                "params": "fixed long ago"}
    new, stale = apply_baseline(findings, stale_bl)
    assert new == []
    assert stale == sorted(stale_bl)    # the fixed entry surfaces as stale


def test_baseline_rejects_wrong_version(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 99, "entries": {}}))
    with pytest.raises(ValueError):
        load_baseline(str(path))


def test_finding_keys_are_line_number_free():
    f1 = analyze_sources({"src/pkg/upd.py": R2_BAD})
    f2 = analyze_sources({"src/pkg/upd.py": "\n\n\n" + R2_BAD})
    assert _keys(f1) == _keys(f2)       # shifting lines keeps keys stable
    assert all(f.lineno != f2[i].lineno for i, f in enumerate(f1))


# ---------------------------------------------------------------------------
# the repo itself
# ---------------------------------------------------------------------------

def test_repo_is_clean_against_baseline():
    """`python -m tools.analyze src/repro` must exit 0: every finding in
    the tree is either fixed or justified in tools/analyze/baseline.json
    — and every baseline entry still corresponds to a live finding."""
    new, stale = apply_baseline(_REPO_FINDINGS, _REPO_BASELINE)
    assert not new, "un-baselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert not stale, "stale baseline entries:\n" + "\n".join(stale)


def test_repo_rule_set_is_non_empty_and_proven():
    """The analyzer is not vacuous: the baseline carries real findings
    from >1 rule, and RULES documents all eight."""
    assert set(RULES) == {"R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"}
    assert len(_REPO_BASELINE) >= 1
    assert len({k.split(":", 1)[0] for k in _REPO_BASELINE}) >= 2


def test_cli_clean_exit_and_explain():
    env = dict(os.environ, PYTHONPATH=_ROOT)
    r = subprocess.run([sys.executable, "-m", "tools.analyze",
                        "src/repro"], cwd=_ROOT, env=env,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    for rule_id, doc in RULES.items():
        r = subprocess.run([sys.executable, "-m", "tools.analyze",
                            "--explain", rule_id], cwd=_ROOT, env=env,
                           capture_output=True, text=True)
        assert r.returncode == 0
        assert doc.title in r.stdout
        assert doc.doc_anchor in r.stdout


def test_cli_nonzero_on_new_finding(tmp_path):
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "upd.py").write_text(R2_BAD)
    env = dict(os.environ, PYTHONPATH=_ROOT)
    r = subprocess.run([sys.executable, "-m", "tools.analyze",
                        "--no-baseline", "--root", str(tmp_path),
                        "src/pkg"], cwd=str(tmp_path),
                       env=env, capture_output=True, text=True)
    assert r.returncode == 1
    assert "does not donate" in r.stdout


def test_cli_github_format_emits_error_annotations(tmp_path):
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "upd.py").write_text(R2_BAD)
    env = dict(os.environ, PYTHONPATH=_ROOT)
    r = subprocess.run([sys.executable, "-m", "tools.analyze",
                        "--no-baseline", "--format", "github",
                        "--root", str(tmp_path), "src/pkg"],
                       cwd=str(tmp_path), env=env,
                       capture_output=True, text=True)
    assert r.returncode == 1
    assert "::error file=src/pkg/upd.py,line=" in r.stdout
    assert "title=R2" in r.stdout


def test_cli_stale_entry_suggests_nearest_live_key(tmp_path):
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "upd.py").write_text(R2_BAD)
    findings = analyze_sources({"src/pkg/upd.py": R2_BAD})
    live_key = next(k for k in _keys(findings) if k.endswith(":params"))
    bl = tmp_path / "baseline.json"
    typo = live_key.replace(":params", ":paramz")
    bl.write_text(json.dumps({
        "version": 1,
        "entries": {k: "ok" for k in _keys(findings) if k != live_key}
        | {typo: "typo'd entry"}}))
    env = dict(os.environ, PYTHONPATH=_ROOT)
    r = subprocess.run([sys.executable, "-m", "tools.analyze",
                        "--baseline", str(bl), "--root", str(tmp_path),
                        "src/pkg"], cwd=str(tmp_path), env=env,
                       capture_output=True, text=True)
    assert r.returncode == 1
    assert "stale baseline" in r.stdout
    assert f"nearest live finding: {live_key}" in r.stdout


def test_cli_changed_only_is_clean_on_repo():
    """--changed-only narrows reporting to the git diff (stale detection
    off); on the repo it must agree with the full run's exit 0."""
    env = dict(os.environ, PYTHONPATH=_ROOT)
    r = subprocess.run([sys.executable, "-m", "tools.analyze",
                        "--changed-only", "src/repro"], cwd=_ROOT,
                       env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_index_resolves_aliased_imports():
    idx = index_sources({"src/pkg/m.py": (
        "import numpy as xp\nimport jax.numpy as jnp\n"
        "from jax import lax\n"
        "def f(x):\n    return xp.asarray(x)\n")})
    mod = idx.modules["pkg.m"]
    fi = mod.functions["f"]
    call = fi.node.body[0].value
    assert idx.dotted_name(mod, call.func) == "numpy.asarray"
