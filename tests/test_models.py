"""Per-architecture smoke tests: reduced (2-layer, d_model<=512, <=4-expert)
variant of every assigned config runs one forward and one train step on CPU
with correct shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.loss import token_logprobs_from_logits
from repro.models.model import decode_step, forward, init_params, prefill, \
    zeros_cache

ARCHS = list(ASSIGNED_ARCHS) + ["qwen2.5-7b"]


def _inputs(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        kw["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.frontend.num_prefix_tokens, cfg.frontend.embed_dim))
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(
            key, (B, 8, cfg.encoder.d_model))
    return toks, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks, kw = _inputs(cfg)
    logits, aux = forward(params, cfg, toks, **kw)
    S_tot = toks.shape[1] + (cfg.frontend.num_prefix_tokens
                             if cfg.frontend is not None
                             and cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (2, S_tot, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One PG-style gradient step: finite loss, finite grads, params move."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks, kw = _inputs(cfg)

    def loss_fn(p):
        logits, aux = forward(p, cfg, toks, **kw)
        logits = logits[:, -toks.shape[1]:]
        lp = token_logprobs_from_logits(logits[:, :-1], toks[:, 1:])
        return -lp.mean() + (0.01 * aux if cfg.moe is not None else 0.0)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ["yi-6b", "jamba-v0.1-52b", "rwkv6-7b",
                                  "whisper-tiny"])
def test_smoke_decode_matches_forward(arch):
    """prefill + N dense decode steps == teacher-forced forward."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, Sp, N = 2, 6, 5
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, Sp + N), 0, cfg.vocab_size)
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(key, (B, 8, cfg.encoder.d_model))
    logits_ref, _ = forward(params, cfg, toks, **kw)
    logits_p, cache = prefill(params, cfg, toks[:, :Sp], Sp + N,
                              dtype=jnp.float32, **kw)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_ref[:, Sp - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(N - 1):
        pos = jnp.full((B,), Sp + t, jnp.int32)
        logits_d, cache = decode_step(params, cfg, toks[:, Sp + t], cache,
                                      pos)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(logits_ref[:, Sp + t]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_binds():
    """gemma3 local layers actually mask beyond the window."""
    cfg = get_config("gemma3-12b", smoke=True)
    assert cfg.sliding_window == 64
    # shrink window so it binds at S=96
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    S = 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                              cfg.vocab_size)
    logits1, _ = forward(params, cfg, toks)
    # perturb an early token: with window=16, logits at the end should be
    # affected only through global layers (layer 2 here is local+local ->
    # change propagates via residual, so instead check window masking math
    # directly through the kernel ref in test_kernels).  Here: no NaN and
    # different from full-attention variant.
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    logits2, _ = forward(params, cfg_full, toks)
    assert not np.allclose(np.asarray(logits1), np.asarray(logits2))


def test_num_params_analytic_close():
    """Analytic count matches the real pytree within 5% (smoke scale)."""
    for arch in ["yi-6b", "olmoe-1b-7b", "rwkv6-7b"]:
        cfg = get_config(arch, smoke=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        est = cfg.num_params()
        assert abs(real - est) / real < 0.25, (arch, real, est)
