"""Runtime lifecycle tracker (repro.core.lifecycle) — the dynamic twin
of the R5/R6 static rules in tools/analyze/verify.py.

Each test either drives a *clean* sequence (guard must stay silent) or
injects the exact defect class a rule covers (guard must name it)."""
import random

import jax
import pytest

from repro.configs import get_config
from repro.configs.base import TreeConfig
from repro.core.engine import TreeEngine
from repro.core.lifecycle import (LifecycleViolation, lifecycle_guard)
from repro.core.sampler import sample_trees
from repro.kv.cache import PagePool, SlotAllocator
from repro.models.model import init_params

TC = TreeConfig(max_depth=3, segment_len=8, max_width=3, branch_factor=2,
                init_divergence_low=2, init_divergence_high=2,
                temperature=1.0)


def _engine(arch="yi-6b", **kw):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    kwargs = dict(num_pages=256, page_size=8, max_slots=16, max_queries=4,
                  max_prompt_len=32, seed=0)
    kwargs.update(kw)
    return TreeEngine(params, cfg, TC, **kwargs)


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------

def test_clean_pool_sequence_is_silent():
    pool = PagePool(num_pages=8)
    with lifecycle_guard() as rep:
        a = pool.alloc()
        b = pool.alloc()
        pool.retain(a)
        pool.release(a)
        pool.release(a)
        pool.release(b)
    assert rep.violations == []
    assert rep.page_allocs == 2
    assert rep.page_retains == 1
    assert rep.page_releases == 3
    assert pool.pages_in_use == 0


def test_page_double_release_is_reported():
    pool = PagePool(num_pages=4)
    with lifecycle_guard(raise_on_violation=False) as rep:
        pid = pool.alloc()
        pool.release(pid)
        # the pool's own assert still fires; the guard reports first
        with pytest.raises(AssertionError):
            pool.release(pid)
    assert any("double release" in v for v in rep.violations)


def test_retain_after_free_is_reported():
    pool = PagePool(num_pages=4)
    with lifecycle_guard(raise_on_violation=False) as rep:
        pid = pool.alloc()
        pool.release(pid)
        with pytest.raises(AssertionError):
            pool.retain(pid)
    assert any("retain" in v and "no live refcount" in v
               for v in rep.violations)


def test_violations_raise_at_guard_exit():
    pool = PagePool(num_pages=4)
    with pytest.raises(LifecycleViolation, match="double release"):
        with lifecycle_guard():
            pid = pool.alloc()
            pool.release(pid)
            try:
                pool.release(pid)
            except AssertionError:
                pass


def test_pool_created_before_arming_is_snapshotted():
    pool = PagePool(num_pages=8)
    held = pool.alloc()      # pre-existing refcount, e.g. the garbage page
    with lifecycle_guard() as rep:
        pid = pool.alloc()
        pool.retain(held)    # legal: snapshot saw the live refcount
        pool.release(held)
        pool.release(pid)
    assert rep.violations == []
    pool.release(held)


# ---------------------------------------------------------------------------
# slots — SlotAllocator has *no* native refcounts: a double release
# silently hands one slot to two paths.  Only the guard catches it.
# ---------------------------------------------------------------------------

def test_slot_double_release_is_reported():
    slots = SlotAllocator(num_slots=4)
    with lifecycle_guard(raise_on_violation=False) as rep:
        s = slots.alloc()
        slots.release(s)
        slots.release(s)     # native code is happy to corrupt the list
    assert any("double release of slot" in v for v in rep.violations)


def test_clean_slot_churn_is_silent():
    slots = SlotAllocator(num_slots=4)
    with lifecycle_guard() as rep:
        for _ in range(8):
            a, b = slots.alloc(), slots.alloc()
            slots.release(b)
            slots.release(a)
    assert rep.violations == []
    assert rep.slot_allocs == 16 and rep.slot_releases == 16


# ---------------------------------------------------------------------------
# path FSM
# ---------------------------------------------------------------------------

def test_fork_of_released_path_is_reported():
    eng = _engine()
    with lifecycle_guard(raise_on_violation=False) as rep:
        [root] = eng.prefill_queries([[1, 2, 3, 4, 5]])
        eng.release_path(root)
        try:
            eng.fork_paths([root])
        except Exception:
            pass
    assert any("fork_paths on a released path" in v for v in rep.violations)


def test_decode_of_released_path_is_reported():
    eng = _engine()
    with lifecycle_guard(raise_on_violation=False) as rep:
        [root] = eng.prefill_queries([[1, 2, 3, 4, 5]])
        eng.preempt_path(root)
        try:
            eng.decode_segments([root])
        except Exception:
            pass
    assert any("decode_segments on a released path" in v
               for v in rep.violations)


def test_engine_fork_release_cycle_is_silent():
    eng = _engine()
    baseline = eng.kv.pool.pages_in_use   # garbage page etc.
    with lifecycle_guard() as rep:
        [root] = eng.prefill_queries([[1, 2, 3, 4, 5]])
        kids = eng.fork_paths([root])
        eng.decode_segments([root] + kids)
        child = eng.fork_from_prefix(root, 3, [1, 2, 3])
        for p in kids + [child]:
            eng.release_path(p)
        eng.preempt_path(root)
        restored = eng.restore_path([1, 2, 3, 4, 5])
        eng.release_path(restored)
    assert rep.violations == []
    assert rep.forks >= 1 and rep.preempts == 1 and rep.restores == 1
    assert eng.kv.pool.pages_in_use == baseline


@pytest.mark.parametrize("fused_kv", [True, False])
def test_fork_copy_failure_rolls_back_cleanly(fused_kv):
    """Satellite (K/V COW desync): a failure inside the jitted
    ``kv.apply_forks`` dispatch must not leave half-applied fork state.

    On device the copy is atomic by construction — the fused pool ships
    K and V in one array (a child can never hold copied K with stale V),
    and even on the legacy split path the pools are only rebound after
    the jitted fn returns.  What CAN leak is host state: the round's
    fresh COW pages, slots and table retains.  ``fork_paths`` must hand
    those back via ``release_partial`` and leave the parent decodable."""
    from repro.core.faults import FaultInjector
    from repro.kv.cache import OutOfPages

    eng = _engine(fused_kv=fused_kv)
    with lifecycle_guard() as rep:
        # 5 tokens, page_size 8 → partial tail page → fork must COW,
        # so apply_forks is guaranteed to run (and to be killed)
        [root] = eng.prefill_queries([[1, 2, 3, 4, 5]])
        baseline = eng.kv.pool.pages_in_use
        root_table = list(root.table)
        with FaultInjector(seed=0).on("kv.apply_forks", at=1):
            with pytest.raises(OutOfPages, match="injected"):
                eng.fork_paths([root])
        # full rollback: every COW page / table retain is back in the pool
        assert eng.kv.pool.pages_in_use == baseline
        # parent untouched and still usable: fork + decode succeed
        assert root.table == root_table and not root.released
        kids = eng.fork_paths([root])
        eng.decode_segments([root] + kids)
        for k in kids:
            eng.release_path(k)
        eng.release_path(root)
    assert rep.violations == []


def test_sampler_end_to_end_under_guard():
    """A full tree-sampling round must satisfy every runtime invariant."""
    eng = _engine()
    with lifecycle_guard() as rep:
        trees, _ = sample_trees(eng, [[1, 2, 3, 4, 5, 6, 7]], ["x"],
                                rng=random.Random(1))
    assert trees[0].num_trajectories >= 1
    assert rep.violations == []
    assert rep.page_allocs > 0 and rep.page_releases > 0


def test_guard_unpatches_on_exit():
    before = PagePool.alloc
    with lifecycle_guard():
        assert PagePool.alloc is not before
        with lifecycle_guard():     # nesting refcounts, no double patch
            pass
        assert PagePool.alloc is not before
    assert PagePool.alloc is before
