"""Per-kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.paged_attention import (
    fused_paged_attention_pallas,
    mla_fused_paged_attention_pallas,
    mla_paged_attention_pallas,
    paged_attention_pallas,
)
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.wkv6 import wkv6_pallas
from repro.kv.layout import (deinterleave_kv, fuse_mla, interleave_kv,
                             split_mla)

# every test here executes real Pallas kernel bodies through the CPU
# interpreter — select with `-m pallas_interpret`, skip with
# `-m "not pallas_interpret"`; they run (and pass) under plain tier-1.
pytestmark = [pytest.mark.pallas_interpret, pytest.mark.kernels]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 32), (2, 7, 96), (1, 129, 64),
                                   (3, 5, 2, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape).astype(dtype)
    sc = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
    got = rmsnorm_pallas(x, sc, interpret=True, block_rows=32)
    want = ref.rmsnorm_ref(x, sc)
    assert got.dtype == want.dtype
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,skv,hq,hkv", [
    (16, 16, 4, 4),      # MHA, aligned
    (37, 37, 4, 2),      # GQA 2:1, ragged
    (8, 40, 4, 1),       # MQA, chunked-prefill style (q_offset)
    (64, 64, 8, 8),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
def test_flash_attention(sq, skv, hq, hkv, causal, window):
    B, D = 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, sq, hq, D))
    k = jax.random.normal(ks[1], (B, skv, hkv, D))
    v = jax.random.normal(ks[2], (B, skv, hkv, D))
    q_off = skv - sq
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_offset=q_off, blk_q=16, blk_k=16,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_off)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("bias_shape", [(2, 4, 37, 48), (1, 4, 37, 48),
                                        (2, 1, 37, 48), (37, 48)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_bias(bias_shape, causal):
    """Additive attention bias (ALiBi/relative-position style), every
    broadcast rank the ref accepts, ragged blocks + chunked prefill."""
    B, Sq, Skv, Hq, Hkv, D = 2, 37, 48, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D))
    bias = jax.random.normal(ks[3], bias_shape) * 2.0
    q_off = Skv - Sq
    got = flash_attention_pallas(q, k, v, causal=causal, q_offset=q_off,
                                 bias=bias, blk_q=16, blk_k=16,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, q_offset=q_off,
                             bias=bias)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
    # sanity: the bias actually changed the result
    plain = ref.attention_ref(q, k, v, causal=causal, q_offset=q_off)
    assert not np.allclose(np.asarray(want), np.asarray(plain))


def test_flash_attention_bias_with_segments():
    """bias and segment_ids compose: mask first, bias on masked logits."""
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(13), 4)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    bias = jax.random.normal(ks[3], (B, Hq, S, S))
    seg = np.full((B, S), -1, np.int32)
    seg[0, :12], seg[0, 12:28] = 0, 1
    seg[1, :20], seg[1, 20:30] = 0, 1
    seg = jnp.asarray(seg)
    got = flash_attention_pallas(q, k, v, causal=True, segment_ids=seg,
                                 bias=bias, blk_q=16, blk_k=16,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, segment_ids=seg,
                             bias=bias)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 12),
                                           (False, 0)])
def test_flash_attention_segment_ids(causal, window):
    """Sequence-packed rows: attention restricted to same-segment pairs
    (ragged segment layout per batch row, -1 tail pads)."""
    B, S, Hq, Hkv, D = 2, 48, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    seg = np.full((B, S), -1, np.int32)
    seg[0, :10], seg[0, 10:30], seg[0, 30:44] = 0, 1, 2
    seg[1, :25], seg[1, 25:40] = 0, 1
    seg = jnp.asarray(seg)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 segment_ids=seg, blk_q=16, blk_k=16,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             segment_ids=seg)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
    # sanity: the segment mask actually changed the result
    plain = ref.attention_ref(q, k, v, causal=causal, window=window)
    assert not np.allclose(np.asarray(want), np.asarray(plain))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 12)])
def test_flash_attention_segment_ids_chunked_prefill(causal, window):
    """Chunked prefill packs too: segment_ids label the KV axis and the
    q chunk's labels are the slice at q_offset.  A SHARED (-2) prefix
    block is attendable by every segment."""
    B, Sq, Skv, Hq, Hkv, D = 2, 16, 48, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D))
    seg = np.full((B, Skv), -1, np.int32)
    seg[0, :6] = ref.SHARED_SEGMENT_ID          # shared modality prefix
    seg[0, 6:24], seg[0, 24:44] = 0, 1
    seg[1, :30], seg[1, 30:48] = 0, 1
    seg = jnp.asarray(seg)
    q_off = Skv - Sq
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_offset=q_off, segment_ids=seg,
                                 blk_q=16, blk_k=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_off, segment_ids=seg)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
    # the segment mask binds, and the shared prefix really is attended:
    # scrubbing it changes row 0's output
    plain = ref.attention_ref(q, k, v, causal=causal, window=window,
                              q_offset=q_off)
    assert not np.allclose(np.asarray(want), np.asarray(plain))
    if window == 0:  # a binding window already hides the distant prefix
        seg_noshare = seg.at[0, :6].set(-1)
        scrubbed = ref.attention_ref(q, k, v, causal=causal, window=window,
                                     q_offset=q_off,
                                     segment_ids=seg_noshare)
        assert not np.allclose(np.asarray(want)[0], np.asarray(scrubbed)[0])


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    B, S, H, D = 1, 33, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, D)).astype(dtype)
    got = flash_attention_pallas(q, k, v, blk_q=16, blk_k=16, interpret=True)
    want = ref.attention_ref(q, k, v)
    assert got.dtype == dtype
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv,window", [(4, 2, 0), (8, 8, 0), (4, 1, 12),
                                           (4, 2, 5)])
def test_paged_attention(hq, hkv, window):
    B, D, P, page, MP = 3, 32, 24, 8, 5
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, hq, D))
    kp = jax.random.normal(ks[1], (P, page, hkv, D))
    vp = jax.random.normal(ks[2], (P, page, hkv, D))
    tables = jnp.array([[3, 5, 1, -1, -1],
                        [0, 2, 7, 9, -1],
                        [11, 12, 13, 14, 15]], jnp.int32)
    lengths = jnp.array([19, 26, 40], jnp.int32)
    got = paged_attention_pallas(q, kp, vp, tables, lengths, page_size=page,
                                 window=window, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths,
                                   page_size=page, window=window)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_paged_vs_dense_decode():
    """Paged attention == dense decode attention on the same KV."""
    B, S, Hq, Hkv, D, page = 2, 24, 4, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    lengths = jnp.array([17, 24], jnp.int32)
    # build pools from the dense cache
    kp = k.reshape(B * S // page, page, Hkv, D)
    vp = v.reshape(B * S // page, page, Hkv, D)
    tables = jnp.array([[0, 1, 2], [3, 4, 5]], jnp.int32)
    got = paged_attention_pallas(q, kp, vp, tables, lengths, page_size=page,
                                 interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# pipelined fused-pool paged attention (multi-buffered page DMA)
# ---------------------------------------------------------------------------

def _fused_inputs(key, B, hq, hkv, D, P, page):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, hq, D))
    kp = jax.random.normal(ks[1], (P, page, hkv, D))
    vp = jax.random.normal(ks[2], (P, page, hkv, D))
    return q, kp, vp, interleave_kv(kp, vp)


def test_kv_layout_roundtrip():
    """interleave/deinterleave and fuse/split are exact inverses — the one
    layout contract every producer/consumer shares."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    k = jax.random.normal(ks[0], (6, 8, 3, 16))
    v = jax.random.normal(ks[1], (6, 8, 3, 16))
    kv = interleave_kv(k, v)
    assert kv.shape == (6, 8, 6, 16)
    # head axis is [K0, V0, K1, V1, ...]
    assert_allclose(np.asarray(kv[..., 0, :]), np.asarray(k[..., 0, :]))
    assert_allclose(np.asarray(kv[..., 1, :]), np.asarray(v[..., 0, :]))
    k2, v2 = deinterleave_kv(kv)
    assert_allclose(np.asarray(k2), np.asarray(k))
    assert_allclose(np.asarray(v2), np.asarray(v))
    ckv = jax.random.normal(ks[2], (6, 8, 16))
    kr = jax.random.normal(ks[3], (6, 8, 4))
    c2, r2 = split_mla(fuse_mla(ckv, kr), 16)
    assert_allclose(np.asarray(c2), np.asarray(ckv))
    assert_allclose(np.asarray(r2), np.asarray(kr))


@pytest.mark.parametrize("hq,hkv,window", [(4, 2, 0), (8, 8, 0), (4, 1, 12),
                                           (4, 2, 5)])
@pytest.mark.parametrize("nb", [1, 2, 4])
def test_fused_paged_attention_parity(hq, hkv, window, nb):
    """Pipelined fused kernel == legacy split kernel == jnp oracle across
    buffer depths, GQA group sizes, window>0, and page counts {0, 1, many}
    (lengths 0 / 5 / 40)."""
    B, D, P, page = 4, 32, 24, 8
    q, kp, vp, kv = _fused_inputs(jax.random.PRNGKey(21), B, hq, hkv, D,
                                  P, page)
    tables = jnp.array([[-1, -1, -1, -1, -1],
                        [3, -1, -1, -1, -1],
                        [0, 2, 7, 9, -1],
                        [11, 12, 13, 14, 15]], jnp.int32)
    lengths = jnp.array([0, 5, 26, 40], jnp.int32)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths,
                                   page_size=page, window=window)
    fused_want = ref.fused_paged_attention_ref(q, kv, tables, lengths,
                                               page_size=page, window=window)
    assert_allclose(np.asarray(fused_want), np.asarray(want), rtol=1e-6,
                    atol=1e-6)
    legacy = paged_attention_pallas(q, kp, vp, tables, lengths,
                                    page_size=page, window=window,
                                    interpret=True)
    got = fused_paged_attention_pallas(q, kv, tables, lengths,
                                       page_size=page, window=window,
                                       num_buffers=nb, interpret=True)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(got), np.asarray(legacy), rtol=1e-5,
                    atol=1e-5)


def test_fused_paged_vs_dense_decode():
    """Pipelined fused kernel == dense decode attention on the same KV."""
    B, S, Hq, Hkv, D, page = 2, 24, 4, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(22), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    lengths = jnp.array([17, 24], jnp.int32)
    kv = interleave_kv(k.reshape(B * S // page, page, Hkv, D),
                       v.reshape(B * S // page, page, Hkv, D))
    tables = jnp.array([[0, 1, 2], [3, 4, 5]], jnp.int32)
    got = fused_paged_attention_pallas(q, kv, tables, lengths,
                                       page_size=page, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_bitwise_stable_across_buffer_depths():
    """num_buffers is a pure DMA-scheduling knob: the page-visit order and
    online softmax are depth-independent, so outputs must be BITWISE equal
    across depths {1, 2, 4} — for both the GQA and the MLA kernel."""
    B, hq, hkv, D, P, page = 3, 8, 2, 32, 24, 8
    q, _, _, kv = _fused_inputs(jax.random.PRNGKey(23), B, hq, hkv, D,
                                P, page)
    tables = jnp.array([[3, 5, 1, -1, -1],
                        [0, 2, 7, 9, -1],
                        [11, 12, 13, 14, 15]], jnp.int32)
    lengths = jnp.array([19, 26, 40], jnp.int32)
    outs = [np.asarray(fused_paged_attention_pallas(
        q, kv, tables, lengths, page_size=page, num_buffers=nb,
        interpret=True)) for nb in (1, 2, 4)]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])

    H, r, rd = 4, 16, 8
    ql, qr, ckv, kr = _mla_inputs(jax.random.PRNGKey(24), B, H, r, rd,
                                  P, page, 5)
    mkv = fuse_mla(ckv, kr)
    mouts = [np.asarray(mla_fused_paged_attention_pallas(
        ql, qr, mkv, tables, lengths, page_size=page, scale=0.2,
        num_buffers=nb, interpret=True)) for nb in (1, 2, 4)]
    assert np.array_equal(mouts[0], mouts[1])
    assert np.array_equal(mouts[1], mouts[2])


@pytest.mark.parametrize("nb", [1, 2, 4])
def test_mla_fused_paged_attention_parity(nb):
    """Pipelined fused-latent MLA kernel == legacy split kernel == oracle,
    including a zero-length padding row."""
    B, H, r, rd, P, page = 4, 4, 16, 8, 24, 8
    ql, qr, ckv, kr = _mla_inputs(jax.random.PRNGKey(25), B, H, r, rd,
                                  P, page, 5)
    mkv = fuse_mla(ckv, kr)
    tables = jnp.array([[-1, -1, -1, -1, -1],
                        [3, -1, -1, -1, -1],
                        [0, 2, 7, 9, -1],
                        [11, 12, 13, 14, 15]], jnp.int32)
    lengths = jnp.array([0, 5, 26, 40], jnp.int32)
    scale = 1.0 / ((r + rd) ** 0.5)
    want = ref.mla_paged_attention_ref(ql, qr, ckv, kr, tables, lengths,
                                       page_size=page, scale=scale)
    fused_want = ref.mla_fused_paged_attention_ref(
        ql, qr, mkv, tables, lengths, page_size=page, scale=scale)
    assert_allclose(np.asarray(fused_want), np.asarray(want), rtol=1e-6,
                    atol=1e-6)
    legacy = mla_paged_attention_pallas(ql, qr, ckv, kr, tables, lengths,
                                        page_size=page, scale=scale,
                                        interpret=True)
    got = mla_fused_paged_attention_pallas(ql, qr, mkv, tables, lengths,
                                           page_size=page, scale=scale,
                                           num_buffers=nb, interpret=True)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(got), np.asarray(legacy), rtol=1e-5,
                    atol=1e-5)


def test_paged_zero_length_rows_emit_zeros_not_page0_garbage():
    """REGRESSION (fully-masked-row bug): a row with lengths[b] == 0 — a
    padding row in the fixed-shape serve dispatch — left m at -1e30, so
    p = exp(s - m) = exp(0) = 1 for every masked position and the flush
    emitted the MEAN OF PAGE 0's stale contents.  Pre-fix, every kernel
    and both paged references returned ~1e4 here (page 0 is poisoned to
    make the old behavior unmissable); post-fix they must return exact
    zeros.  Covers the legacy split kernels, the pipelined fused kernels
    at every buffer depth, and all four references."""
    B, hq, hkv, D, P, page = 2, 4, 2, 16, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(26), 3)
    q = jax.random.normal(ks[0], (B, hq, D))
    # page 0 poisoned: the old bug averaged these values into the output
    kp = jnp.full((P, page, hkv, D), 1e4)
    vp = jnp.full((P, page, hkv, D), 1e4)
    kv = interleave_kv(kp, vp)
    tables = jnp.array([[-1, -1], [1, 2]], jnp.int32)
    lengths = jnp.array([0, 12], jnp.int32)

    for out in (
        ref.paged_attention_ref(q, kp, vp, tables, lengths, page_size=page),
        ref.fused_paged_attention_ref(q, kv, tables, lengths,
                                      page_size=page),
        paged_attention_pallas(q, kp, vp, tables, lengths, page_size=page,
                               interpret=True),
        *[fused_paged_attention_pallas(q, kv, tables, lengths,
                                       page_size=page, num_buffers=nb,
                                       interpret=True) for nb in (1, 2, 4)],
    ):
        out = np.asarray(out)
        assert np.all(out[0] == 0.0), "padding row leaked page-0 garbage"
        assert np.all(np.isfinite(out)) and abs(out[1]).max() > 0

    H, r, rd = 4, 16, 8
    ql = jax.random.normal(ks[1], (B, H, r))
    qr = jax.random.normal(ks[2], (B, H, rd))
    ckv = jnp.full((P, page, r), 1e4)
    kr = jnp.full((P, page, rd), 1e4)
    mkv = fuse_mla(ckv, kr)
    for out in (
        ref.mla_paged_attention_ref(ql, qr, ckv, kr, tables, lengths,
                                    page_size=page, scale=0.2),
        ref.mla_fused_paged_attention_ref(ql, qr, mkv, tables, lengths,
                                          page_size=page, scale=0.2),
        mla_paged_attention_pallas(ql, qr, ckv, kr, tables, lengths,
                                   page_size=page, scale=0.2,
                                   interpret=True),
        *[mla_fused_paged_attention_pallas(ql, qr, mkv, tables, lengths,
                                           page_size=page, scale=0.2,
                                           num_buffers=nb, interpret=True)
          for nb in (1, 2, 4)],
    ):
        out = np.asarray(out)
        assert np.all(out[0] == 0.0), "padding row leaked page-0 garbage"
        assert np.all(np.isfinite(out)) and abs(out[1]).max() > 0


@pytest.mark.parametrize("window", [5, 12])
def test_windowed_radix_shared_prefix_parity(window):
    """Satellite audit: `window > 0` masking composed with radix-style
    block tables whose LEADING pages are shared across rows (the
    cross-request prefix-cache case) and a padded (length-0) row.
    Positions stay consecutive per path regardless of page sharing, so
    the windowed kernels must match a per-row dense gather exactly — no
    double-counting across the shared/private page boundary."""
    Hq, Hkv, D, P, page = 4, 2, 16, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(27), 3)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (P, page, Hkv, D))
    kv = interleave_kv(kp, vp)
    # rows 0/1 share leading pages [2, 3] (radix-matched prefix), then
    # diverge into private pages; row 2 is a padding row
    tables = jnp.array([[2, 3, 5, -1],
                        [2, 3, 9, 11],
                        [-1, -1, -1, -1]], jnp.int32)
    lengths = jnp.array([19, 27, 0], jnp.int32)
    B = tables.shape[0]
    q = jax.random.normal(ks[0], (B, Hq, D))

    # independent oracle: per-row dense gather of the row's own pages,
    # then dense decode attention with the same window
    S = tables.shape[1] * page
    k_dense = kp[jnp.maximum(tables, 0)].reshape(B, S, Hkv, D)
    v_dense = vp[jnp.maximum(tables, 0)].reshape(B, S, Hkv, D)
    want = np.array(ref.decode_attention_ref(q, k_dense, v_dense, lengths,
                                             window=window))
    want[np.asarray(lengths) == 0] = 0.0

    legacy = paged_attention_pallas(q, kp, vp, tables, lengths,
                                    page_size=page, window=window,
                                    interpret=True)
    assert_allclose(np.asarray(legacy), want, rtol=1e-5, atol=1e-5)
    for nb in (1, 2, 4):
        got = fused_paged_attention_pallas(q, kv, tables, lengths,
                                           page_size=page, window=window,
                                           num_buffers=nb, interpret=True)
        assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_fused_dispatch_interpret(monkeypatch):
    """REPRO_PALLAS_INTERPRET=1 routes kops.fused_paged_attention /
    kops.mla_fused_paged_attention through the interpreted pipelined
    kernels; parity with the forced-reference path."""
    from repro.kernels import ops as kops

    B, hq, hkv, D, P, page = 2, 4, 2, 16, 8, 8
    q, _, _, kv = _fused_inputs(jax.random.PRNGKey(28), B, hq, hkv, D,
                                P, page)
    tables = jnp.array([[0, 1, -1], [2, 3, 4]], jnp.int32)
    lengths = jnp.array([11, 22], jnp.int32)
    kw = dict(page_size=page, window=6)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got = kops.fused_paged_attention(q, kv, tables, lengths, **kw)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    want = kops.fused_paged_attention(q, kv, tables, lengths, **kw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    H, r, rd = 4, 16, 8
    ql, qr, ckv, kr = _mla_inputs(jax.random.PRNGKey(29), B, H, r, rd,
                                  P, page, 3)
    mkv = fuse_mla(ckv, kr)
    mkw = dict(page_size=page, scale=0.2)
    monkeypatch.setenv("REPRO_FORCE_REF", "0")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got = kops.mla_fused_paged_attention(ql, qr, mkv, tables, lengths,
                                         **mkw)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    want = kops.mla_fused_paged_attention(ql, qr, mkv, tables, lengths,
                                          **mkw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MLA (absorbed-latent) paged attention
# ---------------------------------------------------------------------------

def _mla_inputs(key, B, H, r, rd, P, page, MP):
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (B, H, r)),
            jax.random.normal(ks[1], (B, H, rd)),
            jax.random.normal(ks[2], (P, page, r)),
            jax.random.normal(ks[3], (P, page, rd)))


@pytest.mark.parametrize("H,r,rd", [(4, 16, 8), (2, 32, 16), (8, 64, 32),
                                    (1, 16, 8)])
def test_mla_paged_attention(H, r, rd):
    """Parity vs the jnp oracle across head counts, ragged lengths, and
    padded (-1) block-table entries."""
    B, P, page, MP = 3, 24, 8, 5
    ql, qr, ckv, kr = _mla_inputs(jax.random.PRNGKey(8), B, H, r, rd,
                                  P, page, MP)
    tables = jnp.array([[3, 5, 1, -1, -1],
                        [0, 2, 7, 9, -1],
                        [11, 12, 13, 14, 15]], jnp.int32)
    lengths = jnp.array([19, 26, 40], jnp.int32)
    scale = 1.0 / ((r + rd) ** 0.5)
    got = mla_paged_attention_pallas(ql, qr, ckv, kr, tables, lengths,
                                     page_size=page, scale=scale,
                                     interpret=True)
    want = ref.mla_paged_attention_ref(ql, qr, ckv, kr, tables, lengths,
                                       page_size=page, scale=scale)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("lengths", [[1, 8, 9], [7, 16, 31], [32, 32, 32]])
def test_mla_paged_attention_lengths(lengths):
    """Sweep page-boundary lengths: single token, exact page multiples,
    one-past-page."""
    B, H, r, rd, P, page = 3, 4, 16, 8, 16, 8
    ql, qr, ckv, kr = _mla_inputs(jax.random.PRNGKey(9), B, H, r, rd,
                                  P, page, 4)
    tables = jnp.array([[0, 1, 2, 3], [4, 5, 6, 7], [8, 9, 10, 11]],
                       jnp.int32)
    ln = jnp.asarray(lengths, jnp.int32)
    got = mla_paged_attention_pallas(ql, qr, ckv, kr, tables, ln,
                                     page_size=page, scale=0.25,
                                     interpret=True)
    want = ref.mla_paged_attention_ref(ql, qr, ckv, kr, tables, ln,
                                       page_size=page, scale=0.25)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_mla_paged_dispatch_interpret(monkeypatch):
    """REPRO_PALLAS_INTERPRET=1 routes kops.mla_paged_attention through the
    interpreted Pallas kernel; parity with the reference path."""
    from repro.kernels import ops as kops

    B, H, r, rd, P, page = 2, 4, 16, 8, 8, 8
    ql, qr, ckv, kr = _mla_inputs(jax.random.PRNGKey(10), B, H, r, rd,
                                  P, page, 3)
    tables = jnp.array([[0, 1, -1], [2, 3, 4]], jnp.int32)
    lengths = jnp.array([11, 22], jnp.int32)
    kw = dict(page_size=page, scale=0.2)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got = kops.mla_paged_attention(ql, qr, ckv, kr, tables, lengths, **kw)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    want = kops.mla_paged_attention(ql, qr, ckv, kr, tables, lengths, **kw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,D", [(1, 5, 1, 8), (2, 16, 3, 16),
                                     (1, 33, 2, 64)])
def test_wkv6(B, T, H, D):
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)) + 2.0)
    u = jax.random.normal(ks[4], (H, D))
    s0 = jax.random.normal(ks[5], (B, H, D, D))
    got_o, got_s = wkv6_pallas(r, k, v, w, u, s0, interpret=True)
    want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
    assert_allclose(np.asarray(got_o), np.asarray(want_o),
                    rtol=5e-4, atol=5e-4)
    assert_allclose(np.asarray(got_s), np.asarray(want_s),
                    rtol=5e-4, atol=5e-4)


def _segment_layout(B, T, seed=0):
    """Ragged per-row segment labels with a tail pad, plus the column
    span of one interior segment per row (for leak checks)."""
    seg = np.full((B, T), -1, np.int32)
    spans = []
    cuts = [0, T // 3, 2 * T // 3, T - 2]
    for b in range(B):
        for s in range(len(cuts) - 1):
            seg[b, cuts[s]: cuts[s + 1]] = s
        spans.append((cuts[1], cuts[2]))
    return jnp.asarray(seg), spans


@pytest.mark.parametrize("B,T,d_in,N", [(2, 24, 8, 4), (1, 33, 16, 8)])
def test_mamba_scan_segment_reset(B, T, d_in, N):
    """Segment-reset parity (Pallas vs ref), per-segment equivalence to a
    fresh scan, and the leak case: without the reset, state from the
    previous segment would contaminate the next one."""
    ks = jax.random.split(jax.random.PRNGKey(12), 5)
    u = jax.random.normal(ks[0], (B, T, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, d_in)))
    B_ = jax.random.normal(ks[2], (B, T, N))
    C_ = jax.random.normal(ks[3], (B, T, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d_in, N)) * 0.3)
    D = jnp.ones((d_in,))
    h0 = jnp.zeros((B, d_in, N))
    seg, spans = _segment_layout(B, T)
    got_y, got_h = mamba_scan_pallas(u, dt, B_, C_, A, D, h0, seg,
                                     blk_d=d_in, interpret=True)
    want_y, want_h = ref.mamba_scan_ref(u, dt, B_, C_, A, D, h0,
                                        segment_ids=seg)
    assert_allclose(np.asarray(got_y), np.asarray(want_y),
                    rtol=2e-5, atol=2e-5)
    assert_allclose(np.asarray(got_h), np.asarray(want_h),
                    rtol=2e-5, atol=2e-5)
    lo, hi = spans[0]
    # the interior segment scans exactly as it would in its own row
    solo_y, _ = ref.mamba_scan_ref(u[:, lo:hi], dt[:, lo:hi], B_[:, lo:hi],
                                   C_[:, lo:hi], A, D, h0)
    assert_allclose(np.asarray(want_y[:, lo:hi]), np.asarray(solo_y),
                    rtol=1e-5, atol=1e-5)
    # leak case: dropping the reset changes that segment's output
    leak_y, _ = ref.mamba_scan_ref(u, dt, B_, C_, A, D, h0)
    assert not np.allclose(np.asarray(leak_y[:, lo:hi]),
                           np.asarray(solo_y))


@pytest.mark.parametrize("B,T,H,D", [(2, 24, 2, 8), (1, 33, 1, 16)])
def test_wkv6_segment_reset(B, T, H, D):
    """Segment-reset parity (Pallas vs ref), per-segment equivalence to a
    fresh recurrence, and the leak case without the reset."""
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)) + 2.0)
    u = jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    seg, spans = _segment_layout(B, T)
    got_o, got_s = wkv6_pallas(r, k, v, w, u, s0, seg, interpret=True)
    want_o, want_s = ref.wkv6_ref(r, k, v, w, u, s0, segment_ids=seg)
    assert_allclose(np.asarray(got_o), np.asarray(want_o),
                    rtol=5e-4, atol=5e-4)
    assert_allclose(np.asarray(got_s), np.asarray(want_s),
                    rtol=5e-4, atol=5e-4)
    lo, hi = spans[0]
    solo_o, _ = ref.wkv6_ref(r[:, lo:hi], k[:, lo:hi], v[:, lo:hi],
                             w[:, lo:hi], u, s0)
    assert_allclose(np.asarray(want_o[:, lo:hi]), np.asarray(solo_o),
                    rtol=1e-5, atol=1e-5)
    leak_o, _ = ref.wkv6_ref(r, k, v, w, u, s0)
    assert not np.allclose(np.asarray(leak_o[:, lo:hi]),
                           np.asarray(solo_o))


def test_recurrent_segment_dispatch_interpret(monkeypatch):
    """REPRO_PALLAS_INTERPRET=1 routes the segment-reset kops through the
    interpreted kernels; parity with the forced-reference path."""
    from repro.kernels import ops as kops

    B, T, H, D = 1, 12, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(14), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    seg = jnp.asarray(np.repeat([[0, 1, 2]], 4, axis=1).reshape(1, 12))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    got_o, got_s = kops.wkv6(r, k, v, w, u, s0, segment_ids=seg)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    monkeypatch.setenv("REPRO_FORCE_REF", "1")
    want_o, want_s = kops.wkv6(r, k, v, w, u, s0, segment_ids=seg)
    assert_allclose(np.asarray(got_o), np.asarray(want_o),
                    rtol=5e-4, atol=5e-4)
    assert_allclose(np.asarray(got_s), np.asarray(want_s),
                    rtol=5e-4, atol=5e-4)


def test_wkv6_state_chaining():
    """Running two halves with carried state == one full run."""
    B, T, H, D = 1, 12, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    o_full, s_full = ref.wkv6_ref(r, k, v, w, u, s0)
    h = T // 2
    o1, s1 = wkv6_pallas(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, s0,
                         interpret=True)
    o2, s2 = wkv6_pallas(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s1,
                         interpret=True)
    assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                    np.asarray(o_full), rtol=5e-4, atol=5e-4)
    assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=5e-4, atol=5e-4)
